//! Quickstart: the full NeuraLUT-Assemble toolflow on the smallest
//! configuration (network intrusion detection), in under a minute.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Steps shown: dataset synthesis -> learned-mappings dense phase ->
//! sparse tree QAT (PJRT-executed train_step driven from rust) ->
//! truth-table enumeration -> bit-exact netlist -> technology mapping ->
//! timing under both pipelining strategies -> Verilog emission.

use anyhow::Result;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions};
use neuralut::dataset::GenOpts;
use neuralut::netlist::OptLevel;
use neuralut::report::{pct, sci};
use neuralut::runtime::Runtime;

fn main() -> Result<()> {
    let meta = Meta::load(Meta::default_dir())?; // artifacts/meta.json
    let rt = Runtime::new()?;

    let opts = FlowOptions {
        config: "nid".into(),
        dense_steps: 300,   // learned-mappings phase (0 = random wiring)
        sparse_steps: 800,  // tree QAT from scratch on the selected wiring
        skip_scale: 1.0,
        seed: 7,
        gen: GenOpts { n_train: 8000, n_test: 1500, ..Default::default() },
        emit_rtl: true,
        verify_bit_exact: true,
        opt_level: OptLevel::Full,
    };
    let r = run_flow(&rt, &meta, &opts)?;

    println!("== NeuraLUT-Assemble quickstart (NID) ==");
    println!("QAT accuracy:            {}", pct(r.qat_acc));
    println!("netlist accuracy:        {}", pct(r.netlist_acc));
    println!("netlist == PJRT forward: {:?} (bit-exact)", r.bit_exact);
    println!("optimizer:               {}", r.opt_report.summary());
    println!("L-LUTs: {} -> {}   mapped P-LUTs: {} (raw {})",
             r.netlist.total_units(), r.netlist_opt.total_units(),
             r.mapped.total_luts(), r.mapped_raw.total_luts());
    for (name, rep) in &r.reports {
        println!(
            "{name}: Fmax {:.0} MHz, latency {:.2} ns, {} FFs, ADP {}",
            rep.fmax_mhz, rep.latency_ns, rep.ffs, sci(rep.area_delay)
        );
    }
    let rtl = r.rtl_text.as_ref().unwrap();
    std::fs::write("nid.v", rtl)?;
    println!("Verilog written to nid.v ({} lines)", rtl.lines().count());
    assert_eq!(r.bit_exact, Some(true), "netlist must match the QAT model");
    Ok(())
}
