//! Loopback load generator for the TCP serving frontend.
//!
//! Two modes:
//! * **self-host** (default): spins up an in-process `NetServer` over
//!   a seeded random netlist and drives it over 127.0.0.1 — a
//!   one-command demo needing no trained artifacts;
//! * **`--addr HOST:PORT`**: drives an already-running
//!   `neuralut serve --listen` process (what the CI smoke job does).
//!
//! Three sweeps, all on the same server:
//! * **capacity**: pipelining depth per connection. Depths under both
//!   the per-connection quota and the global admission bound must
//!   never shed; the final stage deliberately exceeds the global
//!   bound and must see explicit `OVERLOADED`/`CONN_QUOTA` sheds —
//!   bounded-queue rejection, not queue collapse.
//! * **deadline**: the overload depth again, but with a per-request
//!   deadline budget. A budget under the observed p50 is shed at
//!   admission (`DEADLINE`, counted separately from capacity sheds);
//!   a roomy budget is honored — the p99 of the *answered* requests
//!   stays inside it even past capacity.
//! * **retry**: greedy flooder connections saturate admission while a
//!   `RetryClient` pushes requests through; every request ends in a
//!   bit-delivered answer or a typed give-up, and the retry counters
//!   land in the artifact.
//!
//! Results (throughput, p50/p99/p999, shed/retry counters per stage)
//! land in `BENCH_serve.json` next to the other `BENCH_*.json`
//! artifacts.
//!
//! Run: `cargo run --release --example serve_load -- [--quick]
//! [--addr HOST:PORT] [--requests N] [--max-inflight N]
//! [--max-inflight-per-conn N] [--connect-timeout-ms N]`

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use neuralut::coordinator::{InferenceServer, ModelRegistry, ServerConfig};
use neuralut::metrics::LatencyStats;
use neuralut::net::wire::{self, Message};
use neuralut::net::{Client, ClientConfig, NetConfig, NetServer,
                    RetryClient, RetryPolicy};
use neuralut::netlist::testutil::{random_inputs, random_netlist};
use neuralut::report::Table;
use neuralut::util::Json;

struct StageResult {
    kind: &'static str,
    depth: usize,
    deadline_us: Option<u64>,
    requests: usize,
    ok: usize,
    shed: usize,
    quota_sheds: usize,
    deadline_sheds: usize,
    secs: f64,
    /// All responses, sheds included (capacity-sweep latency).
    lat: LatencyStats,
    /// Answered (Result) responses only — what a deadline budget is
    /// measured against.
    lat_ok: LatencyStats,
}

/// Drive `n` single-row requests with `depth` kept in flight,
/// optionally carrying a deadline budget on every request.
fn run_stage(c: &mut Client, kind: &'static str, model: &str,
             n_in: usize, depth: usize, deadline_us: Option<u64>,
             n: usize, xs: &[i32]) -> StageResult {
    let mut window: VecDeque<(u64, Instant)> = VecDeque::new();
    let mut lat = LatencyStats::default();
    let mut lat_ok = LatencyStats::default();
    let mut ok = 0usize;
    let mut shed = 0usize;
    let mut quota_sheds = 0usize;
    let mut deadline_sheds = 0usize;
    let mut recv = |window: &mut VecDeque<(u64, Instant)>,
                    c: &mut Client, lat: &mut LatencyStats,
                    lat_ok: &mut LatencyStats, ok: &mut usize,
                    shed: &mut usize, quota_sheds: &mut usize,
                    deadline_sheds: &mut usize| {
        let (id, sent) = window.pop_front().expect("window empty");
        let frame = c.recv_frame().expect("response");
        assert_eq!(frame.id, id, "responses must arrive in order");
        let us = sent.elapsed().as_secs_f64() * 1e6;
        lat.record(us);
        match frame.msg {
            Message::Result { .. } => {
                lat_ok.record(us);
                *ok += 1;
            }
            Message::Error { code, message } => match code {
                wire::ERR_OVERLOADED => *shed += 1,
                wire::ERR_CONN_QUOTA => *quota_sheds += 1,
                wire::ERR_DEADLINE => *deadline_sheds += 1,
                _ => panic!("unexpected error under load: {message}"),
            },
            other => panic!("unexpected frame {other:?}"),
        }
    };
    let t = Instant::now();
    for i in 0..n {
        if window.len() >= depth {
            recv(&mut window, c, &mut lat, &mut lat_ok, &mut ok,
                 &mut shed, &mut quota_sheds, &mut deadline_sheds);
        }
        let row = xs[(i % (xs.len() / n_in)) * n_in..][..n_in].to_vec();
        let id = c.send_infer_deadline(model, 1, n_in as u32, row,
                                       deadline_us)
            .expect("send");
        window.push_back((id, Instant::now()));
    }
    while !window.is_empty() {
        recv(&mut window, c, &mut lat, &mut lat_ok, &mut ok, &mut shed,
             &mut quota_sheds, &mut deadline_sheds);
    }
    StageResult { kind, depth, deadline_us, requests: n, ok, shed,
                  quota_sheds, deadline_sheds,
                  secs: t.elapsed().as_secs_f64(), lat, lat_ok }
}

fn stage_row(r: &StageResult) -> Json {
    let s = r.lat.summary();
    let mut row = BTreeMap::new();
    row.insert("kind".into(), Json::Str(r.kind.into()));
    row.insert("depth".into(), Json::Num(r.depth as f64));
    if let Some(dl) = r.deadline_us {
        row.insert("deadline_us".into(), Json::Num(dl as f64));
    }
    row.insert("requests".into(), Json::Num(r.requests as f64));
    row.insert("ok".into(), Json::Num(r.ok as f64));
    row.insert("shed".into(), Json::Num(r.shed as f64));
    row.insert("quota_sheds".into(), Json::Num(r.quota_sheds as f64));
    row.insert("deadline_sheds".into(),
               Json::Num(r.deadline_sheds as f64));
    row.insert("req_per_s".into(),
               Json::Num(r.requests as f64 / r.secs));
    row.insert("mean_us".into(), Json::Num(s.mean));
    row.insert("p50_us".into(), Json::Num(s.p50));
    row.insert("p99_us".into(), Json::Num(s.p99));
    row.insert("p999_us".into(), Json::Num(s.p999));
    if r.ok > 0 {
        row.insert("p99_answered_us".into(),
                   Json::Num(r.lat_ok.summary().p99));
    }
    Json::Obj(row)
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let addr = flag(&args, "--addr");
    let per_stage: usize = flag(&args, "--requests")
        .map(|v| v.parse().expect("--requests N"))
        .unwrap_or(if quick { 400 } else { 5000 });
    let connect_timeout = Duration::from_millis(
        flag(&args, "--connect-timeout-ms")
            .map(|v| v.parse().expect("--connect-timeout-ms N"))
            .unwrap_or(5000),
    );

    // self-host unless --addr points at a live `serve --listen`
    let hosted: Option<(NetServer, neuralut::netlist::Netlist)> =
        if addr.is_none() {
            let max_inflight: usize = flag(&args, "--max-inflight")
                .map(|v| v.parse().expect("--max-inflight N"))
                .unwrap_or(64);
            let per_conn: Option<usize> =
                flag(&args, "--max-inflight-per-conn")
                    .map(|v| v.parse().expect("--max-inflight-per-conn N"));
            let nl = random_netlist(11, 8, 1, &[(6, 3, 2), (4, 2, 2)]);
            let mut registry = ModelRegistry::new();
            registry.register("loadtest", nl.clone());
            let server = InferenceServer::start(
                registry,
                ServerConfig { max_batch: 32,
                               max_wait: Duration::from_micros(100),
                               ..ServerConfig::default() });
            let cfg = NetConfig { max_inflight,
                                  max_inflight_per_conn: per_conn,
                                  ..NetConfig::default() };
            let quota = cfg.conn_quota();
            let net = NetServer::bind(server, "127.0.0.1:0", cfg)
                .expect("bind loopback");
            println!("self-hosting on {} (max {} in-flight rows, {} per \
                      connection)",
                     net.local_addr(), max_inflight, quota);
            Some((net, nl))
        } else {
            None
        };
    let target = addr.clone().unwrap_or_else(|| {
        hosted.as_ref().unwrap().0.local_addr().to_string()
    });

    let client_cfg = ClientConfig { connect_timeout,
                                    ..ClientConfig::default() };
    let mut c = Client::connect_with(&target[..], &client_cfg)
        .expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.ping().expect("ping");

    // discover the first hosted model, the admission bound and the
    // per-connection quota
    let stats = c.stats("").expect("stats");
    let doc = Json::parse(&stats).expect("stats json");
    let entry = &doc.at("models").unwrap().as_arr().unwrap()[0];
    let model = entry.at("model").unwrap().as_str().unwrap().to_string();
    let n_in = entry.at("n_in").unwrap().as_usize().unwrap();
    let srv = doc.at("server").unwrap();
    let max_inflight =
        srv.at("max_inflight").unwrap().as_usize().unwrap();
    let quota =
        srv.at("max_inflight_per_conn").unwrap().as_usize().unwrap();
    println!("driving model '{model}' (n_in {n_in}) on {target}; \
              admission bound {max_inflight} rows, {quota} per \
              connection");

    // reproducible inputs: sweep valid codes without needing the model
    let in_bits_guess = 1usize; // codes 0/1 are valid for any in_bits
    let xs: Vec<i32> = (0..1024 * n_in)
        .map(|i| ((i * 7 + i / n_in) % (1 << in_bits_guess)) as i32)
        .collect();

    // capacity sweep: strictly under both bounds (must not shed — at
    // exactly a bound a shed can race the writer's release), then
    // past the global bound (must shed explicitly)
    let safe = quota.min(max_inflight);
    let mut depths: Vec<usize> = [1usize, 8, 32]
        .into_iter()
        .filter(|&d| d < max_inflight)
        .collect();
    let overload_depth = (max_inflight * 4).clamp(max_inflight + 8, 4096);
    depths.push(overload_depth);

    let mut table = Table::new(
        "TCP serving under load (single connection, pipelined)",
        &["kind", "depth", "requests", "ok", "shed", "quota", "deadl",
          "req/s", "p50 us", "p99 us", "p999 us"],
    );
    let mut emit = |table: &mut Table, r: &StageResult| {
        let s = r.lat.summary();
        table.row(&[
            r.kind.to_string(),
            r.depth.to_string(),
            r.requests.to_string(),
            r.ok.to_string(),
            r.shed.to_string(),
            r.quota_sheds.to_string(),
            r.deadline_sheds.to_string(),
            format!("{:.0}", r.requests as f64 / r.secs),
            format!("{:.0}", s.p50),
            format!("{:.0}", s.p99),
            format!("{:.0}", s.p999),
        ]);
    };
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for &depth in &depths {
        let r = run_stage(&mut c, "capacity", &model, n_in, depth, None,
                          per_stage, &xs);
        emit(&mut table, &r);
        rows.push(stage_row(&r));
        results.push(r);
    }

    // the contract the capacity sweep must prove: no sheds under both
    // bounds, explicit sheds past the global bound, and every request
    // answered either way
    for r in &results {
        assert_eq!(r.ok + r.shed + r.quota_sheds, r.requests,
                   "depth {}: {} requests vanished", r.depth,
                   r.requests - r.ok - r.shed - r.quota_sheds);
        if r.depth < safe {
            assert_eq!(r.shed + r.quota_sheds, 0,
                       "depth {} is under both bounds yet shed {}",
                       r.depth, r.shed + r.quota_sheds);
        }
    }
    let overload = results.last().unwrap();
    assert!(overload.shed + overload.quota_sheds > 0,
            "depth {} past the bound {} never shed — admission \
             control is not bounding the queue",
            overload.depth, max_inflight);
    println!("\noverload stage (depth {}): {} served, {} explicitly \
              shed — bounded admission holds",
             overload.depth, overload.ok,
             overload.shed + overload.quota_sheds);

    // deadline sweep at the same overload depth: the p50 the server
    // has observed by now decides admission.  The tight budget is a
    // tenth of the *client-side* depth-1 p50 — decisively below the
    // server's own service-time estimate even after subtracting wire
    // overhead, so the shed is deterministic, not a coin flip
    let p50 = results[0].lat.summary().p50.max(1.0);
    let tight = ((p50 / 10.0) as u64).max(1);
    let roomy = ((p50 * 20.0) as u64).max(5_000);
    let mut deadline_results = Vec::new();
    for (budget, label) in [(tight, "tight"), (roomy, "roomy")] {
        let r = run_stage(&mut c, "deadline", &model, n_in,
                          overload_depth, Some(budget), per_stage, &xs);
        assert_eq!(r.ok + r.shed + r.quota_sheds + r.deadline_sheds,
                   r.requests, "{label}: requests vanished");
        emit(&mut table, &r);
        rows.push(stage_row(&r));
        deadline_results.push((budget, label, r));
    }
    let (_, _, tight_r) = &deadline_results[0];
    assert!(tight_r.deadline_sheds > 0,
            "a {tight} µs budget under the observed p50 ({p50:.0} µs) \
             never shed — deadline admission is not engaging");
    let (_, _, roomy_r) = &deadline_results[1];
    assert!(roomy_r.ok > 0, "a roomy {roomy} µs budget served nothing");
    let p99_answered = roomy_r.lat_ok.summary().p99;
    println!("deadline stages: tight {tight} µs shed {} of {} at \
              admission; roomy {roomy} µs answered {} with p99 \
              {p99_answered:.0} µs",
             tight_r.deadline_sheds, tight_r.requests, roomy_r.ok);
    if !quick {
        assert!(p99_answered <= roomy as f64,
                "p99 of answered requests ({p99_answered:.0} µs) blew \
                 the {roomy} µs budget they were admitted under");
    }

    // retry stage: saturate admission with greedy flooder connections,
    // then push requests through a RetryClient — every request ends in
    // an answer or a typed give-up, never silence
    let flooders = max_inflight / quota.max(1) + 1;
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for _ in 0..flooders {
        let stop = stop.clone();
        let target = target.clone();
        let model = model.clone();
        let row: Vec<i32> = xs[..n_in].to_vec();
        let depth = quota.max(1);
        handles.push(std::thread::spawn(move || {
            let Ok(mut c) = Client::connect(&target[..]) else { return };
            let _ = c.set_read_timeout(Some(Duration::from_secs(1)));
            let mut outstanding = 0usize;
            while !stop.load(Ordering::Relaxed) {
                while outstanding < depth && !stop.load(Ordering::Relaxed)
                {
                    if c.send_infer(&model, 1, n_in as u32, row.clone())
                        .is_err()
                    {
                        return;
                    }
                    outstanding += 1;
                }
                if c.recv_frame().is_ok() {
                    outstanding -= 1;
                } else {
                    return;
                }
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(100));
    let retry_cfg = ClientConfig {
        connect_timeout,
        read_timeout: Some(Duration::from_secs(10)),
        retry: RetryPolicy { max_attempts: 6,
                             base: Duration::from_millis(2),
                             cap: Duration::from_millis(50),
                             seed: 0xBEEF },
        fault: None,
    };
    let mut rc = RetryClient::connect(&target[..], retry_cfg)
        .expect("retry connect");
    let retry_n = if quick { 100 } else { 500 };
    let t = Instant::now();
    let mut retry_ok = 0usize;
    let mut gave_up = 0usize;
    let mut retry_lat = LatencyStats::default();
    for i in 0..retry_n {
        let row = &xs[(i % (xs.len() / n_in)) * n_in..][..n_in];
        let sent = Instant::now();
        match rc.infer(&model, 1, n_in, row, None) {
            Ok(_) => {
                retry_lat.record(sent.elapsed().as_secs_f64() * 1e6);
                retry_ok += 1;
            }
            Err(_) => gave_up += 1,
        }
    }
    let retry_secs = t.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    let st = rc.retry_stats();
    assert_eq!(retry_ok + gave_up, retry_n, "retry requests vanished");
    assert!(retry_ok > 0,
            "the retry client served nothing through the flood");
    println!("retry stage: {retry_ok}/{retry_n} served through \
              {flooders} flooder connections ({} retries, {} typed \
              give-ups)", st.retries, gave_up);
    {
        let s = retry_lat.summary();
        let mut row = BTreeMap::new();
        row.insert("kind".into(), Json::Str("retry".into()));
        row.insert("flooders".into(), Json::Num(flooders as f64));
        row.insert("requests".into(), Json::Num(retry_n as f64));
        row.insert("ok".into(), Json::Num(retry_ok as f64));
        row.insert("gave_up".into(), Json::Num(gave_up as f64));
        row.insert("attempts".into(), Json::Num(st.attempts as f64));
        row.insert("retries".into(), Json::Num(st.retries as f64));
        row.insert("reconnects".into(), Json::Num(st.reconnects as f64));
        row.insert("backoff_us".into(), Json::Num(st.backoff_us as f64));
        row.insert("req_per_s".into(),
                   Json::Num(retry_n as f64 / retry_secs));
        row.insert("p50_us".into(), Json::Num(s.p50));
        row.insert("p99_us".into(), Json::Num(s.p99));
        rows.push(Json::Obj(row));
    }
    table.print();

    // final server-side stats ride along in the bench artifact
    let final_stats = c.stats("").expect("final stats");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("serve".into()));
    root.insert("quick".into(), Json::Bool(quick));
    root.insert("addr".into(), Json::Str(target.clone()));
    root.insert("model".into(), Json::Str(model.clone()));
    root.insert("max_inflight".into(), Json::Num(max_inflight as f64));
    root.insert("max_inflight_per_conn".into(), Json::Num(quota as f64));
    root.insert("requests_per_stage".into(),
                Json::Num(per_stage as f64));
    root.insert("stages".into(), Json::Arr(rows));
    root.insert("server_stats".into(),
                Json::parse(&final_stats).expect("final stats json"));
    let path = "BENCH_serve.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if let Some((net, nl)) = hosted {
        // self-host epilogue: spot-check the answers really came from
        // the model (the stages only checked delivery, not values)
        let x = random_inputs(12, &nl, 1);
        let y = c.infer("loadtest", 1, n_in, x.clone()).expect("infer");
        assert_eq!(y, nl.eval_one(&x).unwrap(), "served answer differs");
        drop(c);
        net.shutdown();
        println!("drained cleanly; {} connections served, {} requests \
                  shed overall ({} deadline, {} quota)",
                 net.accepted_conns(), net.shed_total(),
                 net.deadline_sheds_total(), net.quota_sheds_total());
    }
}
