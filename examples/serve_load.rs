//! Loopback load generator for the TCP serving frontend.
//!
//! Two modes:
//! * **self-host** (default): spins up an in-process `NetServer` over
//!   a seeded random netlist and drives it over 127.0.0.1 — a
//!   one-command demo needing no trained artifacts;
//! * **`--addr HOST:PORT`**: drives an already-running
//!   `neuralut serve --listen` process (what the CI smoke job does).
//!
//! The generator sweeps pipelining depth: each stage keeps `depth`
//! requests in flight on one connection and measures client-side
//! latency per request.  Depths at or below the server's admission
//! bound must never shed; the final stage deliberately exceeds the
//! bound and must see explicit `OVERLOADED` sheds — bounded-queue
//! rejection, not queue collapse.  Results (throughput, p50/p99/p999
//! at and beyond the shed point) land in `BENCH_serve.json` next to
//! the other `BENCH_*.json` artifacts.
//!
//! Run: `cargo run --release --example serve_load -- [--quick]
//! [--addr HOST:PORT] [--requests N] [--max-inflight N]`

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use neuralut::coordinator::{InferenceServer, ModelRegistry, ServerConfig};
use neuralut::metrics::LatencyStats;
use neuralut::net::wire::Message;
use neuralut::net::{Client, NetConfig, NetServer};
use neuralut::netlist::testutil::{random_inputs, random_netlist};
use neuralut::report::Table;
use neuralut::util::Json;

struct StageResult {
    depth: usize,
    requests: usize,
    ok: usize,
    shed: usize,
    secs: f64,
    lat: LatencyStats,
}

/// Drive `n` single-row requests with `depth` kept in flight.
fn run_stage(c: &mut Client, model: &str, n_in: usize, depth: usize,
             n: usize, xs: &[i32]) -> StageResult {
    let mut window: VecDeque<(u64, Instant)> = VecDeque::new();
    let mut lat = LatencyStats::default();
    let mut ok = 0usize;
    let mut shed = 0usize;
    let mut recv = |window: &mut VecDeque<(u64, Instant)>,
                    c: &mut Client, lat: &mut LatencyStats,
                    ok: &mut usize, shed: &mut usize| {
        let (id, sent) = window.pop_front().expect("window empty");
        let frame = c.recv_frame().expect("response");
        assert_eq!(frame.id, id, "responses must arrive in order");
        lat.record(sent.elapsed().as_secs_f64() * 1e6);
        match frame.msg {
            Message::Result { .. } => *ok += 1,
            Message::Error { code, message } => {
                assert_eq!(code, neuralut::net::wire::ERR_OVERLOADED,
                           "unexpected error under load: {message}");
                *shed += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    };
    let t = Instant::now();
    for i in 0..n {
        if window.len() >= depth {
            recv(&mut window, c, &mut lat, &mut ok, &mut shed);
        }
        let row = xs[(i % (xs.len() / n_in)) * n_in..][..n_in].to_vec();
        let id = c.send_infer(model, 1, n_in as u32, row)
            .expect("send");
        window.push_back((id, Instant::now()));
    }
    while !window.is_empty() {
        recv(&mut window, c, &mut lat, &mut ok, &mut shed);
    }
    StageResult { depth, requests: n, ok, shed,
                  secs: t.elapsed().as_secs_f64(), lat }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let addr = flag(&args, "--addr");
    let per_stage: usize = flag(&args, "--requests")
        .map(|v| v.parse().expect("--requests N"))
        .unwrap_or(if quick { 400 } else { 5000 });

    // self-host unless --addr points at a live `serve --listen`
    let hosted: Option<(NetServer, neuralut::netlist::Netlist)> =
        if addr.is_none() {
            let max_inflight: usize = flag(&args, "--max-inflight")
                .map(|v| v.parse().expect("--max-inflight N"))
                .unwrap_or(64);
            let nl = random_netlist(11, 8, 1, &[(6, 3, 2), (4, 2, 2)]);
            let mut registry = ModelRegistry::new();
            registry.register("loadtest", nl.clone());
            let server = InferenceServer::start(
                registry,
                ServerConfig { max_batch: 32,
                               max_wait: Duration::from_micros(100),
                               ..ServerConfig::default() });
            let net = NetServer::bind(
                server, "127.0.0.1:0",
                NetConfig { max_inflight, ..NetConfig::default() })
                .expect("bind loopback");
            println!("self-hosting on {} (max {} in-flight rows)",
                     net.local_addr(), max_inflight);
            Some((net, nl))
        } else {
            None
        };
    let target = addr.clone().unwrap_or_else(|| {
        hosted.as_ref().unwrap().0.local_addr().to_string()
    });

    let mut c = Client::connect(&target[..]).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    c.ping().expect("ping");

    // discover the first hosted model and the admission bound
    let stats = c.stats("").expect("stats");
    let doc = Json::parse(&stats).expect("stats json");
    let entry = &doc.at("models").unwrap().as_arr().unwrap()[0];
    let model = entry.at("model").unwrap().as_str().unwrap().to_string();
    let n_in = entry.at("n_in").unwrap().as_usize().unwrap();
    let max_inflight = doc.at("server").unwrap().at("max_inflight")
        .unwrap().as_usize().unwrap();
    println!("driving model '{model}' (n_in {n_in}) on {target}; \
              admission bound {max_inflight} rows");

    // reproducible inputs: sweep valid codes without needing the model
    let in_bits_guess = 1usize; // codes 0/1 are valid for any in_bits
    let xs: Vec<i32> = (0..1024 * n_in)
        .map(|i| ((i * 7 + i / n_in) % (1 << in_bits_guess)) as i32)
        .collect();

    // depth sweep: strictly under the bound (must not shed — at
    // exactly the bound a shed can race the writer's release), then
    // past it (must shed explicitly)
    let mut depths: Vec<usize> = [1usize, 8, 32]
        .into_iter()
        .filter(|&d| d < max_inflight)
        .collect();
    let overload_depth = (max_inflight * 4).clamp(max_inflight + 8, 4096);
    depths.push(overload_depth);

    let mut table = Table::new(
        "TCP serving under load (single connection, pipelined)",
        &["depth", "requests", "ok", "shed", "req/s", "p50 us",
          "p99 us", "p999 us"],
    );
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for &depth in &depths {
        let r = run_stage(&mut c, &model, n_in, depth, per_stage, &xs);
        let s = r.lat.summary();
        table.row(&[
            r.depth.to_string(),
            r.requests.to_string(),
            r.ok.to_string(),
            r.shed.to_string(),
            format!("{:.0}", r.requests as f64 / r.secs),
            format!("{:.0}", s.p50),
            format!("{:.0}", s.p99),
            format!("{:.0}", s.p999),
        ]);
        let mut row = BTreeMap::new();
        row.insert("depth".into(), Json::Num(r.depth as f64));
        row.insert("requests".into(), Json::Num(r.requests as f64));
        row.insert("ok".into(), Json::Num(r.ok as f64));
        row.insert("shed".into(), Json::Num(r.shed as f64));
        row.insert("req_per_s".into(),
                   Json::Num(r.requests as f64 / r.secs));
        row.insert("mean_us".into(), Json::Num(s.mean));
        row.insert("p50_us".into(), Json::Num(s.p50));
        row.insert("p99_us".into(), Json::Num(s.p99));
        row.insert("p999_us".into(), Json::Num(s.p999));
        row.insert("overload".into(),
                   Json::Bool(r.depth > max_inflight));
        rows.push(Json::Obj(row));
        results.push(r);
    }
    table.print();

    // the contract the sweep must prove: no sheds under the bound,
    // explicit sheds past it, and every request answered either way
    for r in &results {
        assert_eq!(r.ok + r.shed, r.requests,
                   "depth {}: {} requests vanished", r.depth,
                   r.requests - r.ok - r.shed);
        if r.depth < max_inflight {
            assert_eq!(r.shed, 0,
                       "depth {} is under the bound yet shed {}",
                       r.depth, r.shed);
        }
    }
    let overload = results.last().unwrap();
    assert!(overload.shed > 0,
            "depth {} past the bound {} never shed — admission \
             control is not bounding the queue",
            overload.depth, max_inflight);
    println!("\noverload stage (depth {}): {} served, {} explicitly \
              shed — bounded admission holds",
             overload.depth, overload.ok, overload.shed);

    // final server-side stats ride along in the bench artifact
    let final_stats = c.stats("").expect("final stats");
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("serve".into()));
    root.insert("quick".into(), Json::Bool(quick));
    root.insert("addr".into(), Json::Str(target.clone()));
    root.insert("model".into(), Json::Str(model.clone()));
    root.insert("max_inflight".into(), Json::Num(max_inflight as f64));
    root.insert("requests_per_stage".into(),
                Json::Num(per_stage as f64));
    root.insert("stages".into(), Json::Arr(rows));
    root.insert("server_stats".into(),
                Json::parse(&final_stats).expect("final stats json"));
    let path = "BENCH_serve.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if let Some((net, nl)) = hosted {
        // self-host epilogue: spot-check the answers really came from
        // the model (the stages only checked delivery, not values)
        let x = random_inputs(12, &nl, 1);
        let y = c.infer("loadtest", 1, n_in, x.clone()).expect("infer");
        assert_eq!(y, nl.eval_one(&x).unwrap(), "served answer differs");
        drop(c);
        net.shutdown();
        println!("drained cleanly; {} connections served, {} requests \
                  shed overall", net.accepted_conns(), net.shed_total());
    }
}
