//! Deployment scenario: a production box serving *several* LUT networks
//! at once — network-intrusion detection and jet classification behind
//! one multi-model dynamic-batching inference server (the L3 request
//! path — pure table lookups, python nowhere in sight).  Each model
//! carries its own batching policy: the NID stream is latency-sensitive
//! (small batches, short waits) while the jet stream favors throughput
//! (large batches, longer waits).
//!
//!     cargo run --release --example nid_serve

use std::time::Duration;

use anyhow::Result;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, BatchPolicy, FlowOptions,
                            InferenceEngine, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::dataset::{self, GenOpts};
use neuralut::metrics;
use neuralut::netlist::{Netlist, OptLevel};
use neuralut::report::pct;
use neuralut::runtime::Runtime;

/// One trained model plus the request stream and accuracy labels that
/// drive it.
struct Workload {
    name: &'static str,
    netlist: Netlist,
    rows: Vec<Vec<i32>>,
    labels: Vec<i32>,
    /// binary threshold (NID) or None for argmax heads (jet)
    binary_thr: Option<i32>,
}

fn train(rt: &Runtime, meta: &Meta, name: &'static str, dense: usize,
         sparse: usize, gen: &GenOpts, n_req: usize) -> Result<Workload> {
    let opts = FlowOptions {
        config: name.into(),
        dense_steps: dense,
        sparse_steps: sparse,
        skip_scale: 1.0,
        seed: 7,
        gen: gen.clone(),
        emit_rtl: false,
        verify_bit_exact: false,
        opt_level: OptLevel::Full,
    };
    let r = run_flow(rt, meta, &opts)?;
    println!("trained {name} netlist: {} L-LUTs, accuracy {}",
             r.netlist.total_units(), pct(r.netlist_acc));
    {
        let mut sim = r.netlist.simulator();
        use neuralut::coordinator::check_conformance;
        check_conformance(&mut sim, &r.netlist, 7)?;
        println!("  {}", sim.describe());
    }
    let top = &meta.config(name)?.topology;
    let splits = dataset::generate(&top.dataset, top.beta_in, gen)?;
    let test = &splits.test;
    let rows: Vec<Vec<i32>> =
        (0..n_req).map(|i| test.row(i % test.n).to_vec()).collect();
    let labels: Vec<i32> = (0..n_req).map(|i| test.y[i % test.n]).collect();
    let binary_thr = if top.dataset == "nid" {
        Some((1 << (top.beta.last().unwrap() - 1)) as i32)
    } else {
        None
    };
    Ok(Workload { name, netlist: r.netlist, rows, labels, binary_thr })
}

fn main() -> Result<()> {
    let meta = Meta::load(Meta::default_dir())?;
    let rt = Runtime::new()?;
    let gen = GenOpts { n_train: 8000, n_test: 2000, ..Default::default() };
    let n_req = 4000usize;
    let nid = train(&rt, &meta, "nid", 300, 800, &gen, n_req)?;
    let jet = train(&rt, &meta, "jsc_cb", 200, 500, &gen, n_req)?;

    // sweep batching policies per model: the NID stream stays
    // latency-tuned while the jet stream trades wait for occupancy
    println!("\n{:<14} {:<26} {:>10} {:>9} {:>8} {:>8} {:>9} {:>8}",
             "model", "policy", "req/s", "occupancy", "mean us", "p99 us",
             "p999 us", "acc");
    for (round, (nid_pol, jet_pol, sim_threads)) in [
        (BatchPolicy { max_batch: 16,
                       max_wait: Duration::from_micros(100) },
         BatchPolicy { max_batch: 64,
                       max_wait: Duration::from_micros(200) },
         1usize),
        (BatchPolicy { max_batch: 16,
                       max_wait: Duration::from_micros(100) },
         BatchPolicy { max_batch: 256,
                       max_wait: Duration::from_micros(500) },
         1),
        (BatchPolicy { max_batch: 64,
                       max_wait: Duration::from_micros(200) },
         BatchPolicy { max_batch: 256,
                       max_wait: Duration::from_micros(500) },
         4),
    ]
    .into_iter()
    .enumerate()
    {
        let mut registry = ModelRegistry::new();
        registry
            .register_with(nid.name, nid.netlist.clone(), Some(nid_pol))
            .register_with(jet.name, jet.netlist.clone(), Some(jet_pol));
        // every served model is optimized at registration
        // (ServerConfig::opt_level, default O2)
        let server = InferenceServer::start(
            registry,
            ServerConfig { workers: 2, sim_threads,
                           opt_level: OptLevel::Full,
                           ..ServerConfig::default() },
        );
        if round == 0 {
            for name in [nid.name, jet.name] {
                println!("{name}: {}", server.opt_report(name)?.summary());
            }
        }
        // both models' clients hammer the shared router concurrently
        let nid_rows = nid.rows.clone();
        let jet_rows = jet.rows.clone();
        let t = std::time::Instant::now();
        let (outs_nid, outs_jet) = std::thread::scope(|s| {
            let h_nid = {
                let server = &server;
                s.spawn(move || server.infer_many(nid.name, nid_rows))
            };
            let h_jet = {
                let server = &server;
                s.spawn(move || server.infer_many(jet.name, jet_rows))
            };
            (h_nid.join().expect("nid client panicked"),
             h_jet.join().expect("jet client panicked"))
        });
        let secs = t.elapsed().as_secs_f64();
        let (outs_nid, outs_jet) = (outs_nid?, outs_jet?);
        for w in [&nid, &jet] {
            let outs = if w.binary_thr.is_some() { &outs_nid } else { &outs_jet };
            let preds: Vec<i32> = match w.binary_thr {
                Some(thr) => {
                    outs.iter().map(|row| (row[0] >= thr) as i32).collect()
                }
                None => metrics::argmax_rows(&outs.concat(),
                                             w.netlist.out_width()),
            };
            let acc = metrics::accuracy(&preds, &w.labels);
            let st = server.model_stats(w.name)?;
            let pol = if w.binary_thr.is_some() { nid_pol } else { jet_pol };
            println!(
                "{:<14} {:<26} {:>10.0} {:>9.1} {:>8.0} {:>8.0} {:>9.0} \
                 {:>8}",
                w.name,
                format!("batch<={} wait {}us x{}t", pol.max_batch,
                        pol.max_wait.as_micros(), sim_threads),
                st.requests as f64 / secs,
                st.mean_occupancy,
                st.latency.mean,
                st.latency.p99,
                st.latency.p999,
                pct(acc),
            );
        }
        server.shutdown();
    }
    Ok(())
}
