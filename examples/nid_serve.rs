//! Deployment scenario: network-intrusion detection behind the
//! dynamic-batching inference server (the L3 request path — pure table
//! lookups, python nowhere in sight).
//!
//!     cargo run --release --example nid_serve

use std::time::Duration;

use anyhow::Result;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions, InferenceServer, ServerConfig};
use neuralut::dataset::{self, GenOpts};
use neuralut::metrics;
use neuralut::report::pct;
use neuralut::runtime::Runtime;

fn main() -> Result<()> {
    let meta = Meta::load(Meta::default_dir())?;
    let rt = Runtime::new()?;
    let gen = GenOpts { n_train: 8000, n_test: 2000, ..Default::default() };
    let opts = FlowOptions {
        config: "nid".into(),
        dense_steps: 300,
        sparse_steps: 800,
        skip_scale: 1.0,
        seed: 7,
        gen: gen.clone(),
        emit_rtl: false,
        verify_bit_exact: false,
    };
    let r = run_flow(&rt, &meta, &opts)?;
    println!("trained NID netlist: {} L-LUTs, accuracy {}",
             r.netlist.total_units(), pct(r.netlist_acc));
    {
        let sim = r.netlist.simulator();
        println!("simulator kernels: {}/{} layers bit-plane",
                 sim.bitplane_layers(), r.netlist.layers.len());
    }

    // sweep batching policies: latency/throughput trade-off; the last
    // rows add intra-batch parallelism (sim_threads) on top of batching
    let top = &meta.config("nid")?.topology;
    let splits = dataset::generate(&top.dataset, top.beta_in, &gen)?;
    let test = &splits.test;
    println!("\n{:<32} {:>12} {:>12} {:>12} {:>10}",
             "policy", "req/s", "mean us", "p99 us", "acc");
    for (max_batch, wait_us, workers, sim_threads) in
        [(1usize, 0u64, 1usize, 1usize), (16, 100, 2, 1), (64, 200, 2, 1),
         (256, 500, 2, 1), (256, 500, 2, 4)]
    {
        let server = InferenceServer::start(
            r.netlist.clone(),
            ServerConfig {
                max_batch,
                max_wait: Duration::from_micros(wait_us),
                workers,
                sim_threads,
            },
        );
        let n_req = 4000usize;
        let rows: Vec<Vec<i32>> =
            (0..n_req).map(|i| test.row(i % test.n).to_vec()).collect();
        let t = std::time::Instant::now();
        let outs = server.infer_many(rows)?;
        let secs = t.elapsed().as_secs_f64();
        // accuracy of served answers
        let thr = (1 << (top.beta.last().unwrap() - 1)) as i32;
        let preds: Vec<i32> =
            outs.iter().map(|row| (row[0] >= thr) as i32).collect();
        let labels: Vec<i32> =
            (0..n_req).map(|i| test.y[i % test.n]).collect();
        let acc = metrics::accuracy(&preds, &labels);
        let (_, _, mean, p99) = server.stats();
        println!("{:<32} {:>12.0} {:>12.0} {:>12.0} {:>10}",
                 format!("batch<={max_batch} wait {wait_us}us x{sim_threads}t"),
                 n_req as f64 / secs, mean, p99, pct(acc));
        server.shutdown();
    }
    Ok(())
}
