//! Jet-substructure ablation walk-through (the paper's Fig. 2 + Fig. 5
//! story at example scale): assemble the same 16-input budget from
//! 4-input vs 2-input LUT trees, compare area and accuracy, and ablate
//! the learned mappings and tree-level skips on the deepest variant.
//!
//!     cargo run --release --example jsc_ablation

use anyhow::Result;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions};
use neuralut::dataset::GenOpts;
use neuralut::report::{pct, Table};
use neuralut::runtime::Runtime;

fn opts(config: &str, dense: usize, skip: f32) -> FlowOptions {
    FlowOptions {
        config: config.into(),
        dense_steps: dense,
        sparse_steps: 300,
        skip_scale: skip,
        seed: 11,
        gen: GenOpts { n_train: 5000, n_test: 1200, ..Default::default() },
        emit_rtl: false,
        verify_bit_exact: false,
        opt_level: neuralut::netlist::OptLevel::Full,
    }
}

fn main() -> Result<()> {
    let meta = Meta::load(Meta::default_dir())?;
    let rt = Runtime::new()?;
    let mut table = Table::new(
        "JSC tree-assembly ablation",
        &["architecture", "variant", "P-LUTs", "netlist acc"],
    );

    for (config, label) in [
        ("fig5_opt1", "16-input tree of 4-LUTs (depth 2)"),
        ("fig5_opt2", "16-input tree of 2-LUTs (depth 4)"),
        ("fig5_opt3", "64-input tree of 2-LUTs (depth 6)"),
    ] {
        let r = run_flow(&rt, &meta, &opts(config, 40, 1.0))?;
        table.row(&[
            label.into(),
            "complete".into(),
            r.mapped.total_luts().to_string(),
            pct(r.netlist_acc),
        ]);
    }

    // ablations on the deepest tree, where the paper says they matter most
    for (variant, dense, skip) in [("w/o learned mappings", 0usize, 1.0f32),
                                   ("w/o tree-level skips", 40, 0.0)] {
        let r = run_flow(&rt, &meta, &opts("fig5_opt3", dense, skip))?;
        table.row(&[
            "64-input tree of 2-LUTs (depth 6)".into(),
            variant.into(),
            r.mapped.total_luts().to_string(),
            pct(r.netlist_acc),
        ]);
    }
    table.print();
    println!(
        "\nexpected shape (paper Fig. 5): 2-LUT trees much smaller than \
         4-LUT trees at similar accuracy; removing learned mappings or \
         skips costs accuracy, more so at depth 6."
    );
    Ok(())
}
