//! End-to-end validation driver (the repo's required e2e example):
//! the complete MNIST toolflow on the synthetic digit corpus, with the
//! loss curve logged, both MNIST variants (+aug / -aug) like the paper's
//! Table II/IV rows, bit-exactness proven on the whole test set, and the
//! Table-IV-style hardware row printed for each variant.
//!
//!     cargo run --release --example mnist_e2e
//!
//! Results are recorded in EXPERIMENTS.md.

use anyhow::Result;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions};
use neuralut::dataset::GenOpts;
use neuralut::report::{pct, sci, Table};
use neuralut::runtime::Runtime;

fn main() -> Result<()> {
    let meta = Meta::load(Meta::default_dir())?;
    let rt = Runtime::new()?;
    let full = std::env::var("NLA_FULL").is_ok();
    let scale = if full { 4 } else { 1 };

    let mut table = Table::new(
        "MNIST end-to-end (synthetic digits)",
        &["variant", "QAT acc", "netlist acc", "bit-exact", "P-LUTs",
          "FFs", "Fmax", "latency", "ADP"],
    );

    for augment in [true, false] {
        let opts = FlowOptions {
            config: "mnist".into(),
            dense_steps: 25 * scale,
            sparse_steps: 300 * scale,
            skip_scale: 1.0,
            seed: 7,
            gen: GenOpts {
                n_train: 6000 * scale,
                n_test: 1500 * scale,
                augment,
                ..Default::default()
            },
            emit_rtl: false,
            verify_bit_exact: true,
            opt_level: neuralut::netlist::OptLevel::Full,
        };
        let t0 = std::time::Instant::now();
        let r = run_flow(&rt, &meta, &opts)?;
        // loss curve (the e2e training signal): print a decimated trace
        let n = r.losses.len();
        let stride = (n / 12).max(1);
        println!("\nloss curve ({}):",
                 if augment { "mnist +aug" } else { "mnist -aug" });
        for (i, chunk) in r.losses.chunks(stride).enumerate() {
            let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
            println!("  step {:>5}: loss {:.4}", i * stride, mean);
        }
        let first: f32 = r.losses[..stride].iter().sum::<f32>() / stride as f32;
        let last: f32 = r.losses[n - stride..].iter().sum::<f32>() / stride as f32;
        assert!(
            last < first,
            "training must reduce the loss ({first:.3} -> {last:.3})"
        );
        let p3 = &r.reports[1].1;
        table.row(&[
            if augment { "+aug" } else { "-aug" }.into(),
            pct(r.qat_acc),
            pct(r.netlist_acc),
            format!("{:?}", r.bit_exact),
            p3.luts.to_string(),
            p3.ffs.to_string(),
            format!("{:.0} MHz", p3.fmax_mhz),
            format!("{:.2} ns", p3.latency_ns),
            sci(p3.area_delay),
        ]);
        println!("variant done in {:.0}s", t0.elapsed().as_secs_f64());
        assert_eq!(r.bit_exact, Some(true));
    }
    table.print();
    println!(
        "\npaper's MNIST rows for comparison: +aug 98.6% / 5037 LUTs / \
         849 MHz / 2.2 ns / 1.11e4; -aug 97.9% / 5070 LUTs / 863 MHz / \
         2.1 ns / 1.06e4 (real MNIST + Vivado; ours is a synthetic-corpus, \
         model-estimated reproduction of the same flow)."
    );
    Ok(())
}
