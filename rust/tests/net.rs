//! TCP serving-frontend battery: the committed cross-language golden
//! frames, wire-level corruption over a live socket (truncations,
//! flipped bytes, hostile length prefixes, mid-frame disconnects —
//! typed errors or clean closes, never a panic or a hang), admission
//! control under flood (explicit sheds, counted in stats), and
//! graceful drain (in-flight responses flush, new work is refused).
//!
//! The python twin of the golden-frame test is
//! `python/tests/test_wire.py`; regenerate the goldens with
//! `python -m tests.golden_wire` from `python/`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use neuralut::coordinator::{InferenceServer, ModelRegistry, ServerConfig};
use neuralut::net::wire::{self, Frame, Message};
use neuralut::net::{Client, InferError, NetConfig, NetServer, NetSession,
                    Session, INPUT_X, OUTPUT_Y};
use neuralut::netlist::testutil::{random_inputs, random_netlist};
use neuralut::netlist::Netlist;
use neuralut::util::Json;

/// The committed golden frames — keep in lockstep with
/// `python/tests/golden_wire.py::golden_frames`.
fn golden_frames() -> Vec<(u64, Message)> {
    vec![
        (1, Message::Ping),
        (2, Message::Pong),
        (0x0123_4567_89AB_CDEF,
         Message::Infer { model: "nid".into(), batch: 2, n_in: 3,
                          codes: vec![0, 1, -2, 3, 2, 1] }),
        (4, Message::Infer {
            model: "golden_mix".into(), batch: 4, n_in: 5,
            codes: (0..20).map(|i| (i * 7) % 19 - 9).collect(),
        }),
        (7, Message::Result { batch: 2, out_width: 1,
                              codes: vec![1, -3] }),
        (8, Message::Error { code: wire::ERR_OVERLOADED,
                             message: "shed".into() }),
        (9, Message::Stats { model: String::new() }),
        (10, Message::Stats { model: "jsc".into() }),
        (11, Message::StatsResult { json: "{\"x\":1}".into() }),
        (12, Message::Result { batch: 3, out_width: 0, codes: vec![] }),
    ]
}

#[test]
fn golden_wire_frames_decode_and_reencode() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/rust/tests/golden/golden_frames.bin");
    let bytes = std::fs::read(path)
        .expect("tests/golden/golden_frames.bin is committed");
    let mut offset = 0;
    for (id, msg) in golden_frames() {
        let (frame, used) = wire::decode_frame(&bytes[offset..])
            .unwrap_or_else(|e| panic!("frame id {id}: {e}"));
        assert_eq!(frame.id, id);
        assert_eq!(frame.msg, msg);
        // canonical: the rust encoder reproduces the python bytes
        assert_eq!(wire::encode_frame(id, &msg),
                   &bytes[offset..offset + used], "frame id {id}");
        offset += used;
    }
    assert_eq!(offset, bytes.len(), "trailing bytes in the golden file");
}

/// A small served model plus its reference netlist.
fn serve(seed: u64, cfg: NetConfig) -> (NetServer, Netlist) {
    let nl = random_netlist(seed, 6, 1, &[(5, 2, 2), (3, 2, 2)]);
    let mut registry = ModelRegistry::new();
    registry.register("m", nl.clone());
    let server = InferenceServer::start(
        registry,
        ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100),
                       workers: 2, ..ServerConfig::default() },
    );
    let net = NetServer::bind(server, "127.0.0.1:0", cfg)
        .expect("bind loopback");
    (net, nl)
}

#[test]
fn tcp_infer_is_bit_exact_and_stats_count_it() {
    let (net, nl) = serve(201, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.ping().unwrap();
    let batch = 17;
    let x = random_inputs(201, &nl, batch);
    let y = c.infer("m", batch, 6, x.clone()).unwrap();
    let ow = nl.out_width();
    assert_eq!(y.len(), batch * ow);
    for b in 0..batch {
        let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
        assert_eq!(&y[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
    let doc = Json::parse(&c.stats("m").unwrap()).unwrap();
    let models = doc.at("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    let m = &models[0];
    assert_eq!(m.at("model").unwrap().as_str().unwrap(), "m");
    assert_eq!(m.at("n_in").unwrap().as_usize().unwrap(), 6);
    assert_eq!(m.at("out_width").unwrap().as_usize().unwrap(), ow);
    // max_batch 8 stays under the auto threshold: scalar backend,
    // reported per model over the wire
    assert_eq!(m.at("backend").unwrap().as_str().unwrap(), "plan-w1");
    assert_eq!(m.at("lane_width").unwrap().as_usize().unwrap(), 1);
    let netc = m.at("net").unwrap();
    assert_eq!(netc.at("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(netc.at("rows").unwrap().as_usize().unwrap(), batch);
    assert_eq!(netc.at("shed").unwrap().as_usize().unwrap(), 0);
    // the batcher saw every row
    assert_eq!(m.at("requests").unwrap().as_usize().unwrap(), batch);
    // plan-cache telemetry rides along under stable keys: this server
    // compiled its one model in-process (no persistent cache, no
    // identical sibling registration)
    let pc = doc.at("server").unwrap().at("plan_cache").unwrap();
    assert_eq!(pc.at("compiles").unwrap().as_usize().unwrap(), 1);
    assert_eq!(pc.at("memory_hits").unwrap().as_usize().unwrap(), 0);
    assert_eq!(pc.at("disk_hits").unwrap().as_usize().unwrap(), 0);
    net.shutdown();
}

#[test]
fn tcp_rejections_are_typed_values_and_connection_survives() {
    let (net, _nl) = serve(202, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    // unknown model
    match c.infer("ghost", 1, 6, vec![0; 6]) {
        Err(InferError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // wrong declared width
    match c.infer("m", 1, 5, vec![0; 5]) {
        Err(InferError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    // zero batch
    match c.infer("m", 0, 6, vec![]) {
        Err(InferError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    // stats for an unknown model
    match c.stats("ghost") {
        Err(InferError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // the connection answered four rejections and still works
    c.ping().unwrap();
    let y = c.infer("m", 1, 6, vec![0; 6]).unwrap();
    assert!(!y.is_empty());
    net.shutdown();
}

#[test]
fn corrupt_frames_get_typed_errors_recoverable_keeps_connection() {
    let (net, nl) = serve(203, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // flip one body byte: checksum catches it, server answers with an
    // id-0 BAD_FRAME error and the connection stays in sync
    let x = random_inputs(203, &nl, 1);
    let good = wire::encode_frame(77, &Message::Infer {
        model: "m".into(), batch: 1, n_in: 6, codes: x.clone(),
    });
    let mut evil = good.clone();
    let last = evil.len() - 1;
    evil[last] ^= 0x20;
    // write the corrupt frame through a raw socket
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.set_nodelay(true).unwrap();
    raw.write_all(&evil).unwrap();
    let frame = read_one(&mut raw);
    match frame.msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
            assert_eq!(frame.id, 0, "corrupt ids must not be echoed");
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // same connection, valid frame: still served
    raw.write_all(&good).unwrap();
    let frame = read_one(&mut raw);
    match frame.msg {
        Message::Result { codes, .. } => {
            assert_eq!(codes, nl.eval_one(&x).unwrap());
            assert_eq!(frame.id, 77);
        }
        other => panic!("expected result frame, got {other:?}"),
    }

    // unknown kind: recoverable too
    let mut unk = wire::encode_frame(5, &Message::Ping);
    unk[6] = 0xEE;
    raw.write_all(&unk).unwrap();
    match read_one(&mut raw).msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // a response-kind frame from a client is answered, not fatal
    raw.write_all(&wire::encode_frame(6, &Message::Pong)).unwrap();
    match read_one(&mut raw).msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    drop(raw);
    c.ping().unwrap();
    net.shutdown();
}

#[test]
fn fatal_corruption_answers_then_closes_cleanly() {
    let (net, _nl) = serve(204, NetConfig::default());
    // exactly one header each, so the server closes with nothing
    // unread (an unread byte would turn the close into a reset and
    // could discard the error frame in flight)
    for evil in [
        // bad magic: answered best-effort, then closed
        vec![b'X'; wire::HEADER_LEN],
        // hostile length prefix (4 GiB body): rejected before any
        // allocation, answered, closed
        {
            let mut b = wire::encode_frame(9, &Message::Ping);
            b[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
            b
        },
        // wrong version: answered, closed
        {
            let mut b = wire::encode_frame(9, &Message::Ping);
            b[4] = 0x42;
            b
        },
    ] {
        let mut raw = TcpStream::connect(net.local_addr()).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        raw.write_all(&evil).unwrap();
        let frame = read_one(&mut raw);
        match frame.msg {
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_BAD_FRAME);
            }
            other => panic!("expected error frame, got {other:?}"),
        }
        // ... and the server closes: the next read hits EOF, it does
        // not hang
        let mut rest = Vec::new();
        raw.read_to_end(&mut rest).expect("clean close, not a hang");
        assert!(rest.is_empty(), "unexpected bytes after the error");
    }
    // the server survived three hostile connections
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.ping().unwrap();
    net.shutdown();
}

#[test]
fn mid_frame_disconnect_does_not_wedge_the_server() {
    let (net, nl) = serve(205, NetConfig::default());
    // half a header
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(b"NLWP\x01\x00").unwrap();
    drop(raw);
    // a full header promising a body that never comes
    let full = wire::encode_frame(3, &Message::Infer {
        model: "m".into(), batch: 1, n_in: 6, codes: vec![0; 6],
    });
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(&full[..wire::HEADER_LEN + 3]).unwrap();
    drop(raw);
    // the server is still fully alive
    let mut c = Client::connect(net.local_addr()).unwrap();
    let x = random_inputs(205, &nl, 2);
    let y = c.infer("m", 2, 6, x.clone()).unwrap();
    let ow = nl.out_width();
    for b in 0..2 {
        assert_eq!(&y[b * ow..(b + 1) * ow],
                   &nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap()[..]);
    }
    net.shutdown();
}

#[test]
fn overload_sheds_explicitly_and_counts_in_stats() {
    // admission bound of 1 row: pipelined single-row requests race the
    // writer, so a flood must shed; a batch wider than the bound is
    // shed deterministically even when idle
    let (net, nl) = serve(206, NetConfig {
        max_inflight: 1,
        ..NetConfig::default()
    });
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // deterministic: batch 4 > bound 1 is always OVERLOADED
    match c.infer("m", 4, 6, random_inputs(206, &nl, 4)) {
        Err(InferError::Overloaded) => {}
        other => panic!("expected Overloaded, got {other:?}"),
    }

    // flood: pipeline many single-row requests without reading
    let flood = 400usize;
    let x = random_inputs(207, &nl, flood);
    let mut ids = Vec::with_capacity(flood);
    for i in 0..flood {
        let row = x[i * 6..(i + 1) * 6].to_vec();
        ids.push(c.send_infer("m", 1, 6, row).unwrap());
    }
    let mut ok = 0usize;
    let mut shed = 0usize;
    let ow = nl.out_width();
    for (i, id) in ids.into_iter().enumerate() {
        let frame = c.recv_frame().unwrap();
        assert_eq!(frame.id, id, "responses arrive in request order");
        match frame.msg {
            Message::Result { codes, .. } => {
                let want =
                    nl.eval_one(&x[i * 6..(i + 1) * 6]).unwrap();
                assert_eq!(codes[..ow], want[..], "row {i}");
                ok += 1;
            }
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_OVERLOADED,
                           "only sheds may fail under flood");
                shed += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(ok + shed, flood);
    assert!(ok > 0, "nothing was served under flood");
    assert!(shed > 0, "a 1-row bound never shed under a 400-deep flood");
    // every shed (incl. the deterministic batch-4 one) is counted
    let stats = c.stats("").expect("stats stay queryable after overload");
    let doc = Json::parse(&stats).unwrap();
    let m = &doc.at("models").unwrap().as_arr().unwrap()[0];
    let counted =
        m.at("net").unwrap().at("shed").unwrap().as_usize().unwrap();
    assert_eq!(counted, shed + 1, "stats shed count disagrees");
    let srv = doc.at("server").unwrap();
    assert_eq!(srv.at("shed_total").unwrap().as_usize().unwrap(),
               shed + 1);
    assert_eq!(srv.at("max_inflight").unwrap().as_usize().unwrap(), 1);
    net.shutdown();
}

#[test]
fn graceful_drain_flushes_inflight_then_refuses_new_connections() {
    let (net, nl) = serve(208, NetConfig::default());
    let addr = net.local_addr();
    let mut c = Client::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // put work in flight, then drain while it is pending
    let k = 64usize;
    let x = random_inputs(208, &nl, k);
    let mut ids = Vec::new();
    for i in 0..k {
        ids.push(c.send_infer("m", 1, 6,
                              x[i * 6..(i + 1) * 6].to_vec()).unwrap());
    }
    // let admissions land so the drain has real in-flight work
    std::thread::sleep(Duration::from_millis(50));
    let t = Instant::now();
    net.shutdown();
    assert!(t.elapsed() < Duration::from_secs(10), "drain hung");
    // every in-flight request got an answer: a bit-exact result or a
    // typed shutting-down error, never silence
    let ow = nl.out_width();
    let mut answered = 0usize;
    for (i, id) in ids.into_iter().enumerate() {
        let frame = c.recv_frame().unwrap_or_else(|e| {
            panic!("request {i} got no answer before close: {e}")
        });
        assert_eq!(frame.id, id);
        match frame.msg {
            Message::Result { codes, .. } => {
                let want = nl.eval_one(&x[i * 6..(i + 1) * 6]).unwrap();
                assert_eq!(codes[..ow], want[..], "row {i}");
                answered += 1;
            }
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_SHUTTING_DOWN);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(answered > 0, "drain answered nothing");
    // new connections are refused (or immediately closed) after drain
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(mut s) => {
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            s.write_all(&wire::encode_frame(1, &Message::Ping)).ok();
            let mut buf = Vec::new();
            // a drained server never answers; EOF or reset, not a hang
            assert!(matches!(s.read_to_end(&mut buf), Ok(0) | Err(_)),
                    "drained server still answering");
        }
    }
    // shutdown is idempotent
    net.shutdown();
}

#[test]
fn net_session_speaks_the_session_api_over_tcp() {
    let (net, nl) = serve(209, NetConfig::default());
    let mut s = NetSession::open(net.local_addr(), "m").unwrap();
    assert_eq!(s.input_names(), [INPUT_X.to_string()]);
    assert_eq!(s.output_names(), [OUTPUT_Y.to_string()]);
    let x = random_inputs(209, &nl, 9);
    let out = s.run(&[(INPUT_X, &x[..])]).unwrap();
    let y = &out[OUTPUT_Y];
    let ow = nl.out_width();
    for b in 0..9 {
        let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
        assert_eq!(&y[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
    // bad inputs are values here exactly as in-process
    assert!(matches!(s.run(&[("z", &x[..])]),
                     Err(InferError::BadInput(_))));
    assert!(matches!(s.run(&[(INPUT_X, &x[..5])]),
                     Err(InferError::BadInput(_))));
    net.shutdown();
}

/// Read one frame off a raw socket (test helper).
fn read_one(s: &mut TcpStream) -> Frame {
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wire::read_frame(s).expect("a frame")
}
