//! TCP serving-frontend battery: the committed cross-language golden
//! frames (v2 and the frozen v1 stream), wire-level corruption over a
//! live socket (truncations, flipped bytes, hostile length prefixes,
//! forged deadline fields, mid-frame disconnects — typed errors or
//! clean closes, never a panic or a hang), admission control under
//! flood (global bound, per-connection quotas, deadline shedding —
//! explicit sheds, counted in stats), graceful drain (bounded even
//! when the write path is wedged), the retrying client (server coming
//! up late, scripted connection drops), and a deterministic chaos
//! battery (`chaos_*`, seeded via `NLA_CHAOS_SEED`, default 1) that
//! proves the failure story under injected faults: typed errors or
//! successful retries, at-most-once answers per request id, bit-exact
//! conformance through a 1 %-fault plan, bounded drain.
//!
//! The python twin of the golden-frame tests is
//! `python/tests/test_wire.py`; regenerate the goldens with
//! `python -m tests.golden_wire` from the repo root.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use neuralut::coordinator::{check_conformance, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::net::fault::{Dir, Fault, FaultPlan};
use neuralut::net::wire::{self, Frame, Message};
use neuralut::net::{Client, ClientConfig, InferError, NetConfig,
                    NetServer, NetSession, RemoteEngine, RetryClient,
                    RetryPolicy, Session, INPUT_X, OUTPUT_Y};
use neuralut::netlist::testutil::{random_inputs, random_netlist};
use neuralut::netlist::Netlist;
use neuralut::util::Json;

/// Seed for the `chaos_*` tests — override with `NLA_CHAOS_SEED=n` to
/// sweep fault schedules (CI runs several).
fn chaos_seed() -> u64 {
    std::env::var("NLA_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// The committed v2 golden frames — keep in lockstep with
/// `python/tests/golden_wire.py::golden_frames`.
fn golden_frames() -> Vec<(u64, Message)> {
    vec![
        (1, Message::Ping),
        (2, Message::Pong),
        (0x0123_4567_89AB_CDEF,
         Message::Infer { model: "nid".into(), batch: 2, n_in: 3,
                          deadline_us: None,
                          codes: vec![0, 1, -2, 3, 2, 1] }),
        (4, Message::Infer {
            model: "golden_mix".into(), batch: 4, n_in: 5,
            deadline_us: None,
            codes: (0..20).map(|i| (i * 7) % 19 - 9).collect(),
        }),
        // v2: a request carrying a 250 ms deadline budget
        (6, Message::Infer { model: "dl".into(), batch: 1, n_in: 4,
                             deadline_us: Some(250_000),
                             codes: vec![1, 2, 3, 4] }),
        (7, Message::Result { batch: 2, out_width: 1,
                              codes: vec![1, -3] }),
        (8, Message::Error { code: wire::ERR_OVERLOADED,
                             message: "shed".into() }),
        (9, Message::Stats { model: String::new() }),
        (10, Message::Stats { model: "jsc".into() }),
        (11, Message::StatsResult { json: "{\"x\":1}".into() }),
        (12, Message::Result { batch: 3, out_width: 0, codes: vec![] }),
        // v2 error codes
        (13, Message::Error { code: wire::ERR_DEADLINE,
                              message: "late".into() }),
        (14, Message::Error { code: wire::ERR_CONN_QUOTA,
                              message: "greedy".into() }),
    ]
}

/// The frozen v1 golden list (`golden_wire.py::golden_frames_v1`) —
/// the original wire-v1 stream, pinned forever.
fn golden_frames_v1() -> Vec<(u64, Message)> {
    vec![
        (1, Message::Ping),
        (2, Message::Pong),
        (0x0123_4567_89AB_CDEF,
         Message::Infer { model: "nid".into(), batch: 2, n_in: 3,
                          deadline_us: None,
                          codes: vec![0, 1, -2, 3, 2, 1] }),
        (4, Message::Infer {
            model: "golden_mix".into(), batch: 4, n_in: 5,
            deadline_us: None,
            codes: (0..20).map(|i| (i * 7) % 19 - 9).collect(),
        }),
        (7, Message::Result { batch: 2, out_width: 1,
                              codes: vec![1, -3] }),
        (8, Message::Error { code: wire::ERR_OVERLOADED,
                             message: "shed".into() }),
        (9, Message::Stats { model: String::new() }),
        (10, Message::Stats { model: "jsc".into() }),
        (11, Message::StatsResult { json: "{\"x\":1}".into() }),
        (12, Message::Result { batch: 3, out_width: 0, codes: vec![] }),
    ]
}

#[test]
fn golden_wire_frames_decode_and_reencode() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/rust/tests/golden/golden_frames.bin");
    let bytes = std::fs::read(path)
        .expect("tests/golden/golden_frames.bin is committed");
    let mut offset = 0;
    for (id, msg) in golden_frames() {
        let (frame, used) = wire::decode_frame(&bytes[offset..])
            .unwrap_or_else(|e| panic!("frame id {id}: {e}"));
        assert_eq!(frame.id, id);
        assert_eq!(frame.msg, msg);
        // canonical: the rust encoder reproduces the python bytes
        assert_eq!(wire::encode_frame(id, &msg),
                   &bytes[offset..offset + used], "frame id {id}");
        offset += used;
    }
    assert_eq!(offset, bytes.len(), "trailing bytes in the golden file");
}

#[test]
fn golden_v1_frames_decode_with_v2_reader_and_reencode_at_v1() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"),
                       "/rust/tests/golden/golden_frames_v1.bin");
    let bytes = std::fs::read(path)
        .expect("tests/golden/golden_frames_v1.bin is committed");
    let mut offset = 0;
    for (id, msg) in golden_frames_v1() {
        let (frame, used) = wire::decode_frame(&bytes[offset..])
            .unwrap_or_else(|e| panic!("v1 frame id {id}: {e}"));
        assert_eq!(frame.id, id);
        assert_eq!(frame.msg, msg, "v1 decodes to the same message \
                                    (deadline: none)");
        if let Message::Infer { deadline_us, .. } = &frame.msg {
            assert_eq!(*deadline_us, None, "v1 frames carry no deadline");
        }
        // canonical per version: the v1 encoder reproduces the bytes
        assert_eq!(wire::encode_frame_versioned(id, &msg, 1),
                   &bytes[offset..offset + used], "v1 frame id {id}");
        offset += used;
    }
    assert_eq!(offset, bytes.len(), "trailing bytes in the v1 golden");
}

/// A small served model plus its reference netlist.
fn serve(seed: u64, cfg: NetConfig) -> (NetServer, Netlist) {
    let nl = random_netlist(seed, 6, 1, &[(5, 2, 2), (3, 2, 2)]);
    let mut registry = ModelRegistry::new();
    registry.register("m", nl.clone());
    let server = InferenceServer::start(
        registry,
        ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100),
                       workers: 2, ..ServerConfig::default() },
    );
    let net = NetServer::bind(server, "127.0.0.1:0", cfg)
        .expect("bind loopback");
    (net, nl)
}

#[test]
fn tcp_infer_is_bit_exact_and_stats_count_it() {
    let (net, nl) = serve(201, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.ping().unwrap();
    let batch = 17;
    let x = random_inputs(201, &nl, batch);
    let y = c.infer("m", batch, 6, x.clone()).unwrap();
    let ow = nl.out_width();
    assert_eq!(y.len(), batch * ow);
    for b in 0..batch {
        let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
        assert_eq!(&y[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
    let doc = Json::parse(&c.stats("m").unwrap()).unwrap();
    let models = doc.at("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    let m = &models[0];
    assert_eq!(m.at("model").unwrap().as_str().unwrap(), "m");
    assert_eq!(m.at("n_in").unwrap().as_usize().unwrap(), 6);
    assert_eq!(m.at("out_width").unwrap().as_usize().unwrap(), ow);
    // max_batch 8 stays under the auto threshold: scalar backend,
    // reported per model over the wire
    assert_eq!(m.at("backend").unwrap().as_str().unwrap(), "plan-w1");
    assert_eq!(m.at("lane_width").unwrap().as_usize().unwrap(), 1);
    let netc = m.at("net").unwrap();
    assert_eq!(netc.at("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(netc.at("rows").unwrap().as_usize().unwrap(), batch);
    assert_eq!(netc.at("shed").unwrap().as_usize().unwrap(), 0);
    assert_eq!(netc.at("deadline_sheds").unwrap().as_usize().unwrap(), 0);
    assert_eq!(netc.at("quota_sheds").unwrap().as_usize().unwrap(), 0);
    // the batcher saw every row
    assert_eq!(m.at("requests").unwrap().as_usize().unwrap(), batch);
    let srv = doc.at("server").unwrap();
    // default per-connection quota: a quarter of the global bound
    assert_eq!(srv.at("max_inflight_per_conn").unwrap().as_usize()
                  .unwrap(),
               NetConfig::default().max_inflight / 4);
    assert_eq!(srv.at("deadline_sheds").unwrap().as_usize().unwrap(), 0);
    assert_eq!(srv.at("quota_sheds").unwrap().as_usize().unwrap(), 0);
    // this connection shows up in the live per-connection table
    let conns = srv.at("connections").unwrap().as_arr().unwrap();
    assert_eq!(conns.len(), 1);
    assert_eq!(conns[0].at("requests").unwrap().as_usize().unwrap(), 1);
    assert_eq!(conns[0].at("quota_sheds").unwrap().as_usize().unwrap(),
               0);
    // plan-cache telemetry rides along under stable keys: this server
    // compiled its one model in-process (no persistent cache, no
    // identical sibling registration)
    let pc = srv.at("plan_cache").unwrap();
    assert_eq!(pc.at("compiles").unwrap().as_usize().unwrap(), 1);
    assert_eq!(pc.at("memory_hits").unwrap().as_usize().unwrap(), 0);
    assert_eq!(pc.at("disk_hits").unwrap().as_usize().unwrap(), 0);
    net.shutdown();
}

#[test]
fn tcp_rejections_are_typed_values_and_connection_survives() {
    let (net, _nl) = serve(202, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    // unknown model
    match c.infer("ghost", 1, 6, vec![0; 6]) {
        Err(InferError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // wrong declared width
    match c.infer("m", 1, 5, vec![0; 5]) {
        Err(InferError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    // zero batch
    match c.infer("m", 0, 6, vec![]) {
        Err(InferError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    // stats for an unknown model
    match c.stats("ghost") {
        Err(InferError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // the connection answered four rejections and still works
    c.ping().unwrap();
    let y = c.infer("m", 1, 6, vec![0; 6]).unwrap();
    assert!(!y.is_empty());
    net.shutdown();
}

#[test]
fn corrupt_frames_get_typed_errors_recoverable_keeps_connection() {
    let (net, nl) = serve(203, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // flip one body byte: checksum catches it, server answers with an
    // id-0 BAD_FRAME error and the connection stays in sync
    let x = random_inputs(203, &nl, 1);
    let good = wire::encode_frame(77, &Message::Infer {
        model: "m".into(), batch: 1, n_in: 6, deadline_us: None,
        codes: x.clone(),
    });
    let mut evil = good.clone();
    let last = evil.len() - 1;
    evil[last] ^= 0x20;
    // write the corrupt frame through a raw socket
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.set_nodelay(true).unwrap();
    raw.write_all(&evil).unwrap();
    let frame = read_one(&mut raw);
    match frame.msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
            assert_eq!(frame.id, 0, "corrupt ids must not be echoed");
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // same connection, valid frame: still served
    raw.write_all(&good).unwrap();
    let frame = read_one(&mut raw);
    match frame.msg {
        Message::Result { codes, .. } => {
            assert_eq!(codes, nl.eval_one(&x).unwrap());
            assert_eq!(frame.id, 77);
        }
        other => panic!("expected result frame, got {other:?}"),
    }

    // unknown kind: recoverable too
    let mut unk = wire::encode_frame(5, &Message::Ping);
    unk[6] = 0xEE;
    raw.write_all(&unk).unwrap();
    match read_one(&mut raw).msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // a response-kind frame from a client is answered, not fatal
    raw.write_all(&wire::encode_frame(6, &Message::Pong)).unwrap();
    match read_one(&mut raw).msg {
        Message::Error { code, .. } => {
            assert_eq!(code, wire::ERR_BAD_FRAME);
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    drop(raw);
    c.ping().unwrap();
    net.shutdown();
}

/// Rewrite the raw deadline field of an encoded v2 INFER frame and fix
/// the checksum — forged frames whose checksum is valid but whose
/// deadline is semantically hostile.
fn with_raw_deadline(frame: &[u8], model_len: usize, raw: u64) -> Vec<u8> {
    let off = wire::HEADER_LEN + 2 + model_len + 4 + 4;
    let mut b = frame.to_vec();
    b[off..off + 8].copy_from_slice(&raw.to_le_bytes());
    let sum = wire::body_checksum(&b[wire::HEADER_LEN..]);
    b[20..24].copy_from_slice(&sum.to_le_bytes());
    b
}

#[test]
fn forged_deadline_fields_get_bad_frame_and_connection_survives() {
    let (net, nl) = serve(210, NetConfig::default());
    let x = random_inputs(210, &nl, 1);
    let good = wire::encode_frame(31, &Message::Infer {
        model: "m".into(), batch: 1, n_in: 6,
        deadline_us: Some(10_000_000), codes: x.clone(),
    });
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.set_nodelay(true).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // a zero budget and an over-cap budget are both recoverable
    // BAD_FRAME rejections, not sheds and not connection kills
    for forged in [0u64, wire::MAX_DEADLINE_US + 1] {
        raw.write_all(&with_raw_deadline(&good, 1, forged)).unwrap();
        let frame = read_one(&mut raw);
        match frame.msg {
            Message::Error { code, message } => {
                assert_eq!(code, wire::ERR_BAD_FRAME, "deadline {forged}");
                assert!(message.contains("deadline"), "{message}");
                assert_eq!(frame.id, 0);
            }
            other => panic!("expected error frame, got {other:?}"),
        }
    }
    // same connection: a roomy genuine deadline is served bit-exactly
    raw.write_all(&good).unwrap();
    match read_one(&mut raw).msg {
        Message::Result { codes, .. } => {
            assert_eq!(codes, nl.eval_one(&x).unwrap());
        }
        other => panic!("expected result frame, got {other:?}"),
    }
    // neither forged frame counted as a deadline shed
    assert_eq!(net.deadline_sheds_total(), 0);
    net.shutdown();
}

#[test]
fn v1_client_gets_full_service_from_a_v2_server() {
    let (net, nl) = serve(211, NetConfig::default());
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.set_nodelay(true).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // a pure wire-v1 peer: ping, infer, stats — all served
    raw.write_all(&wire::encode_frame_versioned(1, &Message::Ping, 1))
        .unwrap();
    assert!(matches!(read_one(&mut raw).msg, Message::Pong));
    let x = random_inputs(211, &nl, 3);
    raw.write_all(&wire::encode_frame_versioned(
        2,
        &Message::Infer { model: "m".into(), batch: 3, n_in: 6,
                          deadline_us: None, codes: x.clone() },
        1)).unwrap();
    let frame = read_one(&mut raw);
    assert_eq!(frame.id, 2);
    match frame.msg {
        Message::Result { codes, .. } => {
            let ow = nl.out_width();
            for b in 0..3 {
                let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
                assert_eq!(&codes[b * ow..(b + 1) * ow], &want[..]);
            }
        }
        other => panic!("expected result, got {other:?}"),
    }
    raw.write_all(&wire::encode_frame_versioned(
        3, &Message::Stats { model: "m".into() }, 1)).unwrap();
    assert!(matches!(read_one(&mut raw).msg,
                     Message::StatsResult { .. }));
    net.shutdown();
}

#[test]
fn fatal_corruption_answers_then_closes_cleanly() {
    let (net, _nl) = serve(204, NetConfig::default());
    // exactly one header each, so the server closes with nothing
    // unread (an unread byte would turn the close into a reset and
    // could discard the error frame in flight)
    for evil in [
        // bad magic: answered best-effort, then closed
        vec![b'X'; wire::HEADER_LEN],
        // hostile length prefix (4 GiB body): rejected before any
        // allocation, answered, closed
        {
            let mut b = wire::encode_frame(9, &Message::Ping);
            b[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
            b
        },
        // wrong version: answered, closed
        {
            let mut b = wire::encode_frame(9, &Message::Ping);
            b[4] = 0x42;
            b
        },
        // version zero predates the protocol: fatal too
        {
            let mut b = wire::encode_frame(9, &Message::Ping);
            b[4] = 0x00;
            b
        },
    ] {
        let mut raw = TcpStream::connect(net.local_addr()).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        raw.write_all(&evil).unwrap();
        let frame = read_one(&mut raw);
        match frame.msg {
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_BAD_FRAME);
            }
            other => panic!("expected error frame, got {other:?}"),
        }
        // ... and the server closes: the next read hits EOF, it does
        // not hang
        let mut rest = Vec::new();
        raw.read_to_end(&mut rest).expect("clean close, not a hang");
        assert!(rest.is_empty(), "unexpected bytes after the error");
    }
    // the server survived four hostile connections
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.ping().unwrap();
    net.shutdown();
}

#[test]
fn mid_frame_disconnect_does_not_wedge_the_server() {
    let (net, nl) = serve(205, NetConfig::default());
    // half a header
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(b"NLWP\x02\x00").unwrap();
    drop(raw);
    // a full header promising a body that never comes
    let full = wire::encode_frame(3, &Message::Infer {
        model: "m".into(), batch: 1, n_in: 6, deadline_us: None,
        codes: vec![0; 6],
    });
    let mut raw = TcpStream::connect(net.local_addr()).unwrap();
    raw.write_all(&full[..wire::HEADER_LEN + 3]).unwrap();
    drop(raw);
    // the server is still fully alive
    let mut c = Client::connect(net.local_addr()).unwrap();
    let x = random_inputs(205, &nl, 2);
    let y = c.infer("m", 2, 6, x.clone()).unwrap();
    let ow = nl.out_width();
    for b in 0..2 {
        assert_eq!(&y[b * ow..(b + 1) * ow],
                   &nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap()[..]);
    }
    net.shutdown();
}

#[test]
fn overload_sheds_explicitly_and_counts_in_stats() {
    // admission bound of 1 row: pipelined single-row requests race the
    // writer, so a flood must shed; a batch wider than the bound is
    // shed deterministically even when idle.  The per-connection quota
    // is disabled so every shed exercises the *global* bound.
    let (net, nl) = serve(206, NetConfig {
        max_inflight: 1,
        max_inflight_per_conn: Some(usize::MAX),
        ..NetConfig::default()
    });
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // deterministic: batch 4 > bound 1 is always OVERLOADED
    match c.infer("m", 4, 6, random_inputs(206, &nl, 4)) {
        Err(InferError::Overloaded) => {}
        other => panic!("expected Overloaded, got {other:?}"),
    }

    // flood: pipeline many single-row requests without reading
    let flood = 400usize;
    let x = random_inputs(207, &nl, flood);
    let mut ids = Vec::with_capacity(flood);
    for i in 0..flood {
        let row = x[i * 6..(i + 1) * 6].to_vec();
        ids.push(c.send_infer("m", 1, 6, row).unwrap());
    }
    let mut ok = 0usize;
    let mut shed = 0usize;
    let ow = nl.out_width();
    for (i, id) in ids.into_iter().enumerate() {
        let frame = c.recv_frame().unwrap();
        assert_eq!(frame.id, id, "responses arrive in request order");
        match frame.msg {
            Message::Result { codes, .. } => {
                let want =
                    nl.eval_one(&x[i * 6..(i + 1) * 6]).unwrap();
                assert_eq!(codes[..ow], want[..], "row {i}");
                ok += 1;
            }
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_OVERLOADED,
                           "only sheds may fail under flood");
                shed += 1;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(ok + shed, flood);
    assert!(ok > 0, "nothing was served under flood");
    assert!(shed > 0, "a 1-row bound never shed under a 400-deep flood");
    // every shed (incl. the deterministic batch-4 one) is counted
    let stats = c.stats("").expect("stats stay queryable after overload");
    let doc = Json::parse(&stats).unwrap();
    let m = &doc.at("models").unwrap().as_arr().unwrap()[0];
    let counted =
        m.at("net").unwrap().at("shed").unwrap().as_usize().unwrap();
    assert_eq!(counted, shed + 1, "stats shed count disagrees");
    let srv = doc.at("server").unwrap();
    assert_eq!(srv.at("shed_total").unwrap().as_usize().unwrap(),
               shed + 1);
    assert_eq!(srv.at("max_inflight").unwrap().as_usize().unwrap(), 1);
    // none of this was a quota shed — the quota was disabled
    assert_eq!(srv.at("quota_sheds").unwrap().as_usize().unwrap(), 0);
    net.shutdown();
}

#[test]
fn conn_quota_sheds_typed_per_connection_and_counts_in_stats() {
    let (net, nl) = serve(212, NetConfig {
        max_inflight: 1024,
        max_inflight_per_conn: Some(4),
        ..NetConfig::default()
    });
    let mut greedy = Client::connect(net.local_addr()).unwrap();
    greedy.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // batch 5 exceeds this connection's quota of 4: typed CONN_QUOTA,
    // deterministically, even though the global bound has room
    match greedy.infer("m", 5, 6, random_inputs(212, &nl, 5)) {
        Err(InferError::ConnQuota) => {}
        other => panic!("expected ConnQuota, got {other:?}"),
    }
    // batch 4 fits the quota and is served bit-exactly
    let x = random_inputs(213, &nl, 4);
    let y = greedy.infer("m", 4, 6, x.clone()).unwrap();
    let ow = nl.out_width();
    for b in 0..4 {
        assert_eq!(&y[b * ow..(b + 1) * ow],
                   &nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap()[..]);
    }
    // quotas are per connection: a second connection has its own
    let mut polite = Client::connect(net.local_addr()).unwrap();
    let x2 = random_inputs(214, &nl, 4);
    polite.infer("m", 4, 6, x2).expect("independent quota");
    // counted where it happened: once globally, once on the model,
    // once on the greedy connection (and nowhere else)
    assert_eq!(net.quota_sheds_total(), 1);
    assert_eq!(net.shed_total(), 0, "a quota shed is not a global shed");
    let doc = Json::parse(&greedy.stats("m").unwrap()).unwrap();
    let m = &doc.at("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(m.at("net").unwrap().at("quota_sheds").unwrap()
                  .as_usize().unwrap(), 1);
    let srv = doc.at("server").unwrap();
    assert_eq!(srv.at("max_inflight_per_conn").unwrap().as_usize()
                  .unwrap(), 4);
    assert_eq!(srv.at("quota_sheds").unwrap().as_usize().unwrap(), 1);
    let conns = srv.at("connections").unwrap().as_arr().unwrap();
    assert_eq!(conns.len(), 2);
    let shed_counts: Vec<usize> = conns
        .iter()
        .map(|c| c.at("quota_sheds").unwrap().as_usize().unwrap())
        .collect();
    assert_eq!(shed_counts.iter().sum::<usize>(), 1,
               "exactly one connection was throttled: {shed_counts:?}");
    net.shutdown();
}

#[test]
fn infeasible_deadline_is_shed_at_admission_and_counted() {
    let (net, nl) = serve(215, NetConfig::default());
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let x = random_inputs(215, &nl, 1);
    // before any latency history exists, a roomy budget is admitted
    let y = c
        .infer_deadline("m", 1, 6, x.clone(), Some(10_000_000))
        .expect("10 s budget with no p50 history is admitted");
    assert_eq!(y, nl.eval_one(&x).unwrap());
    // warm the latency reservoir so the observed p50 is real, then
    // outwait the p50-cache refresh interval so the next deadline
    // check reads the warmed estimate, not the pre-warmup snapshot
    for _ in 0..50 {
        c.infer("m", 1, 6, x.clone()).unwrap();
    }
    std::thread::sleep(Duration::from_millis(60));
    // a 1 µs budget is spent (or below the observed p50) by the time
    // admission sees it: shed with a typed DEADLINE error
    match c.infer_deadline("m", 1, 6, x.clone(), Some(1)) {
        Err(InferError::DeadlineExceeded(msg)) => {
            assert!(msg.contains("budget"), "{msg}");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(net.deadline_sheds_total(), 1);
    // the connection survives a deadline shed, and no-deadline
    // requests are untouched by the policy
    let y = c.infer("m", 1, 6, x.clone()).unwrap();
    assert_eq!(y, nl.eval_one(&x).unwrap());
    let doc = Json::parse(&c.stats("m").unwrap()).unwrap();
    let m = &doc.at("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(m.at("net").unwrap().at("deadline_sheds").unwrap()
                  .as_usize().unwrap(), 1);
    let srv = doc.at("server").unwrap();
    assert_eq!(srv.at("deadline_sheds").unwrap().as_usize().unwrap(), 1);
    assert_eq!(srv.at("shed_total").unwrap().as_usize().unwrap(), 0,
               "a deadline shed is not a capacity shed");
    net.shutdown();
}

/// p99 of a latency sample (µs).
fn p99(mut v: Vec<f64>) -> f64 {
    v.sort_by(f64::total_cmp);
    let idx = ((v.len() as f64 * 0.99).ceil() as usize).max(1) - 1;
    v[idx.min(v.len() - 1)]
}

/// Run `rounds` rounds of depth-4 pipelined requests against `addr`,
/// returning per-round latencies in µs.  Every response must be a
/// bit-exact result — a polite tenant under quota must never be shed.
fn polite_rounds(addr: std::net::SocketAddr, nl: &Netlist, seed: u64,
                 rounds: usize) -> Vec<f64> {
    let mut c = Client::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let x = random_inputs(seed, nl, 4);
    let ow = nl.out_width();
    let mut lats = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t = Instant::now();
        let mut ids = Vec::with_capacity(4);
        for b in 0..4 {
            ids.push(c.send_infer("m", 1, 6,
                                  x[b * 6..(b + 1) * 6].to_vec())
                      .unwrap());
        }
        for (b, id) in ids.into_iter().enumerate() {
            let frame = c.recv_frame().unwrap();
            assert_eq!(frame.id, id);
            match frame.msg {
                Message::Result { codes, .. } => {
                    let want =
                        nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
                    assert_eq!(codes[..ow], want[..]);
                }
                other => panic!("polite tenant shed: {other:?}"),
            }
        }
        lats.push(t.elapsed().as_micros() as f64);
    }
    lats
}

#[test]
fn quota_keeps_a_polite_tenant_p99_bounded_under_a_greedy_flood() {
    // global bound 64, per-connection quota 16: a depth-400 greedy
    // pipeline can monopolize at most a quarter of the admission
    // capacity, so a polite depth-4 tenant keeps its latency
    let (net, nl) = serve(216, NetConfig {
        max_inflight: 64,
        ..NetConfig::default()
    });
    let addr = net.local_addr();
    let rounds = 150;
    let solo = p99(polite_rounds(addr, &nl, 301, rounds));

    let stop = Arc::new(AtomicBool::new(false));
    let greedy = {
        let stop = stop.clone();
        let row = random_inputs(302, &nl, 1);
        std::thread::spawn(move || {
            let Ok(mut c) = Client::connect(addr) else { return };
            let _ = c.set_read_timeout(Some(Duration::from_millis(200)));
            let mut outstanding = 0usize;
            while !stop.load(Ordering::Relaxed) {
                while outstanding < 400 && !stop.load(Ordering::Relaxed)
                {
                    if c.send_infer("m", 1, 6, row.clone()).is_err() {
                        return;
                    }
                    outstanding += 1;
                }
                if c.recv_frame().is_ok() {
                    outstanding -= 1;
                }
            }
        })
    };
    // let the flood establish itself before measuring
    std::thread::sleep(Duration::from_millis(100));
    let contended = p99(polite_rounds(addr, &nl, 303, rounds));
    stop.store(true, Ordering::Relaxed);
    let _ = greedy.join();
    // within 2x of solo p99 (plus a small absolute grace for noisy CI
    // runners at µs scales)
    let bound = (2.0 * solo).max(solo + 2500.0);
    assert!(contended <= bound,
            "polite p99 {contended:.0} µs exceeds bound {bound:.0} µs \
             (solo p99 {solo:.0} µs) — the quota failed to isolate the \
             greedy flood");
    assert!(net.quota_sheds_total() > 0,
            "a depth-400 pipeline against a 16-row quota never shed");
    net.shutdown();
}

#[test]
fn drain_deadline_fires_mid_write_streak_and_stays_bounded() {
    // every server write sleeps 300 ms, so in-flight answers cannot
    // flush within the 150 ms drain window: the drain deadline fires
    // while rows are still in flight.  The regression this guards:
    // drain sleeps are clamped to the time remaining, so phase 3 ends
    // at the deadline instead of riding past it streak by streak.
    let (net, nl) = serve(217, NetConfig {
        drain_wait: Duration::from_millis(150),
        fault: Some(FaultPlan::delay_writes(300)),
        ..NetConfig::default()
    });
    let mut c = Client::connect(net.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let x = random_inputs(217, &nl, 6);
    for i in 0..6 {
        c.send_infer("m", 1, 6, x[i * 6..(i + 1) * 6].to_vec())
            .unwrap();
    }
    // let the admissions land so the drain has real in-flight work
    std::thread::sleep(Duration::from_millis(50));
    let t = Instant::now();
    net.shutdown();
    let elapsed = t.elapsed();
    assert!(elapsed >= Duration::from_millis(140),
            "drain returned in {elapsed:?} with rows still in flight \
             behind a wedged writer — the deadline cannot have been \
             honored");
    assert!(elapsed < Duration::from_secs(3),
            "drain took {elapsed:?}; the deadline fired but shutdown \
             was not bounded");
    // idempotent, and instant the second time
    let t = Instant::now();
    net.shutdown();
    assert!(t.elapsed() < Duration::from_millis(50));
}

#[test]
fn graceful_drain_flushes_inflight_then_refuses_new_connections() {
    let (net, nl) = serve(208, NetConfig::default());
    let addr = net.local_addr();
    let mut c = Client::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // put work in flight, then drain while it is pending
    let k = 64usize;
    let x = random_inputs(208, &nl, k);
    let mut ids = Vec::new();
    for i in 0..k {
        ids.push(c.send_infer("m", 1, 6,
                              x[i * 6..(i + 1) * 6].to_vec()).unwrap());
    }
    // let admissions land so the drain has real in-flight work
    std::thread::sleep(Duration::from_millis(50));
    let t = Instant::now();
    net.shutdown();
    assert!(t.elapsed() < Duration::from_secs(10), "drain hung");
    // every in-flight request got an answer: a bit-exact result or a
    // typed shutting-down error, never silence
    let ow = nl.out_width();
    let mut answered = 0usize;
    for (i, id) in ids.into_iter().enumerate() {
        let frame = c.recv_frame().unwrap_or_else(|e| {
            panic!("request {i} got no answer before close: {e}")
        });
        assert_eq!(frame.id, id);
        match frame.msg {
            Message::Result { codes, .. } => {
                let want = nl.eval_one(&x[i * 6..(i + 1) * 6]).unwrap();
                assert_eq!(codes[..ow], want[..], "row {i}");
                answered += 1;
            }
            Message::Error { code, .. } => {
                assert_eq!(code, wire::ERR_SHUTTING_DOWN);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(answered > 0, "drain answered nothing");
    // new connections are refused (or immediately closed) after drain
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(mut s) => {
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            s.write_all(&wire::encode_frame(1, &Message::Ping)).ok();
            let mut buf = Vec::new();
            // a drained server never answers; EOF or reset, not a hang
            assert!(matches!(s.read_to_end(&mut buf), Ok(0) | Err(_)),
                    "drained server still answering");
        }
    }
    // shutdown is idempotent
    net.shutdown();
}

#[test]
fn net_session_speaks_the_session_api_over_tcp() {
    let (net, nl) = serve(209, NetConfig::default());
    let mut s = NetSession::open(net.local_addr(), "m").unwrap();
    assert_eq!(s.input_names(), [INPUT_X.to_string()]);
    assert_eq!(s.output_names(), [OUTPUT_Y.to_string()]);
    let x = random_inputs(209, &nl, 9);
    let out = s.run(&[(INPUT_X, &x[..])]).unwrap();
    let y = &out[OUTPUT_Y];
    let ow = nl.out_width();
    for b in 0..9 {
        let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
        assert_eq!(&y[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
    // bad inputs are values here exactly as in-process
    assert!(matches!(s.run(&[("z", &x[..])]),
                     Err(InferError::BadInput(_))));
    assert!(matches!(s.run(&[(INPUT_X, &x[..5])]),
                     Err(InferError::BadInput(_))));
    net.shutdown();
}

#[test]
fn retry_client_survives_the_server_coming_up_late() {
    // reserve a loopback port, free it, and point a retrying client at
    // it before the server exists: connects are refused, the retry
    // loop backs off, and the request lands once the server binds —
    // the restart-survival story without a rebind race
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let nl = random_netlist(218, 6, 1, &[(5, 2, 2), (3, 2, 2)]);
    let server = {
        let nl = nl.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(250));
            let mut registry = ModelRegistry::new();
            registry.register("m", nl);
            let server = InferenceServer::start(
                registry,
                ServerConfig { max_batch: 8,
                               max_wait: Duration::from_micros(100),
                               workers: 2, ..ServerConfig::default() },
            );
            NetServer::bind(server, addr, NetConfig::default())
                .expect("rebind the reserved port")
        })
    };
    let cfg = ClientConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Some(Duration::from_secs(5)),
        retry: RetryPolicy {
            max_attempts: 12,
            base: Duration::from_millis(50),
            cap: Duration::from_millis(200),
            seed: 7,
        },
        fault: None,
    };
    let mut rc = RetryClient::connect(addr, cfg).unwrap();
    let x = random_inputs(218, &nl, 1);
    let y = rc.infer("m", 1, 6, &x, None)
        .expect("the retry loop outlives the server's startup");
    assert_eq!(y, nl.eval_one(&x).unwrap());
    let st = rc.retry_stats();
    assert!(st.retries >= 1,
            "the server started 250 ms late; the first attempt cannot \
             have succeeded: {st:?}");
    assert!(st.backoff_us > 0);
    assert_eq!(st.gave_up, 0);
    let net = server.join().expect("server thread");
    net.shutdown();
}

#[test]
fn retry_client_reconnects_after_a_scripted_connection_drop() {
    let (net, nl) = serve(219, NetConfig::default());
    // kill the very first client write, deterministically
    let plan = FaultPlan::scripted(&[(0, Dir::Write,
                                      Fault::DropConnection)]);
    let cfg = ClientConfig {
        read_timeout: Some(Duration::from_secs(5)),
        retry: RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(20),
            seed: 11,
        },
        fault: Some(plan.clone()),
        ..ClientConfig::default()
    };
    let mut rc = RetryClient::connect(net.local_addr(), cfg).unwrap();
    let x = random_inputs(219, &nl, 2);
    let y = rc.infer("m", 2, 6, &x, None)
        .expect("one dropped connection must not fail the request");
    let ow = nl.out_width();
    for b in 0..2 {
        assert_eq!(&y[b * ow..(b + 1) * ow],
                   &nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap()[..]);
    }
    let st = rc.retry_stats();
    assert_eq!(st.reconnects, 1, "{st:?}");
    assert!(st.retries >= 1, "{st:?}");
    assert_eq!(plan.counts().drops, 1);
    // non-retryable rejections still pass straight through
    match rc.infer("ghost", 1, 6, &x[..6], None) {
        Err(InferError::UnknownModel(_)) => {}
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    net.shutdown();
}

#[test]
fn chaos_client_faults_retry_to_bit_exact_answers() {
    // a seeded 1 % fault plan on the client's own sockets: every
    // injected delay, reset, truncation, corruption or partial op
    // must end in a typed error absorbed by a retry — the answers
    // that come back are bit-exact, every time
    let seed = chaos_seed();
    let (net, nl) = serve(220 ^ seed, NetConfig::default());
    let plan = FaultPlan::seeded(seed, 0.01);
    let cfg = ClientConfig {
        connect_timeout: Duration::from_secs(5),
        // short read timeout: a fault-killed stream surfaces as a
        // typed timeout the retry loop can absorb, not a 30 s stall
        read_timeout: Some(Duration::from_secs(2)),
        retry: RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(50),
            seed,
        },
        fault: Some(plan.clone()),
    };
    let mut eng = RemoteEngine::open_with(net.local_addr(), "m", cfg)
        .expect("open through the fault plan");
    use neuralut::coordinator::InferenceEngine;
    let ow = nl.out_width();
    for i in 0..400u64 {
        let batch = 1 + (i as usize % 7);
        let x = random_inputs(seed.wrapping_add(i), &nl, batch);
        let y = eng
            .run_batch(&x, batch)
            .unwrap_or_else(|e| panic!("request {i}: {e:#}"));
        for b in 0..batch {
            let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
            assert_eq!(&y[b * ow..(b + 1) * ow], &want[..],
                       "request {i} row {b}");
        }
    }
    assert!(plan.counts().total() > 0,
            "a 1 % plan never fired across hundreds of requests \
             (seed {seed})");
    let st = eng.retry_stats();
    assert!(st.attempts >= 400, "{st:?}");
    net.shutdown();
}

#[test]
fn chaos_server_faults_conformance_stays_bit_exact_and_drain_bounded() {
    // the same engine-conformance contract every in-process backend
    // passes, driven through a server whose sockets fail 1 % of the
    // time: retries absorb the chaos, the answers stay bit-exact
    let seed = chaos_seed();
    let (net, nl) = serve(221 ^ seed, NetConfig {
        fault: Some(FaultPlan::seeded(seed ^ 0x5EED, 0.01)),
        ..NetConfig::default()
    });
    let cfg = ClientConfig {
        connect_timeout: Duration::from_secs(5),
        read_timeout: Some(Duration::from_secs(2)),
        retry: RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(50),
            seed,
        },
        fault: None,
    };
    let mut eng = RemoteEngine::open_with(net.local_addr(), "m", cfg)
        .expect("open against a faulty server");
    check_conformance(&mut eng, &nl, seed)
        .expect("conformance through 1 % server faults");
    // drain stays bounded even with fault-wedged connections
    let t = Instant::now();
    net.shutdown();
    assert!(t.elapsed() < Duration::from_secs(15),
            "chaos drain took {:?}", t.elapsed());
}

#[test]
fn chaos_answers_are_at_most_once_per_request_id() {
    // a plain non-retrying client against a faulty server: whatever
    // the fault schedule does, no request id is ever answered twice,
    // and every answered id is answered correctly
    let seed = chaos_seed();
    let (net, nl) = serve(222 ^ seed, NetConfig {
        fault: Some(FaultPlan::seeded(seed ^ 0xACE, 0.01)),
        ..NetConfig::default()
    });
    let cfg = ClientConfig {
        read_timeout: Some(Duration::from_secs(2)),
        retry: RetryPolicy::none(),
        ..ClientConfig::default()
    };
    let mut c = Client::connect_with(net.local_addr(), &cfg).unwrap();
    let n = 300usize;
    let x = random_inputs(seed.wrapping_add(5), &nl, n);
    let mut sent: HashMap<u64, usize> = HashMap::new();
    for i in 0..n {
        match c.send_infer("m", 1, 6, x[i * 6..(i + 1) * 6].to_vec()) {
            Ok(id) => {
                sent.insert(id, i);
            }
            Err(_) => break, // the fault plan killed the connection
        }
    }
    assert!(!sent.is_empty(), "nothing was sent");
    let ow = nl.out_width();
    let mut answered: HashSet<u64> = HashSet::new();
    loop {
        match c.recv_frame() {
            Ok(frame) => {
                if frame.id == 0 {
                    // an id-0 BAD_FRAME from injected read corruption
                    // answers no specific request
                    continue;
                }
                assert!(sent.contains_key(&frame.id),
                        "answer for an id never sent: {}", frame.id);
                assert!(answered.insert(frame.id),
                        "request id {} answered twice", frame.id);
                if let Message::Result { codes, .. } = frame.msg {
                    let i = sent[&frame.id];
                    let want =
                        nl.eval_one(&x[i * 6..(i + 1) * 6]).unwrap();
                    assert_eq!(codes[..ow], want[..],
                               "request id {} answered wrong", frame.id);
                }
            }
            Err(_) => break, // EOF, reset or timeout: stream is done
        }
    }
    assert!(answered.len() <= sent.len());
    net.shutdown();
}

/// Read one frame off a raw socket (test helper).
fn read_one(s: &mut TcpStream) -> Frame {
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wire::read_frame(s).expect("a frame")
}
