//! Integration tests over the compiled artifacts + PJRT runtime.
//!
//! These require `make artifacts` to have run (meta.json + HLO files);
//! they are the system-level counterpart of python/tests/test_enumerate.py:
//! the rust-driven training loop, the enumeration executables, the netlist
//! simulator and the RTL emitter must all agree.
//!
//! The smallest configuration (nid) is used throughout to keep the suite
//! fast; the full-size configs are exercised by the benches/examples.
//!
//! The `golden_*` tests need no runtime: they load the committed
//! python-written `.nlb` artifacts under `tests/golden/` and pin the
//! cross-language format contract (python/tests/test_nlb.py holds the
//! other end).

use neuralut::config::{Meta, TrainConfig};
use neuralut::coordinator::{run_flow, FlowOptions, Session};
use neuralut::dataset::{self, GenOpts};
use neuralut::mapper::map_netlist;
use neuralut::netlist::{optimize, OptLevel};
use neuralut::rtl;
use neuralut::runtime::Runtime;
use neuralut::timing::{evaluate, DelayModel, Pipelining};

/// Load the compiled-artifact index, or `None` when `make artifacts`
/// has not run (the suite then skips: these tests need the PJRT runtime
/// and HLO files, which CI does not build).
fn meta() -> Option<Meta> {
    match Meta::load(Meta::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping PJRT integration test: {e:#} \
                       (run `make artifacts` first)");
            None
        }
    }
}

fn small_gen() -> GenOpts {
    GenOpts { n_train: 1200, n_test: 400, ..Default::default() }
}

#[test]
fn artifacts_load_when_required() {
    // Canary against vacuous green: the other tests skip when artifacts
    // are absent, which would also silently mask a regression in
    // `Meta::load` itself.  Artifact-equipped runners set
    // NLA_REQUIRE_ARTIFACTS=1 to turn a load failure into a hard error.
    if std::env::var("NLA_REQUIRE_ARTIFACTS").ok().as_deref() == Some("1") {
        Meta::load(Meta::default_dir())
            .expect("NLA_REQUIRE_ARTIFACTS=1 but artifacts failed to load");
    }
}

#[test]
fn meta_has_all_presets() {
    let Some(meta) = meta() else { return };
    for cfg in ["mnist", "jsc_cb", "jsc_oml", "nid",
                "fig5_opt1", "fig5_opt2", "fig5_opt3"] {
        let c = meta.config(cfg).unwrap();
        assert!(c.entries.contains_key("train_step"), "{cfg}");
        assert!(c.entries.contains_key("train_step_dense"), "{cfg}");
        assert!(c.entries.contains_key("infer"), "{cfg}");
        assert!(c.entries.contains_key("infer_pallas"), "{cfg}");
        assert!(c.entries.contains_key("lut_infer"), "{cfg}");
        for l in 0..c.topology.n_layers() {
            assert!(c.entries.contains_key(&format!("enum_l{l}")), "{cfg} l{l}");
        }
    }
}

#[test]
fn train_step_reduces_loss_via_pjrt() {
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    let mut sess = Session::new(&rt, cfg, false, None, 3, 1.0).unwrap();
    let tc = TrainConfig::sparse(60);
    let losses = sess.train(&splits.train, &tc).unwrap();
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
}

#[test]
fn netlist_is_bit_exact_with_pjrt_infer() {
    // the system-level keystone, on trained (non-random) weights
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    let mut sess = Session::new(&rt, cfg, false, None, 5, 1.0).unwrap();
    sess.train(&splits.train, &TrainConfig::sparse(40)).unwrap();
    let nl = sess.to_netlist().unwrap();
    nl.validate().unwrap();

    let top = cfg.topology.clone();
    let idx: Vec<usize> = (0..top.batch.min(splits.test.n)).collect();
    let (x, _) = splits.test.batch(&idx, top.batch);
    let pjrt = sess.infer_codes(&x, "infer").unwrap();
    let net = nl.eval_batch(&x, top.batch).unwrap();
    assert_eq!(pjrt, net, "netlist must reproduce the PJRT forward exactly");
}

#[test]
fn pallas_infer_agrees_with_ref_infer() {
    // the L1 Pallas kernel path (infer_pallas artifact) must match the
    // pure-jnp path (infer artifact) on the same trained parameters
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    let mut sess = Session::new(&rt, cfg, false, None, 9, 1.0).unwrap();
    sess.train(&splits.train, &TrainConfig::sparse(25)).unwrap();
    let top = cfg.topology.clone();
    let idx: Vec<usize> = (0..top.batch.min(splits.test.n)).collect();
    let (x, _) = splits.test.batch(&idx, top.batch);
    let a = sess.infer_codes(&x, "infer").unwrap();
    let b = sess.infer_codes(&x, "infer_pallas").unwrap();
    assert_eq!(a, b, "pallas and jnp forwards must produce the same codes");
}

#[test]
fn skip_ablation_changes_model_but_stays_bit_exact() {
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    let mut sess = Session::new(&rt, cfg, false, None, 5, 0.0).unwrap();
    sess.train(&splits.train, &TrainConfig::sparse(25)).unwrap();
    let nl = sess.to_netlist().unwrap();
    let top = cfg.topology.clone();
    let idx: Vec<usize> = (0..top.batch.min(splits.test.n)).collect();
    let (x, _) = splits.test.batch(&idx, top.batch);
    let pjrt = sess.infer_codes(&x, "infer").unwrap();
    let net = nl.eval_batch(&x, top.batch).unwrap();
    assert_eq!(pjrt, net);
}

#[test]
fn full_flow_with_rtl_roundtrip() {
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let opts = FlowOptions {
        config: "fig5_opt1".into(),
        dense_steps: 10,
        sparse_steps: 40,
        skip_scale: 1.0,
        seed: 21,
        gen: small_gen(),
        emit_rtl: true,
        verify_bit_exact: true,
        opt_level: OptLevel::Full,
    };
    let r = run_flow(&rt, &meta, &opts).unwrap();
    assert_eq!(r.bit_exact, Some(true));
    // the RTL is emitted from the optimized netlist (what would ship)
    let text = r.rtl_text.unwrap();
    rtl::verify_roundtrip(&text, &r.netlist_opt).unwrap();
    // mapping + timing sanity; the optimizer can only shrink the design
    assert!(r.mapped.total_luts() > 0);
    assert!(r.mapped.total_luts() <= r.mapped_raw.total_luts());
    assert!(r.netlist_opt.total_units() <= r.netlist.total_units());
    for (_, rep) in &r.reports {
        assert!(rep.fmax_mhz > 50.0 && rep.latency_ns > 0.1);
    }
}

/// The committed golden manifest: [(model, file, content_hash, inputs,
/// outputs)], written by `python -m tests.golden_nlb`.
fn golden_manifest() -> Vec<(String, String, u64, Vec<Vec<i32>>,
                             Vec<Vec<i32>>)> {
    use neuralut::util::json::Json;
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden");
    let text = std::fs::read_to_string(format!("{dir}/golden_io.json"))
        .expect("tests/golden/golden_io.json is committed");
    let rows = |v: &Json| -> Vec<Vec<i32>> {
        v.as_arr().unwrap().iter()
            .map(|row| row.as_arr().unwrap().iter()
                .map(|c| c.as_i64().unwrap() as i32).collect())
            .collect()
    };
    Json::parse(&text).unwrap().as_arr().unwrap().iter()
        .map(|e| (
            e.at("model").unwrap().as_str().unwrap().to_string(),
            format!("{dir}/{}", e.at("file").unwrap().as_str().unwrap()),
            u64::from_str_radix(
                e.at("content_hash").unwrap().as_str().unwrap(), 16)
                .unwrap(),
            rows(e.at("inputs").unwrap()),
            rows(e.at("outputs").unwrap()),
        ))
        .collect()
}

#[test]
fn golden_python_artifacts_load_and_evaluate_bit_exactly() {
    // the cross-language keystone: a python-exported model must load
    // here, hash identically, and reproduce python's recorded outputs
    use neuralut::netlist::load_nlb;
    let manifest = golden_manifest();
    assert_eq!(manifest.len(), 2, "expected both golden models");
    for (model, file, hash, inputs, outputs) in manifest {
        let m = load_nlb(&file).unwrap();
        assert_eq!(m.netlist.name, model);
        assert_eq!(m.netlist.content_hash(), hash,
                   "{model}: content hash diverged between languages");
        assert!(m.plan.is_none(), "python writes no plan image");
        for (x, want) in inputs.iter().zip(&outputs) {
            assert_eq!(&m.netlist.eval_one(x).unwrap(), want,
                       "{model}: output differs from python eval");
        }
    }
}

#[test]
fn golden_artifacts_reserialize_byte_identically() {
    // both writers emit canonical bytes: rust(write(python_read)) must
    // equal the committed python-written file exactly
    use neuralut::netlist::{load_nlb, write_nlb};
    for (model, file, _, _, _) in golden_manifest() {
        let committed = std::fs::read(&file).unwrap();
        let m = load_nlb(&file).unwrap();
        let rewritten = write_nlb(&m.netlist, None).unwrap();
        assert_eq!(rewritten, committed,
                   "{model}: rust re-encoding differs from python bytes");
    }
}

#[test]
fn golden_v1_artifact_still_loads_via_the_copying_read() {
    // back-compat keystone: `golden_mix_v1.nlb` is the pre-padding v1
    // encoding of `golden_mix.nlb` (snapshotted when the format moved
    // to v2).  It must keep loading — through both loaders — decode to
    // the identical model, and never take the zero-copy path (v1 files
    // carry no alignment guarantee)
    use neuralut::netlist::{load_nlb, load_nlb_mapped};
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden");
    let v1_path = format!("{dir}/golden_mix_v1.nlb");
    let v1_bytes = std::fs::read(&v1_path).unwrap();
    assert_eq!(&v1_bytes[4..6], &[1, 0], "fixture must stay version 1");
    let v1 = load_nlb(&v1_path).unwrap();
    let v1_mapped = load_nlb_mapped(&v1_path).unwrap();
    let v2 = load_nlb(format!("{dir}/golden_mix.nlb")).unwrap();
    assert_eq!(v1.netlist.content_hash(), v2.netlist.content_hash());
    assert_eq!(v1_mapped.netlist.content_hash(),
               v2.netlist.content_hash());
    assert!(v1.plan.is_none() && v1_mapped.plan.is_none());
    for (model, _, _, inputs, outputs) in golden_manifest() {
        if model != "golden_mix" {
            continue;
        }
        for (x, want) in inputs.iter().zip(&outputs) {
            assert_eq!(&v1.netlist.eval_one(x).unwrap(), want);
            assert_eq!(&v1_mapped.netlist.eval_one(x).unwrap(), want);
        }
    }
}

#[test]
fn golden_artifacts_compile_and_conform() {
    // a python-trained model dropped into the serving path: compile a
    // plan for it and run the full engine-conformance suite
    use neuralut::coordinator::check_conformance;
    use neuralut::netlist::{load_nlb, PlanExecutor, PlanOptions};
    use std::sync::Arc;
    for (model, file, _, _, _) in golden_manifest() {
        let m = load_nlb(&file).unwrap();
        let plan = m.plan_or_compile(PlanOptions::default());
        let mut ex = PlanExecutor::new(Arc::clone(&plan));
        check_conformance(&mut ex, &m.netlist, 0x60)
            .unwrap_or_else(|e| panic!("{model}: {e:#}"));
    }
}

#[test]
fn learned_mappings_change_connectivity() {
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    // dense phase
    let mut dense = Session::new(&rt, cfg, true, None, 5, 1.0).unwrap();
    dense.train(&splits.train, &TrainConfig::dense(20)).unwrap();
    let scores = dense.group_scores().unwrap();
    assert_eq!(scores.len(), dense.learned_layers().len());
    let top = &cfg.topology;
    let conns: Vec<Vec<Vec<u32>>> = dense
        .learned_layers()
        .iter()
        .enumerate()
        .map(|(k, &l)| neuralut::pruning::select_top_f(&scores[k], top.f[l]))
        .collect();
    // a random session picks different wiring
    let rand_sess = Session::new(&rt, cfg, false, None, 5, 1.0).unwrap();
    let learned_sess =
        Session::new(&rt, cfg, false, Some(&conns), 5, 1.0).unwrap();
    assert_ne!(rand_sess.connections[0], learned_sess.connections[0]);
    // assemble layers always strided
    assert_eq!(rand_sess.connections[1], learned_sess.connections[1]);
}

#[test]
fn mapper_and_timing_on_trained_netlist() {
    let Some(meta) = meta() else { return };
    let rt = Runtime::new().unwrap();
    let cfg = meta.config("nid").unwrap();
    let splits =
        dataset::generate("nid", cfg.topology.beta_in, &small_gen()).unwrap();
    let mut sess = Session::new(&rt, cfg, false, None, 13, 1.0).unwrap();
    sess.train(&splits.train, &TrainConfig::sparse(30)).unwrap();
    let nl = sess.to_netlist().unwrap();
    let mapped = map_netlist(&nl, true);
    let raw = map_netlist(&nl, false);
    // support reduction can only shrink the design
    assert!(mapped.total_luts() <= raw.total_luts());
    let dm = DelayModel::default();
    let p1 = evaluate(&mapped, Pipelining::EveryLayer, &dm);
    let p3 = evaluate(&mapped, Pipelining::EveryK(3), &dm);
    assert!(p3.ffs <= p1.ffs);
    assert!(p3.stages <= p1.stages);
    // the netlist optimizer on *trained* tables: bit-exact on a test
    // batch and never a larger mapped design
    let (opt, report) = optimize(&nl, OptLevel::Full);
    assert!(report.units_after <= report.units_before);
    let idx: Vec<usize> = (0..cfg.topology.batch.min(splits.test.n))
        .collect();
    let (x, _) = splits.test.batch(&idx, cfg.topology.batch);
    assert_eq!(opt.eval_batch(&x, cfg.topology.batch).unwrap(),
               nl.eval_batch(&x, cfg.topology.batch).unwrap());
    let mapped_opt = map_netlist(&opt, true);
    assert!(mapped_opt.total_luts() <= mapped.total_luts());
}
