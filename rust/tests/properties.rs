//! Property-based test suites over the hardware substrates (no artifacts
//! needed — these run on randomly generated netlists/tables, 64 cases per
//! property by default, `NLA_PROP_CASES` to widen).

use std::sync::Arc;

use neuralut::luts::TruthTable;
use neuralut::mapper::{map_netlist, plut_cost, plut_depth};
use neuralut::netlist::testutil::{random_inputs, random_netlist,
                                  random_reducible_netlist};
use neuralut::netlist::{compile, optimize, Netlist, OptLevel, PlanCache,
                        PlanExecutor, PlanOptions, SimOptions,
                        ThreadMode, WidePlanExecutor};
use neuralut::pruning;
use neuralut::rtl;
use neuralut::timing::{evaluate, DelayModel, Pipelining};
use neuralut::util::proptest::{default_cases, forall, gen};
use neuralut::util::Rng;

/// Random (n_in, in_bits, layer shapes) within substrate limits.
fn arb_shape(rng: &mut Rng) -> (u64, usize, usize, Vec<(usize, usize, usize)>) {
    let seed = rng.next_u64();
    let n_in = gen::usize_in(rng, 4, 24);
    let in_bits = gen::usize_in(rng, 1, 3);
    let n_layers = gen::usize_in(rng, 1, 4);
    let mut shapes = Vec::new();
    let mut bits = in_bits;
    for _ in 0..n_layers {
        let fan_in = gen::usize_in(rng, 1, 3.min(8 / bits));
        let out_bits = gen::usize_in(rng, 1, 3);
        let w = gen::usize_in(rng, 1, 12);
        shapes.push((w, fan_in, out_bits));
        bits = out_bits;
    }
    (seed, n_in, in_bits, shapes)
}

#[test]
fn prop_eval_batch_equals_eval_one() {
    forall("eval_batch == eval_one", 0xA1, default_cases(), arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let batch = 1 + (seed % 90) as usize;
        let x = random_inputs(seed ^ 1, &nl, batch);
        let got = nl.eval_batch(&x, batch).map_err(|e| e.to_string())?;
        let ow = nl.out_width();
        for b in 0..batch {
            let one = nl
                .eval_one(&x[b * n_in..(b + 1) * n_in])
                .map_err(|e| e.to_string())?;
            if got[b * ow..(b + 1) * ow] != one[..] {
                return Err(format!("row {b} differs"));
            }
        }
        Ok(())
    });
}

/// Like `arb_shape` but with wide-address layers whose tables have true
/// support <= 6 per output bit, so every layer qualifies for the
/// bit-plane kernel even when `in_bits * fan_in > 6`.  Includes
/// zero-support (constant) output bits by construction.
fn arb_reducible(rng: &mut Rng)
                 -> (u64, usize, usize, Vec<(usize, usize, usize)>) {
    let seed = rng.next_u64();
    let n_in = gen::usize_in(rng, 4, 20);
    let in_bits = gen::usize_in(rng, 1, 3);
    let n_layers = gen::usize_in(rng, 1, 4);
    let mut shapes = Vec::new();
    let mut bits = in_bits;
    for _ in 0..n_layers {
        // raw address width up to 9 bits — beyond a physical LUT
        let fan_in = gen::usize_in(rng, 1, 3.min(9 / bits));
        let out_bits = gen::usize_in(rng, 1, 3);
        let w = gen::usize_in(rng, 1, 12);
        shapes.push((w, fan_in, out_bits));
        bits = out_bits;
    }
    (seed, n_in, in_bits, shapes)
}

#[test]
fn prop_bitplane_matches_eval_one_mixed_width() {
    // the v2 keystone: bit-plane evaluation is bit-exact with eval_one on
    // random mixed-width netlists, for batches that are not multiples of
    // 64, with constant output bits present
    forall("bit-plane == eval_one (mixed width)", 0xB1, default_cases(),
           arb_reducible, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let mut sim = nl.simulator_with(SimOptions {
            min_bitplane_batch: 1, ..Default::default()
        });
        if sim.bitplane_layers() != nl.layers.len() {
            return Err("a reducible layer fell back to gather".into());
        }
        let mut batch = 1 + (seed % 150) as usize;
        if batch % 64 == 0 {
            batch += 1;
        }
        let x = random_inputs(seed ^ 5, &nl, batch);
        let got = sim.eval_batch(&x, batch);
        let ow = nl.out_width();
        for b in 0..batch {
            let one = nl
                .eval_one(&x[b * n_in..(b + 1) * n_in])
                .map_err(|e| e.to_string())?;
            if got[b * ow..(b + 1) * ow] != one[..] {
                return Err(format!("row {b} differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitplane_threaded_matches_eval_one() {
    forall("threaded bit-plane == eval_one", 0xB2, 24, arb_reducible,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let mut sim = nl.simulator_with(SimOptions {
            threads: 4, min_bitplane_batch: 1, ..Default::default()
        });
        let batch = 65 + (seed % 200) as usize;
        let x = random_inputs(seed ^ 6, &nl, batch);
        let got = sim.eval_batch(&x, batch);
        let ow = nl.out_width();
        for b in 0..batch {
            let one = nl
                .eval_one(&x[b * n_in..(b + 1) * n_in])
                .map_err(|e| e.to_string())?;
            if got[b * ow..(b + 1) * ow] != one[..] {
                return Err(format!("row {b} differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_workers_match_scoped_and_eval_one() {
    // the persistent-pool refactor keystone: the pooled simulator is
    // bit-exact with the scoped-thread path (identical chunking, so any
    // divergence is a pool bug) and with eval_one, across batch sizes
    // spanning serial, gather and packed regimes
    forall("pooled == scoped == eval_one", 0xC1, 24, arb_reducible,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let mut pooled = nl.simulator_with(SimOptions {
            threads: 2 + (seed % 3) as usize,
            mode: ThreadMode::Pooled,
            min_bitplane_batch: 1,
            ..Default::default()
        });
        let mut scoped = nl.simulator_with(SimOptions {
            threads: 2 + (seed % 3) as usize,
            mode: ThreadMode::Scoped,
            min_bitplane_batch: 1,
            ..Default::default()
        });
        let ow = nl.out_width();
        for batch in [1usize, 17 + (seed % 80) as usize,
                      301 + (seed % 700) as usize] {
            let x = random_inputs(seed ^ batch as u64, &nl, batch);
            let got_p = pooled.eval_batch(&x, batch);
            let got_s = scoped.eval_batch(&x, batch);
            if got_p != got_s {
                return Err(format!("batch {batch}: pooled != scoped"));
            }
            for b in 0..batch {
                let one = nl
                    .eval_one(&x[b * n_in..(b + 1) * n_in])
                    .map_err(|e| e.to_string())?;
                if got_p[b * ow..(b + 1) * ow] != one[..] {
                    return Err(format!("batch {batch}: row {b} differs \
                                        from eval_one"));
                }
            }
        }
        Ok(())
    });
}

/// Check `optimize(nl, level)` at every level against the *raw*
/// netlist's `eval_one`, via both `eval_batch` and a packed-kernel
/// simulator, on a batch size derived from the seed.
fn check_optimize_bit_exact(nl: &Netlist, seed: u64)
                            -> Result<(), String> {
    let ow = nl.out_width();
    for level in [OptLevel::None, OptLevel::Basic, OptLevel::Full] {
        let (opt, report) = optimize(nl, level);
        opt.validate().map_err(|e| e.to_string())?;
        if report.units_after > report.units_before {
            return Err(format!("{level}: optimizer grew the netlist"));
        }
        let mut batch = 1 + (seed % 120) as usize;
        if batch % 64 == 0 {
            batch += 1; // exercise packed tail words
        }
        let x = random_inputs(seed ^ 0xD1, nl, batch);
        let got = opt.eval_batch(&x, batch).map_err(|e| e.to_string())?;
        for b in 0..batch {
            let one = nl
                .eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])
                .map_err(|e| e.to_string())?;
            if got[b * ow..(b + 1) * ow] != one[..] {
                return Err(format!("{level}: row {b} differs"));
            }
        }
        // force the packed bit-plane machinery even at small batches
        let mut sim = opt.simulator_with(SimOptions {
            min_bitplane_batch: 1, ..Default::default()
        });
        if sim.eval_batch(&x, batch) != got {
            return Err(format!("{level}: packed simulator differs"));
        }
    }
    Ok(())
}

/// Check `compile(optimize(nl, level))` at every level against the
/// *raw* netlist's `eval_one` and `eval_batch`, across thread modes and
/// batch sizes that are not multiples of 64 — the compiled-plan
/// keystone: the whole raw -> optimized -> compiled chain is bit-exact.
fn check_compiled_plan_bit_exact(nl: &Netlist, seed: u64)
                                 -> Result<(), String> {
    let ow = nl.out_width();
    for level in [OptLevel::None, OptLevel::Basic, OptLevel::Full] {
        let (opt, _) = optimize(nl, level);
        let plan = Arc::new(compile(&opt, PlanOptions::default()));
        let threads = 2 + (seed % 3) as usize;
        let mut execs = [
            PlanExecutor::new(plan.clone()),
            PlanExecutor::with_options(plan.clone(), SimOptions {
                threads, mode: ThreadMode::Pooled,
                min_bitplane_batch: 1, ..Default::default()
            }),
            PlanExecutor::with_options(plan.clone(), SimOptions {
                threads, mode: ThreadMode::Scoped,
                min_bitplane_batch: 1, ..Default::default()
            }),
        ];
        let mut batch = 1 + (seed % 150) as usize;
        if batch % 64 == 0 {
            batch += 1; // exercise packed tail words
        }
        // the reference is the *interpreted* object-graph walk of the
        // *raw* netlist (`compiled: false`) — comparing the plan against
        // the default `eval_batch` would be circular now that it
        // compiles a plan itself
        let mut reference = nl.simulator_with(SimOptions {
            compiled: false, ..Default::default()
        });
        for batch in [1usize, batch, 301 + (seed % 700) as usize] {
            let x = random_inputs(seed ^ batch as u64, nl, batch);
            let want = reference.eval_batch(&x, batch);
            for (i, ex) in execs.iter_mut().enumerate() {
                let got = ex.eval_batch(&x, batch);
                if got != want {
                    return Err(format!(
                        "{level}: executor {i} differs at batch {batch}"));
                }
            }
            for b in 0..batch.min(8) {
                let one = nl
                    .eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])
                    .map_err(|e| e.to_string())?;
                if want[b * ow..(b + 1) * ow] != one[..] {
                    return Err(format!(
                        "{level}: row {b} differs from eval_one"));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn prop_compiled_plan_is_bit_exact_on_reducible_netlists() {
    forall("compile(optimize(n)) == eval_one (reducible)", 0xE1, 20,
           arb_reducible, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        check_compiled_plan_bit_exact(&nl, seed)
    });
}

#[test]
fn prop_compiled_plan_is_bit_exact_on_dense_netlists() {
    forall("compile(optimize(n)) == eval_one (dense)", 0xE2, 20,
           arb_shape, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        check_compiled_plan_bit_exact(&nl, seed)
    });
}

/// Wide vs scalar on one netlist: the scalar `PlanExecutor` (`W = 1`)
/// is the reference; `WidePlanExecutor` at W in {4, 8} must reproduce
/// its output bit-for-bit at ragged batch sizes spanning less than one
/// lane block (pure scalar tail), exact block multiples (no tail), and
/// several blocks plus a tail — up to 3 * 64 * W samples.
fn check_wide_matches_scalar(nl: &Netlist, seed: u64)
                             -> Result<(), String> {
    let plan = Arc::new(compile(nl, PlanOptions::default()));
    let mut scalar = PlanExecutor::new(plan.clone());
    let mut w4: WidePlanExecutor<4> = WidePlanExecutor::new(plan.clone());
    let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan);
    for batch in [1usize,
                  1 + (seed % 63) as usize,
                  64 * 4,          // exactly one W=4 lane block
                  64 * 4 + 7,      // one W=4 block + ragged tail
                  64 * 8 + 1,      // one W=8 block + one tail word
                  3 * 64 * 8 - 5,
                  3 * 64 * 8] {
        let x = random_inputs(seed ^ batch as u64, nl, batch);
        let want = scalar.eval_batch(&x, batch);
        if w4.eval_batch(&x, batch) != want {
            return Err(format!("W=4 differs at batch {batch}"));
        }
        if w8.eval_batch(&x, batch) != want {
            return Err(format!("W=8 differs at batch {batch}"));
        }
    }
    Ok(())
}

#[test]
fn prop_wide_executor_is_bit_exact() {
    // the wide-word keystone: every lane width is bit-exact with the
    // scalar reference on dense, reducible and optimized netlists —
    // the plans the serving path actually executes
    forall("wide executor == scalar", 0xE4, 8, arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let dense = random_netlist(seed, n_in, in_bits, shapes);
        check_wide_matches_scalar(&dense, seed)?;
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        check_wide_matches_scalar(&nl, seed)?;
        let (opt, _) = optimize(&nl, OptLevel::Full);
        check_wide_matches_scalar(&opt, seed ^ 0xE4)
    });
}

#[test]
fn prop_plan_cache_hit_is_equivalent_to_fresh_compile() {
    // a cached plan must answer exactly like a freshly compiled one,
    // and content-equal netlists (regardless of name) must share it
    let cache = PlanCache::new();
    forall("plan cache == fresh compile", 0xE3, 16, arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let cached = cache.get_or_compile(&nl, PlanOptions::default());
        let mut renamed = nl.clone();
        renamed.name = format!("alias{seed}");
        let alias = cache.get_or_compile(&renamed, PlanOptions::default());
        if !Arc::ptr_eq(&cached, &alias) {
            return Err("renamed content-equal netlist missed".into());
        }
        let fresh = Arc::new(compile(&nl, PlanOptions::default()));
        let batch = 1 + (seed % 70) as usize;
        let x = random_inputs(seed ^ 0xE3, &nl, batch);
        let mut ex_cached = PlanExecutor::new(cached);
        let mut ex_fresh = PlanExecutor::new(fresh);
        let a = ex_cached.eval_batch(&x, batch);
        let b = ex_fresh.eval_batch(&x, batch);
        if a != b {
            return Err("cached plan diverged from fresh compile".into());
        }
        Ok(())
    });
}

#[test]
fn prop_optimize_is_bit_exact_on_reducible_netlists() {
    // the optimizer keystone: for trained-like tables (pruned supports,
    // constant bits — the structure const-fold/dead-logic/CSE exploit)
    // the optimized netlist is bit-exact with the raw one at every
    // level, across seeds and batch sizes
    forall("optimize == eval_one (reducible)", 0xD1, default_cases(),
           arb_reducible, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        check_optimize_bit_exact(&nl, seed)
    });
}

#[test]
fn prop_optimize_is_bit_exact_on_dense_netlists() {
    // dense random tables leave little to fold, but dead units and
    // duplicate wiring still occur; bit-exactness must hold regardless
    forall("optimize == eval_one (dense)", 0xD2, default_cases(),
           arb_shape, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        check_optimize_bit_exact(&nl, seed)
    });
}

#[test]
fn prop_optimize_never_grows_the_mapped_design() {
    // the mapper on the optimized netlist can only get smaller: every
    // pass deletes units or projects tables (supports never grow)
    forall("mapper: optimized <= raw netlist", 0xD3, 32, arb_reducible,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let (opt, _) = optimize(&nl, OptLevel::Full);
        let a = map_netlist(&opt, true).total_luts();
        let b = map_netlist(&nl, true).total_luts();
        if a <= b {
            Ok(())
        } else {
            Err(format!("optimized {a} > raw {b}"))
        }
    });
}

#[test]
fn prop_optimized_timing_never_worse() {
    // the optimized mapping feeds the timing model: LUTs and registered
    // bits shrink pointwise per layer, so the reports can only improve
    forall("timing: optimized <= raw netlist", 0xD4, 24, arb_reducible,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let (opt, _) = optimize(&nl, OptLevel::Full);
        let m_raw = map_netlist(&nl, true);
        let m_opt = map_netlist(&opt, true);
        let dm = DelayModel::default();
        for strat in [Pipelining::EveryLayer, Pipelining::EveryK(3)] {
            let r = evaluate(&m_raw, strat, &dm);
            let o = evaluate(&m_opt, strat, &dm);
            if o.luts > r.luts {
                return Err(format!("{strat:?}: luts {} > {}", o.luts,
                                   r.luts));
            }
            if o.ffs > r.ffs {
                return Err(format!("{strat:?}: ffs {} > {}", o.ffs,
                                   r.ffs));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimized_rtl_roundtrip() {
    // the RTL emitter consumes the optimized netlist in the flow; the
    // parse-back check must hold on optimizer output too
    forall("rtl roundtrip on optimized netlists", 0xD5, 16,
           arb_reducible, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        let (opt, _) = optimize(&nl, OptLevel::Full);
        let text = rtl::emit(&opt, &rtl::RtlOptions {
            cuts: vec![],
            module_name: "opt_top".into(),
        });
        rtl::verify_roundtrip(&text, &opt).map_err(|e| e.to_string())
    });
}

#[test]
fn prop_simulator_outputs_in_code_range() {
    forall("outputs within out_bits", 0xA2, default_cases(), arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let x = random_inputs(seed ^ 2, &nl, 40);
        let out = nl.eval_batch(&x, 40).map_err(|e| e.to_string())?;
        let max = (1i32 << nl.out_bits()) - 1;
        if out.iter().all(|&c| c >= 0 && c <= max) {
            Ok(())
        } else {
            Err("code out of range".into())
        }
    });
}

#[test]
fn prop_rtl_roundtrip_any_netlist() {
    forall("rtl emit/parse roundtrip", 0xA3, 24, arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        // random register cuts
        let mut rng = Rng::new(seed ^ 3);
        let cuts: Vec<usize> =
            (0..nl.layers.len()).filter(|_| rng.bernoulli(0.5)).collect();
        let text = rtl::emit(&nl, &rtl::RtlOptions {
            cuts,
            module_name: "prop_top".into(),
        });
        rtl::verify_roundtrip(&text, &nl).map_err(|e| e.to_string())
    });
}

#[test]
fn prop_support_reduction_never_increases_cost() {
    forall("mapper: optimized <= worst case", 0xA4, default_cases(),
           arb_shape, |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let opt = map_netlist(&nl, true);
        let raw = map_netlist(&nl, false);
        if opt.total_luts() <= raw.total_luts() {
            Ok(())
        } else {
            Err(format!("{} > {}", opt.total_luts(), raw.total_luts()))
        }
    });
}

#[test]
fn prop_plut_cost_monotone_in_inputs() {
    for a in 1..14 {
        assert!(plut_cost(a) <= plut_cost(a + 1), "cost not monotone at {a}");
        assert!(plut_depth(a) <= plut_depth(a + 1) + 1e-9);
    }
}

#[test]
fn prop_more_pipeline_cuts_more_ffs_fewer_latency_per_stage() {
    forall("pipelining monotonicity", 0xA5, default_cases(), arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let m = map_netlist(&nl, true);
        let dm = DelayModel::default();
        let p1 = evaluate(&m, Pipelining::EveryLayer, &dm);
        let p3 = evaluate(&m, Pipelining::EveryK(3), &dm);
        let pc = evaluate(&m, Pipelining::None, &dm);
        if p3.ffs > p1.ffs {
            return Err("k=3 registered more bits than k=1".into());
        }
        if p3.stages > p1.stages {
            return Err("k=3 produced more stages".into());
        }
        if pc.stages != 1 {
            return Err("combinational must be 1 stage".into());
        }
        // single-stage clock can never beat the pipelined clock
        if pc.fmax_mhz > p1.fmax_mhz + 1e-9 {
            return Err("combinational fmax exceeded pipelined".into());
        }
        Ok(())
    });
}

#[test]
fn prop_truth_table_support_is_sound() {
    // perturbing a non-support address bit never changes the output
    forall("support soundness", 0xA6, default_cases(),
           |rng| {
               let fan_in = gen::usize_in(rng, 1, 3);
               let in_bits = gen::usize_in(rng, 1, 3);
               let entries = 1usize << (fan_in * in_bits);
               let t: Vec<u16> =
                   (0..entries).map(|_| rng.below(4) as u16).collect();
               (fan_in, in_bits, t)
           },
           |&(fan_in, in_bits, ref entries)| {
        let tt = TruthTable::new(fan_in, in_bits, 2, entries.clone())
            .map_err(|e| e.to_string())?;
        for bit in 0..2 {
            let support = tt.bit_support(bit);
            let f = tt.output_bit(bit);
            let a = tt.addr_bits();
            for v in 0..a {
                if support.contains(&v) {
                    continue;
                }
                let stride = 1usize << v;
                for base in 0..entries.len() {
                    if base & stride == 0 && f[base] != f[base | stride] {
                        return Err(format!("bit {v} outside support matters"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_top_f_selection_is_argmax_prefix() {
    forall("top-F == sorted prefix", 0xA7, default_cases(),
           |rng| {
               let p = gen::usize_in(rng, 4, 40);
               let f = gen::usize_in(rng, 1, p.min(8));
               let scores: Vec<f32> =
                   (0..p).map(|_| rng.range(0.0, 10.0)).collect();
               (f, scores)
           },
           |&(f, ref scores)| {
        let sel = pruning::select_top_f(&[scores.clone()], f);
        let min_sel = sel[0]
            .iter()
            .map(|&i| scores[i as usize])
            .fold(f32::MAX, f32::min);
        let max_unsel = (0..scores.len() as u32)
            .filter(|i| !sel[0].contains(i))
            .map(|i| scores[i as usize])
            .fold(f32::MIN, f32::max);
        if sel[0].len() != f {
            return Err("wrong cardinality".into());
        }
        if max_unsel > min_sel + 1e-6 {
            return Err("non-top element selected".into());
        }
        Ok(())
    });
}

#[test]
fn prop_server_answers_match_direct_eval_under_random_load() {
    use neuralut::coordinator::{InferenceServer, ServerConfig};
    use std::time::Duration;
    forall("server == direct", 0xA8, 8, arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let direct = nl.clone();
        let mut rng = Rng::new(seed ^ 9);
        let server = InferenceServer::start_single(nl, ServerConfig {
            max_batch: gen::usize_in(&mut rng, 1, 16),
            max_wait: Duration::from_micros(gen::usize_in(&mut rng, 10, 300) as u64),
            workers: gen::usize_in(&mut rng, 1, 3),
            sim_threads: gen::usize_in(&mut rng, 1, 2),
            ..ServerConfig::default()
        });
        let model = server.default_model().to_string();
        let n = gen::usize_in(&mut rng, 1, 60);
        let rows: Vec<Vec<i32>> = (0..n)
            .map(|i| random_inputs(seed ^ (100 + i as u64), &direct, 1))
            .collect();
        let got = server
            .infer_many(&model, rows.clone())
            .map_err(|e| e.to_string())?;
        server.shutdown();
        for (i, row) in rows.iter().enumerate() {
            let want = direct.eval_one(row).map_err(|e| e.to_string())?;
            if got[i] != want {
                return Err(format!("request {i} wrong"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nlb_roundtrip_is_canonical_and_bit_exact() {
    use neuralut::netlist::{read_nlb, write_nlb};
    // the artifact-format keystone: any valid netlist survives the
    // serialize -> validate -> load trip unchanged (canonical bytes),
    // and a shipped plan image answers exactly like the source netlist
    forall("nlb roundtrip (both plan options)", 0xF1, 20, arb_reducible,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_reducible_netlist(seed, n_in, in_bits, shapes, 6);
        // netlist-only: decode(encode(nl)) re-encodes byte-identically
        let plain = write_nlb(&nl, None).map_err(|e| e.to_string())?;
        let m = read_nlb(&plain).map_err(|e| e.to_string())?;
        if m.plan.is_some() {
            return Err("plan appeared from nowhere".into());
        }
        let again =
            write_nlb(&m.netlist, None).map_err(|e| e.to_string())?;
        if again != plain {
            return Err("re-encoding is not canonical".into());
        }
        // with a plan image, under both compile options
        let ow = nl.out_width();
        for bitplane in [true, false] {
            let plan = compile(&nl, PlanOptions { bitplane });
            let bytes =
                write_nlb(&nl, Some(&plan)).map_err(|e| e.to_string())?;
            let m = read_nlb(&bytes).map_err(|e| e.to_string())?;
            let loaded = m.plan.ok_or("plan image missing after load")?;
            if loaded.key() != plan.key() {
                return Err("plan key changed in flight".into());
            }
            let batch = 1 + (seed % 90) as usize;
            let x = random_inputs(seed ^ bitplane as u64, &nl, batch);
            let got = PlanExecutor::new(loaded).eval_batch(&x, batch);
            for b in 0..batch {
                let one = nl
                    .eval_one(&x[b * n_in..(b + 1) * n_in])
                    .map_err(|e| e.to_string())?;
                if got[b * ow..(b + 1) * ow] != one[..] {
                    return Err(format!(
                        "bitplane={bitplane}: row {b} differs"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nlb_rejects_any_single_byte_corruption_or_accepts_equivalent() {
    use neuralut::netlist::{read_nlb, write_nlb};
    // flipping any single byte either fails cleanly or yields a model
    // whose netlist still matches its own (rewritten) hashes — i.e. the
    // reader never panics and never silently accepts corrupt content
    forall("nlb single-byte corruption", 0xF2, 12, arb_shape,
           |&(seed, n_in, in_bits, ref shapes)| {
        let nl = random_netlist(seed, n_in, in_bits, shapes);
        let bytes = write_nlb(&nl, None).map_err(|e| e.to_string())?;
        let mut rng = Rng::new(seed ^ 0xF2);
        for _ in 0..32 {
            let mut evil = bytes.clone();
            let at = rng.below(evil.len());
            evil[at] ^= 1 << rng.below(8);
            // must not panic; when the header is untouched the checksum
            // catches payload flips, so Ok is only reachable when the
            // flip landed in the header's own hash fields and collided
            // — astronomically unlikely, treat it as corruption missed
            if read_nlb(&evil).is_ok() && evil != bytes {
                return Err(format!("byte {at} corruption accepted"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_consistency_rust_side() {
    // Dataset::encode_features must agree with the midrise decode used by
    // the baselines (encode(decode(c)) == c), for all betas in use.
    forall("rust encode/decode roundtrip", 0xA9, default_cases(),
           |rng| gen::usize_in(rng, 1, 8),
           |&beta| {
        let levels = 1i64 << beta;
        for c in 0..levels {
            let v = ((2 * c + 1) as f32 / levels as f32) - 1.0;
            let back = neuralut::dataset::Dataset::encode_features(&[v], beta);
            if back[0] as i64 != c {
                return Err(format!("beta {beta} code {c} -> {}", back[0]));
            }
        }
        Ok(())
    });
}
