//! Failure-lattice battery for the zero-copy mapped artifact loader.
//!
//! The mapped path borrows plan arenas straight out of `mmap`ed `.nlb`
//! and `.plan` files, so the loader's contract under hostile input is
//! load-bearing: every truncation, corruption, misalignment or
//! foreign-endian marker must either produce a descriptive error or
//! fall back to the copying decoder — never UB, never a panic.  The
//! same lattice runs against v1 (unpadded, copy-only) files to prove
//! the back-compat read is just as total.  The tail of the file proves
//! the *success* path end-to-end: a mapped artifact serves bit-exactly
//! through every executor width and over TCP.

use std::path::PathBuf;
use std::sync::Arc;

use neuralut::coordinator::{check_conformance, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::net::{NetConfig, NetServer, RemoteEngine};
use neuralut::netlist::testutil::{random_netlist, write_nlb_v1};
use neuralut::netlist::{load_nlb, load_nlb_mapped, read_nlb, write_nlb,
                        LaneExecutor, Netlist, PlanExecutor, PlanOptions,
                        SimOptions, WidePlanExecutor};
use neuralut::util::Rng;

/// Whether this host satisfies the zero-copy preconditions (the mapped
/// loader exists everywhere; *borrowing* needs unix + 64-bit +
/// little-endian, everything else falls back to copying).
fn host_maps() -> bool {
    cfg!(all(unix, target_pointer_width = "64", target_endian = "little"))
}

fn temp_path(tag: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("nlb_lattice_{}_{tag}.nlb", std::process::id()));
    p
}

/// Small netlist + its v2 artifact bytes (with a compiled-plan image —
/// the section the mapped loader actually borrows from).
fn artifact(seed: u64) -> (Netlist, Vec<u8>) {
    let nl = random_netlist(seed, 8, 1, &[(5, 2, 2), (3, 2, 2)]);
    let plan = nl.compile_plan(PlanOptions::default());
    let bytes = write_nlb(&nl, Some(&plan)).unwrap();
    (nl, bytes)
}

/// Write `bytes` to a temp file and run the mapped loader on it.
fn mapped_load(tag: &str, bytes: &[u8])
               -> anyhow::Result<neuralut::netlist::NlbModel> {
    let path = temp_path(tag);
    std::fs::write(&path, bytes).unwrap();
    let r = load_nlb_mapped(&path);
    let _ = std::fs::remove_file(&path);
    r
}

#[test]
fn every_truncation_errors_cleanly() {
    let (_nl, bytes) = artifact(301);
    // the copying decoder sees every possible prefix...
    for cut in 0..bytes.len() {
        assert!(read_nlb(&bytes[..cut]).is_err(),
                "truncation to {cut}/{} bytes parsed", bytes.len());
    }
    // ...and the mapped loader a sampled lattice of them (file + mmap
    // per probe), always including the header/payload/image boundaries
    let mut cuts: Vec<usize> = (0..bytes.len()).step_by(17).collect();
    cuts.extend([0, 1, 31, 32, 33, bytes.len() - 1]);
    for cut in cuts {
        assert!(mapped_load("trunc", &bytes[..cut]).is_err(),
                "mapped truncation to {cut}/{} bytes parsed",
                bytes.len());
    }
}

#[test]
fn v1_truncations_error_cleanly_too() {
    let nl = random_netlist(302, 8, 1, &[(5, 2, 2), (3, 2, 2)]);
    let plan = nl.compile_plan(PlanOptions::default());
    let bytes = write_nlb_v1(&nl, Some(&plan)).unwrap();
    assert_eq!(bytes[4], 1, "fixture must be a v1 file");
    for cut in 0..bytes.len() {
        assert!(read_nlb(&bytes[..cut]).is_err(),
                "v1 truncation to {cut}/{} bytes parsed", bytes.len());
    }
    for cut in (0..bytes.len()).step_by(23) {
        assert!(mapped_load("trunc_v1", &bytes[..cut]).is_err());
    }
}

/// 32 random single-byte corruptions per fixture: each must either be
/// rejected or decode to a model bit-identical to the original (a flip
/// can land in a byte the format legitimately tolerates only if it
/// changes nothing observable).  Both decoders, never a panic.
fn corruption_lattice(tag: &str, nl: &Netlist, bytes: &[u8], seed: u64) {
    let reference = {
        let m = read_nlb(bytes).unwrap();
        assert_eq!(m.netlist.content_hash(), nl.content_hash());
        m
    };
    let mut rng = Rng::new(seed);
    for case in 0..32 {
        let pos = rng.below(bytes.len());
        let flip = 1u8 << rng.below(8);
        let mut bad = bytes.to_vec();
        bad[pos] ^= flip;
        for (which, result) in [("copying", read_nlb(&bad)),
                                ("mapped", mapped_load(tag, &bad))] {
            match result {
                Err(_) => {}
                Ok(m) => {
                    assert_eq!(
                        m.netlist.content_hash(),
                        reference.netlist.content_hash(),
                        "{which} decoder accepted corruption case \
                         {case} (byte {pos} ^ {flip:#04x}) as a \
                         *different* model");
                    let x = neuralut::netlist::testutil::random_inputs(
                        seed ^ 0xC0DE, &m.netlist, 4);
                    for b in 0..4 {
                        let row = &x[b * nl.n_in..(b + 1) * nl.n_in];
                        assert_eq!(m.netlist.eval_one(row).unwrap(),
                                   nl.eval_one(row).unwrap(),
                                   "{which} decoder, case {case}: \
                                    accepted model diverges");
                    }
                }
            }
        }
    }
}

#[test]
fn single_byte_corruptions_are_rejected_or_harmless() {
    let (nl, bytes) = artifact(303);
    corruption_lattice("corrupt_v2", &nl, &bytes, 404);
}

#[test]
fn plan_free_corruptions_are_rejected_or_harmless() {
    let nl = random_netlist(304, 8, 1, &[(5, 2, 2), (3, 2, 2)]);
    let bytes = write_nlb(&nl, None).unwrap();
    corruption_lattice("corrupt_noplan", &nl, &bytes, 405);
}

#[test]
fn v1_corruptions_are_rejected_or_harmless() {
    let nl = random_netlist(305, 8, 1, &[(5, 2, 2), (3, 2, 2)]);
    let plan = nl.compile_plan(PlanOptions::default());
    let bytes = write_nlb_v1(&nl, Some(&plan)).unwrap();
    corruption_lattice("corrupt_v1", &nl, &bytes, 406);
}

#[test]
fn foreign_endian_count_fields_are_rejected_cleanly() {
    let (_nl, bytes) = artifact(306);
    // byte-swap the first payload u32 (the name length) as a
    // big-endian writer would have encoded it, then re-seal the
    // payload checksum so only the *semantic* checks can object — the
    // reader must still reject (the count no longer matches the
    // payload), not trust the foreign encoding
    let mut bad = bytes.clone();
    bad[32..36].reverse();
    let fnv = fnv1a(&bad[32..]);
    bad[24..32].copy_from_slice(&fnv.to_le_bytes());
    assert!(read_nlb(&bad).is_err(), "byte-swapped count parsed");
    assert!(mapped_load("endian", &bad).is_err());
}

/// FNV-1a mirror of the format's payload checksum (the crate keeps its
/// own private; the test re-seals tampered payloads with it).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[test]
fn v1_files_with_plans_take_the_copying_fallback() {
    // v1 has no alignment padding, so the mapped loader must not
    // borrow from it — the fall-back arm of the lattice
    let nl = random_netlist(307, 8, 1, &[(5, 2, 2), (3, 2, 2)]);
    let plan = nl.compile_plan(PlanOptions::default());
    let bytes = write_nlb_v1(&nl, Some(&plan)).unwrap();
    let m = mapped_load("v1_fallback", &bytes).unwrap();
    let p = m.plan.expect("fixture carries a plan image");
    assert!(!p.is_mapped(), "v1 file must load via the copying read");
    let x = neuralut::netlist::testutil::random_inputs(307, &nl, 6);
    let mut ex = PlanExecutor::new(Arc::new(p));
    check_conformance(&mut ex, &nl, 87).unwrap();
    for b in 0..6 {
        let row = &x[b * nl.n_in..(b + 1) * nl.n_in];
        assert_eq!(m.netlist.eval_one(row).unwrap(),
                   nl.eval_one(row).unwrap());
    }
}

#[test]
fn mapped_artifact_conforms_at_every_lane_width() {
    let (nl, bytes) = artifact(308);
    let path = temp_path("conform");
    std::fs::write(&path, &bytes).unwrap();
    let m = load_nlb_mapped(&path).unwrap();
    let plan = Arc::new(m.plan.expect("fixture carries a plan image"));
    assert_eq!(plan.is_mapped(), host_maps(),
               "zero-copy load expected iff the host supports it");
    let mut w1 = PlanExecutor::new(plan.clone());
    check_conformance(&mut w1, &nl, 81).unwrap();
    let mut w4: WidePlanExecutor<4> = WidePlanExecutor::new(plan.clone());
    check_conformance(&mut w4, &nl, 82).unwrap();
    let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan.clone());
    check_conformance(&mut w8, &nl, 83).unwrap();
    for width in [1usize, 4, 8] {
        let mut ex = LaneExecutor::for_width(width, plan.clone(),
                                             SimOptions::default());
        check_conformance(&mut ex, &nl, 84).unwrap();
    }
    // the copying loader agrees with the mapped one bit-for-bit
    let copied = load_nlb(&path).unwrap();
    let cp = copied.plan.expect("copying load keeps the plan");
    assert!(!cp.is_mapped());
    let mut ex = PlanExecutor::new(Arc::new(cp));
    check_conformance(&mut ex, &nl, 85).unwrap();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mapped_artifact_serves_bit_exactly_over_tcp() {
    let (nl, bytes) = artifact(309);
    let path = temp_path("tcp");
    std::fs::write(&path, &bytes).unwrap();
    let m = load_nlb_mapped(&path).unwrap();
    assert_eq!(m.plan.as_ref().map(|p| p.is_mapped()), Some(host_maps()));
    let mut registry = ModelRegistry::new();
    registry.register_artifact("mapped", m);
    let server = InferenceServer::start(registry, ServerConfig {
        max_batch: 16,
        ..ServerConfig::default()
    });
    let net = NetServer::bind(server, "127.0.0.1:0",
                              NetConfig::default()).unwrap();
    let mut remote = RemoteEngine::open(net.local_addr(), "mapped")
        .unwrap();
    check_conformance(&mut remote, &nl, 86).unwrap();
    net.shutdown();
    let _ = std::fs::remove_file(&path);
}
