//! Engine-conformance suite: every `InferenceEngine` backend — direct
//! simulator (serial / pooled / scoped threads), the batching server,
//! and the multi-model registry — must pass the same contract
//! (`check_conformance`: shape, bit-exactness vs `eval_one`,
//! determinism, width rejection).  Plus serving stress tests: shutdown
//! under concurrent client load must join promptly without dropping
//! in-flight answers.

use std::sync::Arc;
use std::time::{Duration, Instant};

use neuralut::coordinator::{check_conformance, BatchPolicy,
                            InferenceEngine, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::netlist::testutil::{random_inputs, random_netlist,
                                  random_reducible_netlist};
use neuralut::netlist::{optimize, OptLevel, PlanCache, PlanExecutor,
                        PlanOptions, SimOptions, ThreadMode};

#[test]
fn conformance_direct_simulator() {
    let nl = random_netlist(61, 14, 1, &[(10, 3, 2), (5, 2, 2), (3, 2, 3)]);
    let mut sim = nl.simulator();
    check_conformance(&mut sim, &nl, 61).unwrap();
}

#[test]
fn conformance_pooled_threads_simulator() {
    let nl = random_reducible_netlist(
        62, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
    let mut sim = nl.simulator_with(SimOptions {
        threads: 4,
        mode: ThreadMode::Pooled,
        min_bitplane_batch: 1,
        ..Default::default()
    });
    check_conformance(&mut sim, &nl, 62).unwrap();
    assert!(sim.describe().contains("Pooled"));
}

#[test]
fn conformance_scoped_threads_simulator() {
    let nl = random_reducible_netlist(
        63, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
    let mut sim = nl.simulator_with(SimOptions {
        threads: 4,
        mode: ThreadMode::Scoped,
        min_bitplane_batch: 1,
        ..Default::default()
    });
    check_conformance(&mut sim, &nl, 63).unwrap();
    assert!(sim.describe().contains("Scoped"));
}

#[test]
fn conformance_interpreted_simulator() {
    // the reference walk stays a first-class backend
    let nl = random_reducible_netlist(
        68, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
    let mut sim = nl.simulator_with(SimOptions {
        compiled: false,
        ..Default::default()
    });
    check_conformance(&mut sim, &nl, 68).unwrap();
    assert!(sim.describe().contains("interpreted"));
}

#[test]
fn conformance_plan_executor_serial_and_threaded() {
    // the compiled plan is the serving execution model: a shared plan
    // driven by serial, pooled and scoped executors must all satisfy
    // the engine contract
    let nl = random_reducible_netlist(
        69, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
    let cache = PlanCache::new();
    let plan = cache.get_or_compile(&nl, PlanOptions::default());
    let mut serial = PlanExecutor::new(plan.clone());
    check_conformance(&mut serial, &nl, 69).unwrap();
    let mut pooled = PlanExecutor::with_options(plan.clone(), SimOptions {
        threads: 4,
        mode: ThreadMode::Pooled,
        min_bitplane_batch: 1,
        ..Default::default()
    });
    check_conformance(&mut pooled, &nl, 70).unwrap();
    let mut scoped = PlanExecutor::with_options(plan, SimOptions {
        threads: 4,
        mode: ThreadMode::Scoped,
        min_bitplane_batch: 1,
        ..Default::default()
    });
    check_conformance(&mut scoped, &nl, 71).unwrap();
    // all three executors ran the same compiled artifact
    assert_eq!(cache.len(), 1);
}

#[test]
fn conformance_wide_plan_executors_every_width() {
    // the wide-word backends are engines in their own right: every
    // supported lane width must pass the same contract as the scalar
    // reference, on an optimized netlist (the plans serving ships)
    use neuralut::netlist::{LaneExecutor, WidePlanExecutor};
    let nl = random_reducible_netlist(
        76, 20, 2, &[(40, 3, 2), (24, 2, 2), (6, 2, 2)], 6);
    let (opt, _) = optimize(&nl, OptLevel::Full);
    let plan = Arc::new(opt.compile_plan(PlanOptions::default()));
    let mut w4: WidePlanExecutor<4> = WidePlanExecutor::new(plan.clone());
    check_conformance(&mut w4, &opt, 76).unwrap();
    let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan.clone());
    check_conformance(&mut w8, &opt, 77).unwrap();
    // and through the runtime-selected wrapper, at every width
    for width in [1usize, 4, 8] {
        let mut ex = LaneExecutor::for_width(width, plan.clone(),
                                             SimOptions::default());
        check_conformance(&mut ex, &opt, 78 + width as u64).unwrap();
        assert_eq!(ex.width(), width);
    }
}

#[test]
fn conformance_plan_of_optimized_netlist() {
    // the exact serving chain: optimize, compile, execute — conformance
    // against the optimized netlist and bit-exactness against the raw
    let nl = random_reducible_netlist(
        74, 20, 2, &[(40, 3, 2), (24, 2, 2), (6, 2, 2)], 6);
    let (opt, _) = optimize(&nl, OptLevel::Full);
    let plan = std::sync::Arc::new(opt.compile_plan(PlanOptions::default()));
    let mut ex = PlanExecutor::new(plan);
    check_conformance(&mut ex, &opt, 74).unwrap();
    let batch = 97;
    let x = random_inputs(75, &nl, batch);
    let got = ex.eval_batch(&x, batch);
    let ow = nl.out_width();
    for b in 0..batch {
        let want = nl.eval_one(&x[b * 20..(b + 1) * 20]).unwrap();
        assert_eq!(&got[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
}

#[test]
fn conformance_optimized_netlist_simulator() {
    // the serving path compiles *optimized* netlists: the simulator on
    // optimizer output must satisfy the full engine contract, and must
    // still agree with the raw netlist's reference evaluation
    let nl = random_reducible_netlist(
        66, 20, 2, &[(40, 3, 2), (24, 2, 2), (6, 2, 2)], 6);
    let (opt, report) = optimize(&nl, OptLevel::Full);
    assert!(report.units_after <= report.units_before);
    let mut sim = opt.simulator_with(SimOptions {
        threads: 2,
        min_bitplane_batch: 1,
        ..Default::default()
    });
    check_conformance(&mut sim, &opt, 66).unwrap();
    let batch = 97;
    let x = random_inputs(67, &nl, batch);
    let got = sim.eval_batch(&x, batch);
    let ow = nl.out_width();
    for b in 0..batch {
        let want = nl.eval_one(&x[b * 20..(b + 1) * 20]).unwrap();
        assert_eq!(&got[b * ow..(b + 1) * ow], &want[..], "row {b}");
    }
}

#[test]
fn conformance_batching_server() {
    let nl = random_netlist(64, 9, 1, &[(6, 3, 2), (3, 2, 2)]);
    let server = InferenceServer::start_single(
        nl.clone(),
        ServerConfig { max_batch: 16, max_wait: Duration::from_micros(100),
                       workers: 2, sim_threads: 1,
                       ..ServerConfig::default() },
    );
    let mut engine = server.engine(server.default_model()).unwrap();
    check_conformance(&mut engine, &nl, 64).unwrap();
    server.shutdown();
}

#[test]
fn conformance_multi_model_registry() {
    // three models with distinct shapes behind one server: each hosted
    // engine must satisfy the same contract as a dedicated process, and
    // the per-model statistics must stay independent
    let nls = [
        random_netlist(71, 12, 1, &[(8, 3, 2), (4, 2, 2)]),
        random_netlist(72, 6, 2, &[(5, 2, 3), (3, 2, 2)]),
        random_reducible_netlist(73, 16, 2, &[(24, 3, 2), (8, 2, 2)], 6),
    ];
    let names = ["alpha", "beta", "gamma"];
    let mut registry = ModelRegistry::new();
    for (name, nl) in names.iter().zip(nls.iter()) {
        registry.register_with(
            name,
            nl.clone(),
            Some(BatchPolicy { max_batch: 8,
                               max_wait: Duration::from_micros(80) }),
        );
    }
    let server = InferenceServer::start(
        registry,
        ServerConfig { workers: 2, sim_threads: 2,
                       ..ServerConfig::default() },
    );
    assert_eq!(server.models(), names.iter().map(|s| s.to_string())
                                     .collect::<Vec<_>>());
    for (i, (name, nl)) in names.iter().zip(nls.iter()).enumerate() {
        let mut engine = server.engine(name).unwrap();
        check_conformance(&mut engine, nl, 80 + i as u64).unwrap();
    }
    // conformance drove 1+5+64+130 (+2 deterministic re-runs of each)
    // requests per model; stats must be per-model, not pooled
    let per_model = (1 + 5 + 64 + 130) * 2;
    for name in names {
        let st = server.model_stats(name).unwrap();
        assert_eq!(st.requests, per_model as u64, "model {name}");
        assert!(st.batches > 0 && st.max_batch_seen <= 8, "model {name}");
        assert!(st.latency.p50 <= st.latency.p99
                && st.latency.p99 <= st.latency.p999, "model {name}");
    }
    assert!(server.engine("delta").is_err(), "unknown model must error");
    server.shutdown();
}

#[test]
fn shutdown_under_concurrent_load() {
    // clients hammer the server from several threads while the main
    // thread shuts it down: every accepted request must be answered
    // correctly, every rejected one must fail with an error (never hang,
    // never a wrong answer), and shutdown must join promptly
    let nl = random_netlist(91, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
    let direct = nl.clone();
    let server = Arc::new(InferenceServer::start_single(
        nl,
        ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100),
                       workers: 3, sim_threads: 1,
                       ..ServerConfig::default() },
    ));
    let model = server.default_model().to_string();
    let n_clients = 4;
    let per_client = 400;
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let server = server.clone();
        let model = model.clone();
        let direct = direct.clone();
        clients.push(std::thread::spawn(move || {
            let x = random_inputs(91 + c as u64, &direct, per_client);
            let mut answered = 0usize;
            let mut rejected = 0usize;
            for i in 0..per_client {
                let row = x[i * 8..(i + 1) * 8].to_vec();
                match server.infer(&model, row.clone()) {
                    Ok(got) => {
                        assert_eq!(got, direct.eval_one(&row).unwrap(),
                                   "client {c} request {i}");
                        answered += 1;
                    }
                    Err(_) => rejected += 1,
                }
            }
            (answered, rejected)
        }));
    }
    // let traffic build up, then pull the plug mid-stream
    std::thread::sleep(Duration::from_millis(20));
    let t = Instant::now();
    server.shutdown();
    assert!(t.elapsed() < Duration::from_secs(5), "shutdown hung");
    let mut answered = 0;
    let mut rejected = 0;
    for h in clients {
        let (a, r) = h.join().expect("client panicked");
        answered += a;
        rejected += r;
    }
    assert_eq!(answered + rejected, n_clients * per_client);
    assert!(answered > 0, "no request was served before shutdown");
    // post-shutdown submissions must be rejected, not hang
    assert!(server.infer(&model, vec![0; 8]).is_err());
}

#[test]
fn conformance_tcp_remote_engine_on_artifact_matches_plan_executor() {
    // the full deployment chain: export an `.nlb` artifact, load it
    // into a server, expose it over TCP, and hold the remote engine to
    // the exact same contract as the in-process executor of the same
    // artifact — the wire adds frames, never bits
    use neuralut::net::{NetConfig, NetServer, RemoteEngine};
    use neuralut::netlist::{load_nlb, save_nlb};

    let nl = random_netlist(96, 8, 1, &[(6, 3, 2), (4, 2, 2)]);
    let plan = nl.compile_plan(PlanOptions::default());
    let path = std::env::temp_dir().join(format!(
        "nid_net_artifact_{}.nlb", std::process::id()));
    save_nlb(&path, &nl, Some(&plan)).unwrap();
    let model = load_nlb(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // in-process reference: the artifact's own plan image
    let image = model.plan.clone().expect("artifact carries a plan");
    let mut local = PlanExecutor::new(image);

    let mut registry = ModelRegistry::new();
    registry.register_artifact("art", model);
    let server = InferenceServer::start(registry, ServerConfig::default());
    let net = NetServer::bind(server, "127.0.0.1:0",
                              NetConfig::default()).unwrap();
    let mut remote = RemoteEngine::open(net.local_addr(), "art").unwrap();

    // the remote engine satisfies the engine contract end to end
    // (shape, bit-exactness vs eval_one, determinism, rejection)
    check_conformance(&mut remote, &nl, 96).unwrap();

    // and answers bit-exactly what the in-process executor answers
    for batch in [1usize, 7, 64, 129] {
        let x = random_inputs(97 ^ batch as u64, &nl, batch);
        let want = local.run_batch(&x, batch).unwrap();
        let got = remote.run_batch(&x, batch).unwrap();
        assert_eq!(got, want, "batch {batch}: TCP differs from local");
    }
    net.shutdown();
}

#[test]
fn conformance_tcp_remote_engine_on_wide_lane_server() {
    // a server pinned to W=4 workers serves over TCP: the remote engine
    // must satisfy the same contract as against scalar workers, and the
    // wire-visible stats must name the wide backend per model
    use neuralut::net::{Client, NetConfig, NetServer, RemoteEngine};
    use neuralut::netlist::LaneSelect;
    use neuralut::util::Json;

    let nl = random_netlist(98, 8, 1, &[(6, 3, 2), (4, 2, 2)]);
    let mut registry = ModelRegistry::new();
    registry.register("wide", nl.clone());
    let server = InferenceServer::start(
        registry,
        ServerConfig { lanes: LaneSelect::W4, ..ServerConfig::default() });
    assert_eq!(server.model_lane_width("wide").unwrap(), 4);
    let net = NetServer::bind(server, "127.0.0.1:0",
                              NetConfig::default()).unwrap();
    let mut remote = RemoteEngine::open(net.local_addr(), "wide").unwrap();
    check_conformance(&mut remote, &nl, 98).unwrap();
    let mut c = Client::connect(net.local_addr()).unwrap();
    let doc = Json::parse(&c.stats("wide").unwrap()).unwrap();
    let models = doc.at("models").unwrap().as_arr().unwrap();
    assert_eq!(models[0].at("backend").unwrap().as_str().unwrap(),
               "plan-w4");
    assert_eq!(models[0].at("lane_width").unwrap().as_usize().unwrap(),
               4);
    net.shutdown();
}

#[test]
fn server_requests_after_engine_use_still_route() {
    // an engine view and direct infer calls share the same router
    let nl = random_netlist(95, 6, 1, &[(4, 2, 2), (2, 2, 2)]);
    let direct = nl.clone();
    let server = InferenceServer::start_single(nl, ServerConfig::default());
    let model = server.default_model().to_string();
    let x = random_inputs(95, &direct, 12);
    let mut engine = server.engine(&model).unwrap();
    let got = engine.run_batch(&x, 12).unwrap();
    let ow = engine.out_width();
    for b in 0..12 {
        let want = direct.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
        assert_eq!(&got[b * ow..(b + 1) * ow], &want[..], "row {b}");
        let one = server
            .infer(&model, x[b * 6..(b + 1) * 6].to_vec())
            .unwrap();
        assert_eq!(one, want, "direct infer row {b}");
    }
    server.shutdown();
}
