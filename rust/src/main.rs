//! `neuralut` — CLI for the NeuraLUT-Assemble toolflow.
//!
//! Subcommands:
//!   list                      show compiled configurations
//!   flow   --config <name>    run the full toolflow (train → LUTs → timing)
//!   rtl    --config <name>    run the flow and write Verilog
//!   serve  --config <name>    train, extract netlist, run the batch server
//!
//! Common flags: --steps N --dense-steps N --train N --test N --seed N
//!               --no-skips --random-conn --augment --artifacts DIR

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions, InferenceServer, ServerConfig};
use neuralut::report::{pct, sci, Table};
use neuralut::runtime::Runtime;
use neuralut::util::Stopwatch;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    let mut switches = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            match name {
                "no-skips" | "random-conn" | "augment" | "verify" | "quiet" => {
                    switches.push(name.to_string());
                }
                _ => {
                    let v = it.next().with_context(|| format!("--{name} needs a value"))?;
                    flags.insert(name.to_string(), v);
                }
            }
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(Args { cmd, flags, switches })
}

impl Args {
    fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
    fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

fn flow_options(args: &Args) -> Result<FlowOptions> {
    let config = args
        .flags
        .get("config")
        .context("--config <name> is required")?
        .clone();
    let mut opts = FlowOptions::quick(&config);
    opts.dense_steps = args.usize_flag("dense-steps", opts.dense_steps)?;
    opts.sparse_steps = args.usize_flag("steps", opts.sparse_steps)?;
    opts.seed = args.usize_flag("seed", opts.seed as usize)? as u64;
    opts.gen.n_train = args.usize_flag("train", opts.gen.n_train)?;
    opts.gen.n_test = args.usize_flag("test", opts.gen.n_test)?;
    opts.gen.augment = args.has("augment");
    if args.has("no-skips") {
        opts.skip_scale = 0.0;
    }
    if args.has("random-conn") {
        opts.dense_steps = 0;
    }
    Ok(opts)
}

fn meta_from(args: &Args) -> Result<Meta> {
    let dir = args
        .flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Meta::default_dir);
    Meta::load(dir)
}

fn cmd_list(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let mut t = Table::new("compiled configurations",
                           &["config", "dataset", "layers w", "F", "beta", "L-LUTs"]);
    for (name, cfg) in &meta.configs {
        let top = &cfg.topology;
        t.row(&[
            name.clone(),
            top.dataset.clone(),
            format!("{:?}", top.w),
            format!("{:?}", top.f),
            format!("{:?}", top.beta),
            top.total_units().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn print_flow_result(r: &neuralut::coordinator::FlowResult) {
    let mut t = Table::new(
        &format!("toolflow result: {}", r.config),
        &["metric", "value"],
    );
    t.row(&["QAT accuracy".into(), pct(r.qat_acc)]);
    t.row(&["netlist accuracy".into(), pct(r.netlist_acc)]);
    if let Some(be) = r.bit_exact {
        t.row(&["netlist == PJRT (bit-exact)".into(), be.to_string()]);
    }
    t.row(&["L-LUTs".into(), r.netlist.total_units().to_string()]);
    t.row(&["P-LUTs (mapped)".into(), r.mapped.total_luts().to_string()]);
    for (name, rep) in &r.reports {
        t.row(&[format!("{name} Fmax"), format!("{:.0} MHz", rep.fmax_mhz)]);
        t.row(&[format!("{name} latency"), format!("{:.2} ns", rep.latency_ns)]);
        t.row(&[format!("{name} FFs"), rep.ffs.to_string()]);
        t.row(&[format!("{name} area-delay"), sci(rep.area_delay)]);
    }
    t.print();
}

fn cmd_flow(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let sw = Stopwatch::start();
    let r = run_flow(&rt, &meta, &opts)?;
    print_flow_result(&r);
    println!("\nflow completed in {:.1}s", sw.secs());
    Ok(())
}

fn cmd_rtl(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let mut opts = flow_options(args)?;
    opts.emit_rtl = true;
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.v", opts.config));
    let r = run_flow(&rt, &meta, &opts)?;
    let text = r.rtl_text.as_ref().context("no RTL emitted")?;
    std::fs::write(&out, text)?;
    print_flow_result(&r);
    println!("\nwrote {} ({} lines)", out, text.lines().count());
    Ok(())
}

/// Run the flow, then print netlist-level statistics: per-layer support
/// histograms, constant/duplicate units — the signals the mapper's
/// synthesis-style optimizations exploit.
fn cmd_inspect(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let r = run_flow(&rt, &meta, &opts)?;
    let mut t = Table::new(
        &format!("netlist inspection: {}", r.config),
        &["layer", "units", "addr bits", "avg support", "const bits",
          "dup units", "P-LUTs"],
    );
    for (l, layer) in r.netlist.layers.iter().enumerate() {
        let mut support_sum = 0usize;
        let mut bits = 0usize;
        let mut consts = 0usize;
        let mut seen = std::collections::HashSet::new();
        let mut dups = 0usize;
        for u in 0..layer.w {
            let tt = layer.truth_table(u);
            for b in 0..layer.out_bits {
                bits += 1;
                if tt.bit_constant(b).is_some() {
                    consts += 1;
                } else {
                    support_sum += tt.bit_support(b).len();
                }
            }
            if !seen.insert((layer.unit_conn(u).to_vec(),
                             layer.unit_table(u).to_vec())) {
                dups += 1;
            }
        }
        t.row(&[
            l.to_string(),
            layer.w.to_string(),
            (layer.in_bits * layer.fan_in).to_string(),
            format!("{:.2}", support_sum as f64 / bits.max(1) as f64),
            consts.to_string(),
            dups.to_string(),
            r.mapped.layers[l].luts.to_string(),
        ]);
    }
    t.print();
    println!("\ntotal P-LUTs {} (worst case {})",
             r.mapped.total_luts(), r.mapped.total_luts_worst_case());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let n_req = args.usize_flag("requests", 2000)?;
    let r = run_flow(&rt, &meta, &opts)?;
    print_flow_result(&r);

    let top = &meta.config(&opts.config)?.topology;
    let splits = neuralut::dataset::generate(&top.dataset, top.beta_in, &opts.gen)?;
    {
        let sim = r.netlist.simulator();
        println!("simulator kernels: {}/{} layers bit-plane",
                 sim.bitplane_layers(), r.netlist.layers.len());
    }
    let cfg = ServerConfig {
        max_batch: args.usize_flag("max-batch", 64)?,
        workers: args.usize_flag("workers", 2)?,
        sim_threads: args.usize_flag("sim-threads", 1)?,
        ..ServerConfig::default()
    };
    let server = InferenceServer::start(r.netlist.clone(), cfg);
    let sw = Stopwatch::start();
    let rows: Vec<Vec<i32>> = (0..n_req)
        .map(|i| splits.test.row(i % splits.test.n).to_vec())
        .collect();
    let _ = server.infer_many(rows)?;
    let secs = sw.secs();
    let (reqs, batches, mean, p99) = server.stats();
    println!(
        "\nserved {reqs} requests in {batches} batches: {:.0} req/s, \
         latency mean {:.0}us p99 {:.0}us",
        reqs as f64 / secs, mean, p99
    );
    server.shutdown();
    Ok(())
}

fn main() {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.cmd.as_str() {
        "list" => cmd_list(&args),
        "flow" => cmd_flow(&args),
        "rtl" => cmd_rtl(&args),
        "serve" => cmd_serve(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            println!(
                "neuralut <list|flow|rtl|serve|inspect> --config <name> \
                 [--steps N] [--dense-steps N] [--train N] [--test N] \
                 [--seed N] [--no-skips] [--random-conn] [--augment] \
                 [--artifacts DIR] [--out FILE] [--requests N] \
                 [--max-batch N] [--workers N] [--sim-threads N]"
            );
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}' (try: help)");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
