//! `neuralut` — CLI for the NeuraLUT-Assemble toolflow.
//!
//! Subcommands:
//!   list                      show compiled configurations
//!   flow   --config <name>    run the full toolflow (train → LUTs → timing)
//!   rtl    --config <name>    run the flow and write Verilog
//!   export --config <name>    run the flow and write a versioned `.nlb`
//!                             artifact (optimized netlist + plan image)
//!   serve  --config <a[,b..]> train the named configs, serve them all
//!                             from one multi-model batch server
//!   serve  --model <f.nlb,..> serve exported artifacts without training
//!   serve  --listen <addr>    expose the models over TCP (NLWP wire
//!                             protocol; --serve-secs, --max-inflight,
//!                             --max-inflight-per-conn)
//!   inspect --model <f.nlb>   inspect an artifact without a runtime
//!
//! Common flags: --steps N --dense-steps N --train N --test N --seed N
//!               --no-skips --random-conn --augment --artifacts DIR
//!               --plan-cache DIR (persistent compiled-plan cache)
//!               --lanes auto|1|4|8 (wide-word execution backend)
//!               --no-mmap (force copying artifact/plan loads)

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::mapper::{map_netlist, MappedNetlist};
use neuralut::net::{NetConfig, NetServer};
use neuralut::netlist::{load_nlb, load_nlb_mapped, select_backend,
                        ExecPlan, LaneSelect, Netlist, OptLevel};
use neuralut::report::{pct, sci, Table};
use neuralut::runtime::Runtime;
use neuralut::util::Stopwatch;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    let mut switches = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            match name {
                "no-skips" | "random-conn" | "augment" | "verify" | "quiet"
                | "plan" | "no-mmap" => {
                    switches.push(name.to_string());
                }
                _ => {
                    let v = it.next().with_context(|| format!("--{name} needs a value"))?;
                    flags.insert(name.to_string(), v);
                }
            }
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(Args { cmd, flags, switches })
}

impl Args {
    fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
    fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// `--opt-level 0|1|2` (default: the full pass pipeline).
    fn opt_level(&self) -> Result<OptLevel> {
        match self.flags.get("opt-level") {
            Some(v) => v.parse(),
            None => Ok(OptLevel::Full),
        }
    }

    /// `--lanes auto|1|4|8` (default: auto — resolved per model against
    /// its batch ceiling and the CPU's vector width).
    fn lanes(&self) -> Result<LaneSelect> {
        match self.flags.get("lanes") {
            Some(v) => v.parse(),
            None => Ok(LaneSelect::Auto),
        }
    }
}

fn flow_options(args: &Args) -> Result<FlowOptions> {
    let config = args
        .flags
        .get("config")
        .context("--config <name> is required")?
        .clone();
    flow_options_named(args, &config)
}

/// Flow options for an explicit config name (`serve` hosts several
/// configs from one `--config a,b,...` flag, each with its own flow).
fn flow_options_named(args: &Args, config: &str) -> Result<FlowOptions> {
    let mut opts = FlowOptions::quick(config);
    opts.dense_steps = args.usize_flag("dense-steps", opts.dense_steps)?;
    opts.sparse_steps = args.usize_flag("steps", opts.sparse_steps)?;
    opts.seed = args.usize_flag("seed", opts.seed as usize)? as u64;
    opts.gen.n_train = args.usize_flag("train", opts.gen.n_train)?;
    opts.gen.n_test = args.usize_flag("test", opts.gen.n_test)?;
    opts.gen.augment = args.has("augment");
    if args.has("no-skips") {
        opts.skip_scale = 0.0;
    }
    if args.has("random-conn") {
        opts.dense_steps = 0;
    }
    opts.opt_level = args.opt_level()?;
    Ok(opts)
}

fn meta_from(args: &Args) -> Result<Meta> {
    let dir = args
        .flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Meta::default_dir);
    Meta::load(dir)
}

fn cmd_list(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let mut t = Table::new("compiled configurations",
                           &["config", "dataset", "layers w", "F", "beta", "L-LUTs"]);
    for (name, cfg) in &meta.configs {
        let top = &cfg.topology;
        t.row(&[
            name.clone(),
            top.dataset.clone(),
            format!("{:?}", top.w),
            format!("{:?}", top.f),
            format!("{:?}", top.beta),
            top.total_units().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// `--plan`: the compiled execution plan's arena/dedup statistics —
/// what the serving path actually ships (whether freshly compiled or
/// revived from an `.nlb` artifact's plan image).
fn print_plan_stats(name: &str, plan: &ExecPlan) {
    let st = plan.stats();
    let mut t = Table::new(
        &format!("execution plan: {} (key {:016x})", name, plan.key()),
        &["metric", "value"],
    );
    t.row(&["layers (bit-plane)".into(),
            format!("{} ({})", st.layers, st.bitplane_layers)]);
    t.row(&["planes".into(), st.planes.to_string()]);
    t.row(&["tables compiled".into(), st.tables_total.to_string()]);
    t.row(&["tables unique (dedup)".into(),
            st.tables_unique.to_string()]);
    t.row(&["table arena words".into(), st.table_words.to_string()]);
    t.row(&["conn arena entries".into(), st.conn_entries.to_string()]);
    t.row(&["arena bytes".into(), st.arena_bytes.to_string()]);
    t.print();
}

fn print_flow_result(r: &neuralut::coordinator::FlowResult) {
    let mut t = Table::new(
        &format!("toolflow result: {}", r.config),
        &["metric", "value"],
    );
    t.row(&["QAT accuracy".into(), pct(r.qat_acc)]);
    t.row(&["netlist accuracy".into(), pct(r.netlist_acc)]);
    if let Some(be) = r.bit_exact {
        t.row(&["netlist == PJRT (bit-exact)".into(), be.to_string()]);
    }
    t.row(&["L-LUTs (raw)".into(), r.netlist.total_units().to_string()]);
    t.row(&["L-LUTs (optimized)".into(),
            r.netlist_opt.total_units().to_string()]);
    t.row(&["P-LUTs (mapped)".into(), r.mapped.total_luts().to_string()]);
    t.row(&["P-LUTs (raw mapping)".into(),
            r.mapped_raw.total_luts().to_string()]);
    t.row(&["optimizer".into(), r.opt_report.summary()]);
    for (name, rep) in &r.reports {
        t.row(&[format!("{name} Fmax"), format!("{:.0} MHz", rep.fmax_mhz)]);
        t.row(&[format!("{name} latency"), format!("{:.2} ns", rep.latency_ns)]);
        t.row(&[format!("{name} FFs"), rep.ffs.to_string()]);
        t.row(&[format!("{name} area-delay"), sci(rep.area_delay)]);
    }
    t.print();
}

fn cmd_flow(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let sw = Stopwatch::start();
    let r = run_flow(&rt, &meta, &opts)?;
    print_flow_result(&r);
    if args.has("plan") {
        print_plan_stats(&r.config, &r.plan);
    }
    println!("\nflow completed in {:.1}s", sw.secs());
    Ok(())
}

/// Run the flow, then write the optimized netlist and its compiled plan
/// to a versioned `.nlb` artifact — the deliverable `serve --model` and
/// `inspect --model` map back in without retraining or recompiling.
fn cmd_export(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.nlb", opts.config));
    let sw = Stopwatch::start();
    let r = run_flow(&rt, &meta, &opts)?;
    print_flow_result(&r);
    r.export_nlb(&out)?;
    let bytes = std::fs::metadata(&out)?.len();
    println!("\nwrote {out}: {bytes} bytes, netlist content hash {:016x}, \
              plan image key {:016x} ({:.1}s total)",
             r.netlist_opt.content_hash(), r.plan.key(), sw.secs());
    Ok(())
}

fn cmd_rtl(args: &Args) -> Result<()> {
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let mut opts = flow_options(args)?;
    opts.emit_rtl = true;
    let out = args
        .flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("{}.v", opts.config));
    let r = run_flow(&rt, &meta, &opts)?;
    let text = r.rtl_text.as_ref().context("no RTL emitted")?;
    std::fs::write(&out, text)?;
    print_flow_result(&r);
    println!("\nwrote {} ({} lines)", out, text.lines().count());
    Ok(())
}

/// Per-layer netlist statistics table: support histograms,
/// constant/duplicate units — the signals the mapper's synthesis-style
/// optimizations exploit. Shared by the config path (flow-produced
/// netlist) and the artifact path (`--model foo.nlb`).
fn print_netlist_inspection(title: &str, nl: &Netlist,
                            mapped_raw: &MappedNetlist) {
    let mut t = Table::new(
        &format!("netlist inspection: {title}"),
        &["layer", "units", "addr bits", "avg support", "const bits",
          "dup units", "P-LUTs"],
    );
    for (l, layer) in nl.layers.iter().enumerate() {
        let mut support_sum = 0usize;
        let mut bits = 0usize;
        let mut consts = 0usize;
        let mut seen = std::collections::HashSet::new();
        let mut dups = 0usize;
        for u in 0..layer.w {
            let tt = layer.truth_table(u);
            for b in 0..layer.out_bits {
                bits += 1;
                if tt.bit_constant(b).is_some() {
                    consts += 1;
                } else {
                    support_sum += tt.bit_support(b).len();
                }
            }
            if !seen.insert((layer.unit_conn(u).to_vec(),
                             layer.unit_table(u).to_vec())) {
                dups += 1;
            }
        }
        t.row(&[
            l.to_string(),
            layer.w.to_string(),
            (layer.in_bits * layer.fan_in).to_string(),
            format!("{:.2}", support_sum as f64 / bits.max(1) as f64),
            consts.to_string(),
            dups.to_string(),
            mapped_raw.layers[l].luts.to_string(),
        ]);
    }
    t.print();
}

/// Inspect an exported `.nlb` artifact without a runtime: validate and
/// map it, print the same per-layer table as the config path, and
/// describe the embedded plan image (if any).
fn inspect_artifact(args: &Args, path: &str) -> Result<()> {
    let model = if args.has("no-mmap") {
        load_nlb(path)?
    } else {
        load_nlb_mapped(path)?
    };
    let nl = &model.netlist;
    let mapped_raw = map_netlist(nl, false);
    print_netlist_inspection(&format!("{} ({path})", nl.name), nl,
                             &mapped_raw);
    println!("\ntotal P-LUTs {} raw (worst case {}); content hash {:016x}",
             mapped_raw.total_luts(), mapped_raw.total_luts_worst_case(),
             nl.content_hash());
    match &model.plan {
        Some(plan) => {
            println!("plan image: {}{}", plan.stats().summary(),
                     if plan.is_mapped() { " [mapped zero-copy]" }
                     else { "" });
            if args.has("plan") {
                print_plan_stats(&nl.name, plan);
            }
        }
        None => println!("plan image: none (serve will compile at \
                          registration)"),
    }
    // what this host would execute the artifact with: batch hint 0
    // means "no ceiling known", i.e. the widest profitable lane
    let lanes = args.lanes()?;
    println!("execution backend here: {}x64-sample lanes (--lanes \
              {lanes})", select_backend(lanes, 0));
    Ok(())
}

/// Print netlist-level statistics — for a trained config (runs the
/// flow) or, with `--model foo.nlb`, for an exported artifact.
fn cmd_inspect(args: &Args) -> Result<()> {
    if let Some(path) = args.flags.get("model") {
        return inspect_artifact(args, path);
    }
    let meta = meta_from(args)?;
    let rt = Runtime::new()?;
    let opts = flow_options(args)?;
    let r = run_flow(&rt, &meta, &opts)?;
    print_netlist_inspection(&r.config, &r.netlist, &r.mapped_raw);
    println!("\ntotal P-LUTs {} raw (worst case {}) -> {} after the \
              netlist optimizer",
             r.mapped_raw.total_luts(),
             r.mapped_raw.total_luts_worst_case(),
             r.mapped.total_luts());
    println!("optimizer: {}", r.opt_report.summary());
    if args.has("plan") {
        print_plan_stats(&r.config, &r.plan);
    }
    Ok(())
}

/// Comma-separated multi-value flag (`--config a,b` / `--model x,y`).
fn list_flag(args: &Args, name: &str) -> Vec<String> {
    args.flags
        .get(name)
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

/// Train every named config and/or map every named `.nlb` artifact,
/// register them in one `ModelRegistry`, and serve them all
/// concurrently from one process — per-model request streams, per-model
/// latency/occupancy statistics. Artifacts skip training, the
/// optimizer, and (when they carry a plan image) plan compilation
/// entirely; `--plan-cache DIR` additionally persists compiled plans
/// across server processes.
fn cmd_serve(args: &Args) -> Result<()> {
    let configs = list_flag(args, "config");
    let model_files = list_flag(args, "model");
    anyhow::ensure!(!configs.is_empty() || !model_files.is_empty(),
                    "--config <name[,name...]> or --model \
                     <file.nlb[,file.nlb...]> is required");
    // catch duplicates up front: the registry asserts on them, and by
    // then each flow has already trained for minutes
    let mut seen = std::collections::HashSet::new();
    for name in &configs {
        anyhow::ensure!(seen.insert(name.clone()),
                        "duplicate config '{name}' in --config");
    }
    let n_req = args.usize_flag("requests", 2000)?;

    let mut registry = ModelRegistry::new();
    let mut served: Vec<String> = Vec::new();
    let mut model_rows: Vec<Vec<Vec<i32>>> = Vec::new();
    if !configs.is_empty() {
        let meta = meta_from(args)?;
        let rt = Runtime::new()?;
        for name in &configs {
            let opts = flow_options_named(args, name)?;
            let r = run_flow(&rt, &meta, &opts)?;
            print_flow_result(&r);
            // what the server will actually execute (the registry
            // netlist is optimized and plan-compiled again at
            // registration, hitting the server's plan cache for
            // identical content)
            println!("{name}: {}/{} layers bit-plane after optimization \
                      (plan key {:016x})",
                     r.plan.bitplane_layers(), r.netlist_opt.layers.len(),
                     r.plan.key());
            let top = &meta.config(name)?.topology;
            let splits = neuralut::dataset::generate(&top.dataset,
                                                     top.beta_in,
                                                     &opts.gen)?;
            model_rows.push(
                (0..n_req)
                    .map(|i| splits.test.row(i % splits.test.n).to_vec())
                    .collect(),
            );
            served.push(name.clone());
            // last use of `r`: move the netlist (tables can be large)
            registry.register(name, r.netlist);
        }
    }
    let use_mmap = !args.has("no-mmap");
    for path in &model_files {
        let model = if use_mmap {
            load_nlb_mapped(path)
        } else {
            load_nlb(path)
        }
        .with_context(|| format!("loading artifact '{path}'"))?;
        let name = if model.netlist.name.is_empty() {
            std::path::Path::new(path)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.clone())
        } else {
            model.netlist.name.clone()
        };
        anyhow::ensure!(seen.insert(name.clone()),
                        "artifact '{path}' duplicates model name '{name}'");
        println!("{name}: artifact {path} ({} layers, {} L-LUTs, \
                  content hash {:016x}, plan image: {})",
                 model.netlist.layers.len(), model.netlist.total_units(),
                 model.netlist.content_hash(),
                 match &model.plan {
                     Some(p) if p.is_mapped() => "yes, mapped zero-copy",
                     Some(_) => "yes",
                     None => "no",
                 });
        // artifacts ship no dataset: drive them with random (but valid
        // and reproducible) input codes
        let seed = args.usize_flag("seed", 7)? as u64;
        let flat = neuralut::netlist::testutil::random_inputs(
            seed ^ model.netlist.content_hash(), &model.netlist, n_req);
        model_rows.push(flat
            .chunks(model.netlist.n_in.max(1))
            .map(|r| r.to_vec())
            .collect());
        served.push(name.clone());
        registry.register_artifact(&name, model);
    }

    let plan_cache_dir =
        args.flags.get("plan-cache").map(std::path::PathBuf::from);
    let cfg = ServerConfig {
        max_batch: args.usize_flag("max-batch", 64)?,
        max_wait: Duration::from_micros(
            args.usize_flag("max-wait-us", 200)? as u64),
        workers: args.usize_flag("workers", 2)?,
        sim_threads: args.usize_flag("sim-threads", 1)?,
        opt_level: args.opt_level()?,
        plan_cache_dir: plan_cache_dir.clone(),
        mmap: use_mmap,
        lanes: args.lanes()?,
    };
    let server = InferenceServer::start(registry, cfg);
    let configs = served;
    for name in &configs {
        println!("{name}: optimizer {}",
                 server.opt_report(name)?.summary());
        println!("{name}: plan {}", server.plan_stats(name)?.summary());
        let lw = server.model_lane_width(name)?;
        println!("{name}: backend plan-w{lw} ({lw}x64-sample lanes)");
    }
    {
        // same three counters the STATS wire JSON reports under
        // `plan_cache`: compiles / memory hits / disk hits
        let (compiled, hits) = server.plan_cache_counts();
        println!("plan cache: {compiled} compiles, {hits} memory hits, \
                  {} disk hits{}",
                 server.plan_cache_disk_hits(),
                 if plan_cache_dir.is_some() && use_mmap {
                     " (disk hits served zero-copy via mmap)"
                 } else {
                     ""
                 });
    }
    // --listen ADDR: expose the server over TCP instead of driving
    // synthetic traffic in-process
    if let Some(addr) = args.flags.get("listen") {
        return serve_listen(args, server, &configs, addr);
    }

    let sw = Stopwatch::start();
    // one client thread per model: the streams interleave in the router
    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = configs
            .iter()
            .zip(model_rows)
            .map(|(name, rows)| {
                let server = &server;
                s.spawn(move || server.infer_many(name, rows).map(|_| ()))
            })
            .collect();
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    let secs = sw.secs();

    let mut t = Table::new(
        "serving statistics (per model)",
        &["model", "requests", "batches", "occupancy", "mean us", "p50 us",
          "p99 us", "p999 us"],
    );
    let mut total = 0u64;
    for st in server.all_stats() {
        total += st.requests;
        t.row(&[
            st.model.clone(),
            st.requests.to_string(),
            st.batches.to_string(),
            format!("{:.1}", st.mean_occupancy),
            format!("{:.0}", st.latency.mean),
            format!("{:.0}", st.latency.p50),
            format!("{:.0}", st.latency.p99),
            format!("{:.0}", st.latency.p999),
        ]);
    }
    t.print();
    println!("\nserved {total} requests across {} models in {:.2}s \
              ({:.0} req/s)",
             configs.len(), secs, total as f64 / secs);
    server.shutdown();
    Ok(())
}

/// `serve --listen ADDR`: host the models over TCP (NLWP protocol)
/// instead of driving synthetic traffic in-process.  `--serve-secs N`
/// bounds the run (0 = until killed); `--max-inflight N` sets the
/// global admission bound and `--max-inflight-per-conn N` the
/// per-connection quota (default: a quarter of the global bound) —
/// past either, requests are shed with a typed OVERLOADED /
/// CONN_QUOTA error.  On a bounded run the server drains gracefully
/// (flushes in-flight responses) before printing final statistics.
fn serve_listen(args: &Args, server: InferenceServer,
                models: &[String], addr: &str) -> Result<()> {
    let max_inflight = args.usize_flag(
        "max-inflight", NetConfig::default().max_inflight)?;
    let per_conn = match args.flags.get("max-inflight-per-conn") {
        Some(v) => Some(v.parse::<usize>()?),
        None => None,
    };
    let cfg = NetConfig {
        max_inflight,
        max_inflight_per_conn: per_conn,
        ..NetConfig::default()
    };
    let conn_quota = cfg.conn_quota();
    let net = NetServer::bind(server, addr, cfg)?;
    println!("listening on {} — {} models ({}), max {} in-flight rows \
              ({} per connection)",
             net.local_addr(), models.len(), models.join(", "),
             max_inflight, conn_quota);
    let secs = args.usize_flag("serve-secs", 0)?;
    if secs == 0 {
        println!("serving until killed (--serve-secs N for a bounded \
                  run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs as u64));
    println!("\n{secs}s elapsed: draining (refusing new work, flushing \
              in-flight responses)");
    net.shutdown();

    let mut t = Table::new(
        "serving statistics (per model)",
        &["model", "requests", "batches", "occupancy", "mean us",
          "p50 us", "p99 us", "p999 us"],
    );
    let mut total = 0u64;
    for st in net.inner().all_stats() {
        total += st.requests;
        t.row(&[
            st.model.clone(),
            st.requests.to_string(),
            st.batches.to_string(),
            format!("{:.1}", st.mean_occupancy),
            format!("{:.0}", st.latency.mean),
            format!("{:.0}", st.latency.p50),
            format!("{:.0}", st.latency.p99),
            format!("{:.0}", st.latency.p999),
        ]);
    }
    t.print();
    println!("\nserved {total} requests over TCP in {secs}s; {} \
              connections accepted, {} requests shed ({} deadline, \
              {} conn-quota)",
             net.accepted_conns(), net.shed_total(),
             net.deadline_sheds_total(), net.quota_sheds_total());
    Ok(())
}

fn main() {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.cmd.as_str() {
        "list" => cmd_list(&args),
        "flow" => cmd_flow(&args),
        "rtl" => cmd_rtl(&args),
        "export" => cmd_export(&args),
        "serve" => cmd_serve(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            println!(
                "neuralut <list|flow|rtl|export|serve|inspect> \
                 --config <name> \
                 [--steps N] [--dense-steps N] [--train N] [--test N] \
                 [--seed N] [--no-skips] [--random-conn] [--augment] \
                 [--artifacts DIR] [--out FILE] [--requests N] \
                 [--max-batch N] [--max-wait-us N] [--workers N] \
                 [--sim-threads N] [--opt-level 0|1|2] [--plan] \
                 [--lanes auto|1|4|8] \
                 [--model FILE.nlb[,FILE.nlb...]] [--plan-cache DIR] \
                 [--no-mmap] \
                 [--listen ADDR] [--serve-secs N] [--max-inflight N] \
                 [--max-inflight-per-conn N]\n\n\
                 serve hosts several configs at once: \
                 --config nid,jsc_cb serves both from one process \
                 (per-model batching policies and statistics). \
                 --max-batch / --max-wait-us set the default dispatch \
                 policy (batch fills or oldest request ages out); \
                 --workers and --sim-threads size the shared evaluation \
                 threads. --opt-level picks the netlist optimizer \
                 pipeline (0 none, 1 const-fold+dead-logic, 2 +CSE; \
                 default 2) applied before mapping, RTL and serving; \
                 per-model OptReport stats are printed at startup. \
                 Serving executes compiled plans (netlists flattened \
                 into deduplicated arenas, compiled once per content \
                 hash); --plan prints the plan's arena/dedup statistics \
                 on flow/inspect, and serve logs per-model plan stats \
                 plus plan-cache hit counts. --lanes picks the wide-word \
                 execution backend (W 64-sample words per op, \
                 auto-vectorized): auto resolves per model from its \
                 batch ceiling and the CPU's vector width, 1/4/8 pin \
                 the width; every width is bit-exact.\n\n\
                 export writes a versioned .nlb artifact (optimized \
                 netlist + compiled-plan image, default <config>.nlb, \
                 override with --out). serve --model and inspect \
                 --model map such artifacts back in: serving skips \
                 training/optimizer/compile, inspect needs no runtime. \
                 --plan-cache DIR keeps compiled plans on disk keyed by \
                 content hash so a restarted server cold-loads instead \
                 of recompiling. Artifact and plan-cache loads are \
                 zero-copy by default: the file is memory-mapped and \
                 the plan's arenas are borrowed straight from the \
                 mapping when the host is little-endian and the file \
                 offsets are aligned (v2 artifacts pad to guarantee \
                 this); --no-mmap forces the copying loader, and \
                 unaligned/v1/foreign-endian files fall back to it \
                 automatically.\n\n\
                 serve --listen ADDR exposes the models over TCP (the \
                 NLWP length-prefixed protocol; see DESIGN.md): \
                 per-connection pipelining feeds the same batching \
                 router, requests past --max-inflight rows are shed \
                 with a typed OVERLOADED error, a single connection \
                 past --max-inflight-per-conn rows (default: a quarter \
                 of the global bound) with CONN_QUOTA, and requests \
                 whose wire-v2 deadline budget cannot be met are shed \
                 up front with DEADLINE. Stats (p50/p99/p999, \
                 occupancy, shed counts incl. deadline/quota sheds, \
                 per-connection counters) are queryable over the \
                 wire. --serve-secs N bounds the run and drains \
                 gracefully; 0 (default) serves until killed. \
                 examples/serve_load.rs is a ready-made load generator."
            );
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}' (try: help)");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
