//! The end-to-end NeuraLUT-Assemble toolflow (paper Fig. 3):
//!
//! 1. (optional) dense pre-training with the group-lasso regularizer and
//!    top-F connection selection — the "learned mappings";
//! 2. sparse QAT of the assembled tree model, from scratch, on the
//!    selected connectivity (SGDR + AdamW via the PJRT `train_step`);
//! 3. sub-network → L-LUT conversion by exhaustive enumeration;
//! 4. netlist extraction and **bit-exactness verification** against the
//!    quantized PJRT forward on the whole test set;
//! 5. technology mapping and timing under both pipelining strategies;
//! 6. Verilog RTL emission with a parse-back round-trip check.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{ConfigMeta, Meta, TrainConfig};
use crate::coordinator::session::{predictions, Session};
use crate::dataset::{self, GenOpts, Splits};
use crate::mapper::{map_netlist, MappedNetlist};
use crate::metrics;
use crate::netlist::{optimize, save_nlb, select_backend, ExecPlan,
                     LaneExecutor, LaneSelect, Netlist, OptLevel,
                     OptReport, PlanExecutor, PlanOptions, SimOptions};
use crate::pruning;
use crate::rtl;
use crate::runtime::Runtime;
use crate::timing::{evaluate as time_evaluate, DelayModel, Pipelining, TimingReport};

/// Options for one toolflow run.
#[derive(Clone, Debug)]
pub struct FlowOptions {
    pub config: String,
    /// steps of the dense learned-mappings phase (0 = skip; connections
    /// are then random — the "w/o Learned Mappings" ablation)
    pub dense_steps: usize,
    pub sparse_steps: usize,
    /// 1.0 normal; 0.0 ablates tree-level skips ("w/o Tree-Level Skips")
    pub skip_scale: f32,
    pub seed: u64,
    pub gen: GenOpts,
    /// emit RTL text (large for big configs)
    pub emit_rtl: bool,
    /// verify netlist == PJRT quantized forward on the test set
    pub verify_bit_exact: bool,
    /// netlist optimizer level applied before mapping / timing / RTL
    /// (the raw netlist is still mapped for the worst-case comparison)
    pub opt_level: OptLevel,
}

impl FlowOptions {
    pub fn quick(config: &str) -> FlowOptions {
        FlowOptions {
            config: config.to_string(),
            dense_steps: 30,
            sparse_steps: 150,
            skip_scale: 1.0,
            seed: 7,
            gen: GenOpts::default(),
            emit_rtl: false,
            verify_bit_exact: true,
            opt_level: OptLevel::Full,
        }
    }
}

/// Everything a table/figure harness needs from one run.
pub struct FlowResult {
    pub config: String,
    /// QAT accuracy of the trained quantized model (PJRT forward)
    pub qat_acc: f64,
    /// accuracy of the extracted LUT netlist (rust simulator)
    pub netlist_acc: f64,
    /// netlist output == PJRT output on every test row?
    pub bit_exact: Option<bool>,
    /// the raw extracted netlist (the PJRT bit-exactness reference and
    /// the worst-case mapping input)
    pub netlist: Netlist,
    /// the optimizer's output — what mapping, timing, RTL emission and
    /// serving consume (bit-exact with `netlist` by contract, checked
    /// on the test set during the flow)
    pub netlist_opt: Netlist,
    /// the compiled execution plan of `netlist_opt` — the artifact the
    /// serving path actually runs (bit-exactness re-checked on the test
    /// set during the flow); shareable across executors as-is
    pub plan: Arc<ExecPlan>,
    /// what each optimizer pass removed
    pub opt_report: OptReport,
    /// mapping of the *optimized* netlist (the real design point)
    pub mapped: MappedNetlist,
    /// mapping of the raw netlist (ablation / worst-case comparison)
    pub mapped_raw: MappedNetlist,
    /// (strategy name, report) for both pipelining strategies
    pub reports: Vec<(String, TimingReport)>,
    pub losses: Vec<f32>,
    /// learned-mapping hit quality on NID (fraction of selected inputs
    /// that are informative), when measurable
    pub rtl_text: Option<String>,
}

impl FlowResult {
    /// Export the serving artifact as an `.nlb` file: the *optimized*
    /// netlist (what mapping, RTL and serving consume — bit-exactness
    /// with the raw extraction was proven on the test set during the
    /// flow) together with its compiled plan image, so a server loads
    /// this file instead of re-running the config flow.  This is the
    /// `nid export` path.
    pub fn export_nlb(&self, path: impl AsRef<std::path::Path>)
                      -> Result<()> {
        save_nlb(path, &self.netlist_opt, Some(&self.plan))
    }
}

/// Run the complete toolflow for one configuration.
pub fn run_flow(rt: &Runtime, meta: &Meta, opts: &FlowOptions) -> Result<FlowResult> {
    let cfg: &ConfigMeta = meta.config(&opts.config)?;
    let top = cfg.topology.clone();
    let splits: Splits = dataset::generate(&top.dataset, top.beta_in, &opts.gen)?;

    // ---- phase 1: learned mappings (dense + group lasso + top-F) ----
    let learned_conns = if opts.dense_steps > 0 {
        log::info!("[{}] dense phase: {} steps", top.name, opts.dense_steps);
        let mut dense = Session::new(rt, cfg, true, None, opts.seed ^ 0xDE45E,
                                     opts.skip_scale)?;
        let tc = TrainConfig::dense(opts.dense_steps);
        dense.train(&splits.train, &tc)?;
        let scores = dense.group_scores()?;
        let mut conns = Vec::new();
        for (k, l) in dense.learned_layers().into_iter().enumerate() {
            conns.push(pruning::select_top_f(&scores[k], top.f[l]));
        }
        if top.dataset == "nid" && std::env::var("NLA_TRACE").is_ok() {
            let informative =
                crate::dataset::nid_informative_positions(opts.gen.seed);
            eprintln!("[{}] learned-mapping hit rate on informative bits: {:.2}",
                      top.name,
                      pruning::selection_hit_rate(&conns[0], &informative));
        }
        Some(conns)
    } else {
        None
    };

    // ---- phase 2: sparse tree QAT, trained from scratch ----
    // Train in chunks, validating on a held-out slice of the training set
    // after each chunk and keeping the best checkpoint (the role the
    // paper's long SGDR schedule plays; QAT of deep quantized trees is
    // noisy enough that last-iterate selection throws accuracy away).
    log::info!("[{}] sparse phase: {} steps", top.name, opts.sparse_steps);
    let (fit, val) = split_train(&splits.train, 0.85);
    let mut sess = Session::new(rt, cfg, false, learned_conns.as_deref(),
                                opts.seed, opts.skip_scale)?;
    let tc = TrainConfig::sparse(opts.sparse_steps);
    let chunks = 8usize;
    let chunk_len = (opts.sparse_steps / chunks).max(1);
    let mut losses = Vec::new();
    let mut best: Option<(f64, Vec<(String, Vec<usize>, Vec<f32>)>,
                          Vec<(String, Vec<usize>, Vec<f32>)>)> = None;
    for chunk in 0..chunks {
        losses.extend(sess.train_range(&fit, &tc, chunk * chunk_len,
                                        chunk_len)?);
        let val_acc = sess.evaluate(&val)?;
        if std::env::var("NLA_TRACE").is_ok() {
            eprintln!("[{}] step {}: loss {:.4} val acc {:.3}",
                      top.name, (chunk + 1) * chunk_len,
                      losses.last().copied().unwrap_or(f32::NAN), val_acc);
        }
        if best.as_ref().map(|(a, _, _)| val_acc > *a).unwrap_or(true) {
            best = Some((val_acc, sess.params.snapshot()?,
                         sess.stats.snapshot()?));
        }
    }
    if let Some((_, psnap, ssnap)) = &best {
        sess.params.restore(psnap)?;
        sess.stats.restore(ssnap)?;
    }
    let qat_acc = sess.evaluate(&splits.test)?;

    // ---- phase 3/4: enumerate -> netlist -> verify ----
    let netlist = sess.to_netlist()?;
    let test = &splits.test;
    // the *interpreted* object-graph walk is the reference every
    // downstream check compares against: the default eval_batch now
    // executes a compiled plan itself, so using it here would make the
    // optimizer and plan bit-exactness checks below compiled-vs-compiled
    let net_out = {
        let mut reference = netlist.simulator_with(SimOptions {
            compiled: false,
            ..SimOptions::default()
        });
        reference.eval_batch(&test.x, test.n)
    };
    let net_preds = predictions(&top, &net_out);
    let netlist_acc = metrics::accuracy(&net_preds, &test.y);

    let bit_exact = if opts.verify_bit_exact {
        Some(verify_bit_exact(&mut sess, &netlist, test)?)
    } else {
        None
    };

    // ---- phase 5: optimize -> map + time ----
    // The optimizer's contract is bit-exact observable outputs; the
    // property suite proves it on random netlists, and this enforces it
    // on the actual trained tables before anything downstream consumes
    // the optimized artifact.
    let (netlist_opt, opt_report) = optimize(&netlist, opts.opt_level);
    let opt_out = netlist_opt.eval_batch(&test.x, test.n)?;
    anyhow::ensure!(opt_out == net_out,
                    "netlist optimizer broke bit-exactness on '{}'",
                    opts.config);
    log::info!("[{}] optimizer: {}", top.name, opt_report.summary());

    // compile the serving artifact and prove it on the same test set:
    // the plan is what the server's workers will execute, so the flow
    // checks the whole chain raw -> optimized -> compiled end to end
    let plan = Arc::new(netlist_opt.compile_plan(PlanOptions::default()));
    let mut plan_exec = PlanExecutor::new(plan.clone());
    let plan_out = plan_exec.eval_batch(&test.x, test.n);
    anyhow::ensure!(plan_out == net_out,
                    "compiled execution plan broke bit-exactness on '{}'",
                    opts.config);
    // ...and at the lane width a server would auto-select for this
    // host, so the exact backend that serves traffic is the one proven
    // on the test set (scalar and wide share one generic kernel, but
    // the flow checks the instantiation, not the argument)
    let wide_w = select_backend(LaneSelect::Auto, test.n.max(256));
    if wide_w > 1 {
        let mut wide = LaneExecutor::for_width(
            wide_w, plan.clone(), SimOptions::default());
        anyhow::ensure!(wide.eval_batch(&test.x, test.n) == net_out,
                        "wide ({wide_w}-lane) execution broke \
                         bit-exactness on '{}'", opts.config);
    }
    log::info!("[{}] plan: {} ({}x64-sample lanes auto-selected)",
               top.name, plan.stats().summary(), wide_w);
    let mapped = map_netlist(&netlist_opt, true);
    let mapped_raw = map_netlist(&netlist, true);
    let dm = DelayModel::default();
    let reports = vec![
        ("pipeline-1".to_string(),
         time_evaluate(&mapped, Pipelining::EveryLayer, &dm)),
        ("pipeline-3".to_string(),
         time_evaluate(&mapped, Pipelining::EveryK(3), &dm)),
    ];

    // ---- phase 6: RTL (of the optimized netlist — what would ship) ----
    let rtl_text = if opts.emit_rtl {
        let cuts = reports[1].1.cuts.clone();
        let text = rtl::emit(&netlist_opt, &rtl::RtlOptions {
            cuts,
            module_name: format!("neuralut_{}", top.name),
        });
        rtl::verify_roundtrip(&text, &netlist_opt)?;
        Some(text)
    } else {
        None
    };

    Ok(FlowResult {
        config: opts.config.clone(),
        qat_acc,
        netlist_acc,
        bit_exact,
        netlist,
        netlist_opt,
        plan,
        opt_report,
        mapped,
        mapped_raw,
        reports,
        losses,
        rtl_text,
    })
}

/// Deterministic train/validation split (by prefix; generators already
/// interleave classes).
fn split_train(d: &crate::dataset::Dataset, frac: f64)
               -> (crate::dataset::Dataset, crate::dataset::Dataset) {
    let n_fit = ((d.n as f64 * frac) as usize).clamp(1, d.n.saturating_sub(1));
    let fit = crate::dataset::Dataset {
        x: d.x[..n_fit * d.n_in].to_vec(),
        y: d.y[..n_fit].to_vec(),
        n: n_fit,
        n_in: d.n_in,
        beta_in: d.beta_in,
        n_classes: d.n_classes,
    };
    let val = crate::dataset::Dataset {
        x: d.x[n_fit * d.n_in..].to_vec(),
        y: d.y[n_fit..].to_vec(),
        n: d.n - n_fit,
        n_in: d.n_in,
        beta_in: d.beta_in,
        n_classes: d.n_classes,
    };
    (fit, val)
}

/// Compare netlist simulation against the PJRT quantized forward on the
/// whole test set — the reproduction's system-level keystone.
fn verify_bit_exact(sess: &mut Session, nl: &Netlist,
                    test: &crate::dataset::Dataset) -> Result<bool> {
    let top = sess.cfg.topology.clone();
    let mut i = 0usize;
    while i < test.n {
        let idx: Vec<usize> = (i..(i + top.batch).min(test.n)).collect();
        let (x, _) = test.batch(&idx, top.batch);
        let pjrt_codes = sess.infer_codes(&x, "infer")?;
        let net_codes = nl.eval_batch(&x, top.batch)?;
        if pjrt_codes != net_codes {
            let w = nl.out_width();
            for (row, (a, b)) in pjrt_codes.chunks(w).zip(net_codes.chunks(w)).enumerate() {
                if a != b {
                    log::error!("bit-exactness broke at test row {}: {:?} vs {:?}",
                                i + row, a, b);
                    break;
                }
            }
            return Ok(false);
        }
        i += top.batch;
    }
    Ok(true)
}
