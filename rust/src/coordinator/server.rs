//! Multi-model dynamic-batching inference server over the bit-exact
//! netlist simulator.
//!
//! Deployment story of an always-on LUT-inference "FPGA": one server
//! process hosts every deployed model — the paper family targets NID,
//! jet classification and MNIST side by side — behind shared router and
//! worker threads.  A [`ModelRegistry`] names the netlists; requests are
//! routed by model name, batched *per model* (a batch never mixes
//! models), and dispatched when a model's `max_batch` is reached or its
//! oldest waiting request exceeds its `max_wait` — each model can carry
//! its own [`BatchPolicy`].  Every model is compiled **once** at
//! registration into an arena-backed execution plan (`netlist::plan`)
//! through a per-server [`PlanCache`] keyed by netlist content hash —
//! content-identical models share one plan — and worker threads own
//! one [`LaneExecutor`] (private scratch over the shared immutable
//! plan) per model, each with `sim_threads` evaluation threads on a
//! lent worker pool, so one big batch fans out across cores.  The lane
//! width each model runs at is resolved once at startup
//! ([`select_backend`] over [`ServerConfig::lanes`] with the model's
//! `max_batch` as the hint) and every worker runs that width, so the
//! backend is a per-model property, not a per-worker accident.
//! Workers publish per-model latency ([`LatencyStats`]) and
//! batch-occupancy ([`BatchStats`]) statistics.  Python is nowhere on
//! this path.
//!
//! The router blocks on the request channel with a timeout equal to the
//! earliest pending batch deadline — no spin-waiting — so an idle or
//! half-loaded server burns no CPU between dispatches.
//!
//! # Shutdown protocol
//!
//! [`InferenceServer::shutdown`] (idempotent, callable through a shared
//! reference — e.g. an `Arc` handed to client threads) stops the
//! pipeline in two tiers:
//!
//! 1. the request sender is dropped and the router is joined.  The
//!    router observes the disconnect (setting the shared `stop` flag
//!    itself), flushes any pending requests as final batches, then exits
//!    — dropping the batch sender.
//! 2. the `stop` flag is raised and workers are joined.  Workers drain
//!    the batch channel and exit when it disconnects (router gone) **or**
//!    when `stop` is set and no batch arrives within one poll interval
//!    (`WORKER_POLL`).  The flag check means workers terminate even if
//!    a batch producer wedges with the channel open, so worker joins
//!    cannot hang; raising it only *after* the router flush means no
//!    in-flight request is dropped.
//!
//! In-flight requests are answered before their worker exits; requests
//! submitted after shutdown fail with "server stopped".

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender,
                      TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{BatchStats, LatencyStats, LatencySummary};
use crate::netlist::{load_nlb, load_nlb_mapped, optimize,
                     select_backend, ExecPlan, LaneExecutor, LaneSelect,
                     Netlist, NlbModel, OptLevel, OptReport, PlanCache,
                     PlanOptions, PlanStats, SimOptions, WorkerPool};

use super::engine::ModelEngine;

/// Per-model batching policy: dispatch when `max_batch` requests are
/// waiting or the oldest has waited `max_wait` — the standard
/// latency/throughput knob, now settable per model.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

/// Server tuning knobs.  `max_batch`/`max_wait` are the default
/// [`BatchPolicy`] for models registered without an override; `workers`
/// and `sim_threads` are shared by all models.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Concurrent batch-evaluation workers (each owns one simulator per
    /// registered model).
    pub workers: usize,
    /// Evaluation threads *inside* each worker's simulators: large
    /// batches are chunked over unit ranges (`SimOptions::threads`,
    /// persistent-pool workers).  1 keeps the v1 behavior; raise it when
    /// `max_batch` is large and cores outnumber concurrent batches.
    pub sim_threads: usize,
    /// Netlist optimizer level applied to every model at registration,
    /// before the workers' simulators are built — fewer units and
    /// planes for every batch the server ever evaluates.  The optimizer
    /// contract is bit-exact outputs, so the default is the full
    /// pipeline; models can override it per registration
    /// ([`ModelRegistry::register_with_opt`]).  Artifacts
    /// ([`ModelRegistry::register_artifact`]) are served verbatim and
    /// never pass through the optimizer.
    pub opt_level: OptLevel,
    /// Directory for the persistent plan cache.  With a directory set,
    /// every plan compiled at registration is written as a plan image
    /// and a restarted server loads images instead of recompiling —
    /// the cold-start path (`benches/coldstart`).  `None` keeps the
    /// cache in-memory only.
    pub plan_cache_dir: Option<PathBuf>,
    /// Serve persistent-cache disk hits from memory-mapped `.plan`
    /// files (zero-copy arenas, O(validation) cold start) instead of
    /// reading them into owned buffers.  On by default; `--no-mmap` on
    /// the CLI clears it.  Hosts where mapping is unavailable or a file
    /// is unaligned fall back to the copying read regardless.
    pub mmap: bool,
    /// Lane-width policy for the workers' executors (`--lanes` on the
    /// CLI).  `Auto` resolves per model against its `max_batch`: small
    /// batch ceilings stay on the scalar `W = 1` path, large ones get
    /// the widest profitable lane the CPU supports.  A fixed width
    /// pins every model.
    pub lanes: LaneSelect,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            workers: 2,
            sim_threads: 1,
            opt_level: OptLevel::Full,
            plan_cache_dir: None,
            mmap: true,
            lanes: LaneSelect::Auto,
        }
    }
}

impl ServerConfig {
    fn default_policy(&self) -> BatchPolicy {
        BatchPolicy { max_batch: self.max_batch.max(1),
                      max_wait: self.max_wait }
    }
}

/// Where a registered model's netlist came from — the two producers of
/// "a runnable model".
enum ModelSource {
    /// Synthesized in-process (config/training flow): optimized at
    /// registration, then compiled through the plan cache.
    Config { nl: Netlist, opt_level: Option<OptLevel> },
    /// Loaded from an `.nlb` artifact: served verbatim (no optimizer
    /// pass — the producer already shipped the netlist it wants
    /// served), reusing the artifact's plan image when it carries one.
    Artifact(NlbModel),
}

/// One registered model awaiting server start.
struct ModelSpec {
    name: String,
    source: ModelSource,
    policy: Option<BatchPolicy>,
}

/// Named netlists for one [`InferenceServer`] to host.  Registration
/// order is preserved (the first model is the default).
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<ModelSpec>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register `nl` under `name` with the server's default policy.
    /// Panics on duplicate names (a registry is built once, at startup).
    pub fn register(&mut self, name: &str, nl: Netlist) -> &mut Self {
        self.register_with(name, nl, None)
    }

    /// Register with a model-specific batching policy.
    pub fn register_with(&mut self, name: &str, nl: Netlist,
                         policy: Option<BatchPolicy>) -> &mut Self {
        self.register_with_opt(name, nl, policy, None)
    }

    /// Register with batching-policy and optimizer-level overrides
    /// (`None` inherits the server defaults from [`ServerConfig`]).
    pub fn register_with_opt(&mut self, name: &str, nl: Netlist,
                             policy: Option<BatchPolicy>,
                             opt_level: Option<OptLevel>) -> &mut Self {
        self.push(ModelSpec {
            name: name.to_string(),
            source: ModelSource::Config { nl, opt_level },
            policy,
        })
    }

    /// Register a loaded `.nlb` artifact under `name`.  Artifacts are
    /// the deliverable of the train → export pipeline and are served
    /// verbatim: the optimizer does not run, and if the artifact
    /// carries a compiled-plan image that plan is admitted into the
    /// server's cache instead of being recompiled.
    pub fn register_artifact(&mut self, name: &str, model: NlbModel)
                             -> &mut Self {
        self.register_artifact_with(name, model, None)
    }

    /// [`ModelRegistry::register_artifact`] with a batching policy.
    pub fn register_artifact_with(&mut self, name: &str, model: NlbModel,
                                  policy: Option<BatchPolicy>)
                                  -> &mut Self {
        self.push(ModelSpec {
            name: name.to_string(),
            source: ModelSource::Artifact(model),
            policy,
        })
    }

    /// Load an `.nlb` file and register it — the `nid serve --model
    /// foo.nlb` path.  Fails on any malformed artifact (see
    /// `netlist::format` for the validation pass).  Maps the file for a
    /// zero-copy load when the host and file layout allow it, falling
    /// back to the copying read otherwise; use
    /// [`ModelRegistry::register_file_with`] to force the copying path
    /// (`--no-mmap`).
    pub fn register_file(&mut self, name: &str, path: impl AsRef<Path>)
                         -> Result<&mut Self> {
        self.register_file_with(name, path, true)
    }

    /// [`ModelRegistry::register_file`] with an explicit mapping policy:
    /// `mmap = false` always reads the artifact into owned buffers.
    pub fn register_file_with(&mut self, name: &str,
                              path: impl AsRef<Path>, mmap: bool)
                              -> Result<&mut Self> {
        let model = if mmap {
            load_nlb_mapped(path)?
        } else {
            load_nlb(path)?
        };
        Ok(self.register_artifact(name, model))
    }

    fn push(&mut self, spec: ModelSpec) -> &mut Self {
        assert!(!self.models.iter().any(|m| m.name == spec.name),
                "duplicate model name '{}'", spec.name);
        self.models.push(spec);
        self
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }
}

/// How long an idle worker waits on the batch channel before re-checking
/// the stop flag.
const WORKER_POLL: Duration = Duration::from_millis(2);

/// How long the idle router blocks for a first request before
/// re-checking the stop flag.
const ROUTER_IDLE_POLL: Duration = Duration::from_millis(5);

struct Request {
    /// index into the model table
    model: usize,
    x: Vec<i32>,
    enqueued: Instant,
    reply: Sender<Vec<i32>>,
}

struct BatchJob {
    model: usize,
    reqs: Vec<Request>,
}

/// Shared per-model serving state.
struct ModelState {
    name: String,
    /// the compiled execution plan of the *optimized* netlist —
    /// compiled once at registration (through the server's [`PlanCache`],
    /// so identically-structured models share one plan) and executed by
    /// every worker with private scratch
    plan: Arc<ExecPlan>,
    policy: BatchPolicy,
    /// lane width every worker executes this model at — resolved once
    /// at startup from [`ServerConfig::lanes`] with the model's
    /// `max_batch` as the batch hint
    lane_width: usize,
    n_in: usize,
    out_width: usize,
    /// what the optimizer removed at registration
    opt_report: OptReport,
    stats: Mutex<LatencyStats>,
    batches: Mutex<BatchStats>,
}

/// A submitted-but-unanswered request: the reply half of
/// [`InferenceServer::submit`].  Dropping it abandons the answer (the
/// worker's send fails harmlessly); [`Pending::wait`] blocks until the
/// batch containing the request completes.
pub struct Pending {
    rx: Receiver<Vec<i32>>,
}

impl Pending {
    /// Block until the router/worker pipeline answers.  Fails only if
    /// the server stopped before the request was evaluated.
    pub fn wait(self) -> Result<Vec<i32>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server stopped"))
    }
}

/// Point-in-time per-model serving statistics.
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub model: String,
    pub requests: u64,
    pub batches: u64,
    /// mean requests per dispatched batch
    pub mean_occupancy: f64,
    pub max_batch_seen: usize,
    pub latency: LatencySummary,
}

/// Handle to a running server.
pub struct InferenceServer {
    /// `None` once shutdown has begun; taking it closes the request
    /// channel (tier 1).
    tx: Mutex<Option<Sender<Request>>>,
    models: Vec<Arc<ModelState>>,
    by_name: HashMap<String, usize>,
    /// registration-time plan cache: content-identical models compile
    /// once and share one immutable plan across all workers
    plans: PlanCache,
    stop: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl InferenceServer {
    /// Spawn the shared router + workers for every registered model.
    pub fn start(registry: ModelRegistry, cfg: ServerConfig)
                 -> InferenceServer {
        assert!(!registry.is_empty(), "registry holds no models");
        let default_policy = cfg.default_policy();
        let mut plans = match &cfg.plan_cache_dir {
            Some(dir) => PlanCache::persistent(dir),
            None => PlanCache::new(),
        };
        plans.set_mmap(cfg.mmap);
        let models: Vec<Arc<ModelState>> = registry
            .models
            .into_iter()
            .map(|spec| {
                let (opt_report, plan) = match spec.source {
                    ModelSource::Config { nl, opt_level } => {
                        // optimize at registration: bit-exact by
                        // contract, so n_in / out_width are unchanged
                        // and every batch this server ever evaluates
                        // runs on the smaller netlist
                        let level = opt_level.unwrap_or(cfg.opt_level);
                        let (nl, opt_report) = optimize(&nl, level);
                        log::info!("model '{}' optimizer: {}", spec.name,
                                   opt_report.summary());
                        // compile once, through the cache: workers
                        // execute the shared immutable plan with
                        // private scratch; content-identical models
                        // share one plan outright, and a persistent
                        // cache answers from disk before compiling
                        let plan = plans
                            .get_or_compile(&nl, PlanOptions::default());
                        (opt_report, plan)
                    }
                    ModelSource::Artifact(m) => {
                        let NlbModel { netlist, plan } = m;
                        let plan = match plan {
                            // the artifact shipped its compiled plan:
                            // admit it (cache-shared, re-verified)
                            // rather than recompiling
                            Some(p) => plans
                                .admit(&netlist, p)
                                .unwrap_or_else(|e| {
                                    log::warn!(
                                        "model '{}': artifact plan \
                                         rejected ({e:#}), recompiling",
                                        spec.name);
                                    plans.get_or_compile(
                                        &netlist,
                                        PlanOptions::default())
                                }),
                            None => plans.get_or_compile(
                                &netlist, PlanOptions::default()),
                        };
                        // served verbatim: the report records that no
                        // pass ran on the artifact
                        let entries: usize = netlist
                            .layers
                            .iter()
                            .map(|l| l.tables.len())
                            .sum();
                        let opt_report = OptReport {
                            level: OptLevel::None,
                            passes: Vec::new(),
                            units_before: netlist.total_units(),
                            units_after: netlist.total_units(),
                            table_entries_before: entries,
                            table_entries_after: entries,
                        };
                        (opt_report, plan)
                    }
                };
                let n_in = plan.n_in();
                let out_width = plan.out_width();
                let mut policy = spec.policy.unwrap_or(default_policy);
                policy.max_batch = policy.max_batch.max(1);
                // the model's batch ceiling is the best batch-size hint
                // a server has: a model capped at small batches never
                // profits from wide lanes
                let lane_width =
                    select_backend(cfg.lanes, policy.max_batch);
                log::info!("model '{}' plan: {} ({}x64-sample lanes)",
                           spec.name, plan.stats().summary(), lane_width);
                Arc::new(ModelState {
                    name: spec.name,
                    plan,
                    policy,
                    lane_width,
                    n_in,
                    out_width,
                    opt_report,
                    stats: Mutex::new(LatencyStats::default()),
                    batches: Mutex::new(BatchStats::default()),
                })
            })
            .collect();
        let by_name = models
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name.clone(), i))
            .collect();

        let (tx, rx) = channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        // router: per-model batch assembly; workers: evaluation
        let (btx, brx) = channel::<BatchJob>();
        let brx = Arc::new(Mutex::new(brx));
        let mut handles = Vec::new();

        {
            let stop = stop.clone();
            let models = models.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("nla-router".into())
                    .spawn(move || router_loop(rx, btx, &models, &stop))
                    .expect("spawn router"),
            );
        }
        let sim_opts = SimOptions {
            threads: cfg.sim_threads.max(1),
            lanes: cfg.lanes,
            ..SimOptions::default()
        };
        for w in 0..cfg.workers.max(1) {
            let brx = brx.clone();
            let models = models.clone();
            let stop = stop.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("nla-worker-{w}"))
                    .spawn(move || worker_loop(&brx, &models, &stop, sim_opts))
                    .expect("spawn worker"),
            );
        }

        InferenceServer {
            tx: Mutex::new(Some(tx)),
            models,
            by_name,
            plans,
            stop,
            handles: Mutex::new(handles),
        }
    }

    /// Single-model convenience: a registry of one, named after the
    /// netlist.
    pub fn start_single(nl: Netlist, cfg: ServerConfig) -> InferenceServer {
        let mut registry = ModelRegistry::new();
        let name =
            if nl.name.is_empty() { "default".into() } else { nl.name.clone() };
        registry.register(&name, nl);
        InferenceServer::start(registry, cfg)
    }

    /// Hosted model names, in registration order.
    pub fn models(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    /// The first registered model (convenience for single-model use).
    pub fn default_model(&self) -> &str {
        &self.models[0].name
    }

    fn model(&self, name: &str) -> Result<(usize, &Arc<ModelState>)> {
        match self.by_name.get(name) {
            Some(&i) => Ok((i, &self.models[i])),
            None => anyhow::bail!("unknown model '{name}'"),
        }
    }

    fn sender(&self) -> Result<Sender<Request>> {
        match self.tx.lock().unwrap().as_ref() {
            Some(tx) => Ok(tx.clone()),
            None => anyhow::bail!("server stopped"),
        }
    }

    /// Input width / output width of a hosted model.
    pub fn model_io(&self, model: &str) -> Result<(usize, usize)> {
        let (_, m) = self.model(model)?;
        Ok((m.n_in, m.out_width))
    }

    /// Asynchronous request: validate and enqueue one sample for
    /// `model`, returning a [`Pending`] handle immediately.  The
    /// submitting thread is free to pipeline more requests (the TCP
    /// frontend's reader thread does exactly this) while the
    /// router/worker pipeline batches and evaluates.
    pub fn submit(&self, model: &str, x: Vec<i32>) -> Result<Pending> {
        let (idx, m) = self.model(model)?;
        anyhow::ensure!(x.len() == m.n_in,
                        "bad input width {} for model '{model}' (n_in {})",
                        x.len(), m.n_in);
        let tx = self.sender()?;
        let (rtx, rrx) = channel();
        tx.send(Request { model: idx, x, enqueued: Instant::now(),
                          reply: rtx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(Pending { rx: rrx })
    }

    /// Synchronous request: submit one sample to `model`, wait for its
    /// output codes.
    pub fn infer(&self, model: &str, x: Vec<i32>) -> Result<Vec<i32>> {
        self.submit(model, x)?.wait()
    }

    /// Fire-and-collect: submit many samples for `model` from this
    /// thread, waiting for each (benches pair this with multiple client
    /// threads — and multiple models).
    pub fn infer_many(&self, model: &str, rows: Vec<Vec<i32>>)
                      -> Result<Vec<Vec<i32>>> {
        let pending: Vec<Pending> = rows
            .into_iter()
            .map(|x| self.submit(model, x))
            .collect::<Result<_>>()?;
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// A [`ModelEngine`] view of one hosted model (implements
    /// `InferenceEngine`, so the conformance suite runs against the
    /// whole router/worker pipeline).
    pub fn engine(&self, model: &str) -> Result<ModelEngine<'_>> {
        let (_, m) = self.model(model)?;
        Ok(ModelEngine {
            server: self,
            model: m.name.clone(),
            n_in: m.n_in,
            out_width: m.out_width,
        })
    }

    /// The optimizer report recorded when `model` was registered (what
    /// the pass pipeline removed from its netlist).
    pub fn opt_report(&self, model: &str) -> Result<OptReport> {
        let (_, m) = self.model(model)?;
        Ok(m.opt_report.clone())
    }

    /// The compiled execution plan `model`'s workers run (shared,
    /// immutable; content-identical models return the same `Arc`).
    pub fn model_plan(&self, model: &str) -> Result<Arc<ExecPlan>> {
        let (_, m) = self.model(model)?;
        Ok(m.plan.clone())
    }

    /// Arena/dedup statistics of `model`'s compiled plan.
    pub fn plan_stats(&self, model: &str) -> Result<PlanStats> {
        let (_, m) = self.model(model)?;
        Ok(m.plan.stats())
    }

    /// Lane width (64-sample words per op) `model`'s workers execute
    /// at — resolved once at startup from [`ServerConfig::lanes`] and
    /// the model's `max_batch`.
    pub fn model_lane_width(&self, model: &str) -> Result<usize> {
        let (_, m) = self.model(model)?;
        Ok(m.lane_width)
    }

    /// (distinct plans compiled, cache hits) across all registrations —
    /// hits mean several models shared one compilation.
    pub fn plan_cache_counts(&self) -> (usize, u64) {
        (self.plans.len(), self.plans.hits())
    }

    /// Registrations answered by loading a plan image from the
    /// persistent cache directory instead of compiling (always 0
    /// without [`ServerConfig::plan_cache_dir`]).
    pub fn plan_cache_disk_hits(&self) -> u64 {
        self.plans.disk_hits()
    }

    /// Statistics snapshot for one model.
    pub fn model_stats(&self, model: &str) -> Result<ModelStats> {
        let (_, m) = self.model(model)?;
        Ok(snapshot(m))
    }

    /// Statistics for every hosted model, in registration order.
    pub fn all_stats(&self) -> Vec<ModelStats> {
        self.models.iter().map(|m| snapshot(m)).collect()
    }

    /// Stop the server and join all threads (see the module doc for the
    /// two-tier protocol).  Idempotent; takes `&self` so client threads
    /// holding an `Arc<InferenceServer>` can keep submitting (and get
    /// "server stopped" errors) while another thread shuts down.
    pub fn shutdown(&self) {
        // tier 1: close the request channel; the router flushes pending
        // requests as final batches and exits, closing the batch channel
        if let Ok(mut tx) = self.tx.lock() {
            let _ = tx.take();
        }
        let handles = match self.handles.lock() {
            Ok(mut h) => std::mem::take(&mut *h),
            Err(_) => Vec::new(),
        };
        let mut it = handles.into_iter();
        if let Some(router) = it.next() {
            let _ = router.join();
        }
        // tier 2: raise the stop flag only after the router has flushed,
        // so workers cannot exit past an in-flight final batch; they
        // drain the (now closed) batch channel, then observe either the
        // disconnect or the flag and terminate
        self.stop.store(true, Ordering::SeqCst);
        for h in it {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn snapshot(m: &ModelState) -> ModelStats {
    // clone under the lock, sort/summarize outside it: summary() sorts
    // the (up to 64Ki-sample) reservoir, and workers block on this same
    // mutex to record batch latencies
    let stats = m.stats.lock().unwrap().clone();
    let latency = stats.summary();
    let b = m.batches.lock().unwrap().clone();
    ModelStats {
        model: m.name.clone(),
        requests: b.requests(),
        batches: b.batches(),
        mean_occupancy: b.mean_occupancy(),
        max_batch_seen: b.max_size(),
        latency,
    }
}

/// Send every full-or-due batch (every non-empty one when `flush`).
/// Returns false if the batch channel is closed (workers gone).
fn dispatch_due(pending: &mut [Vec<Request>], n_pending: &mut usize,
                models: &[Arc<ModelState>], btx: &Sender<BatchJob>,
                flush: bool) -> bool {
    let now = Instant::now();
    for (m, q) in pending.iter_mut().enumerate() {
        let pol = &models[m].policy;
        while !q.is_empty() {
            let full = q.len() >= pol.max_batch;
            let due = now >= q[0].enqueued + pol.max_wait;
            if !(full || due || flush) {
                break;
            }
            let take = q.len().min(pol.max_batch);
            let reqs: Vec<Request> = q.drain(..take).collect();
            *n_pending -= take;
            models[m].batches.lock().unwrap().record(take);
            if btx.send(BatchJob { model: m, reqs }).is_err() {
                return false;
            }
        }
    }
    true
}

fn router_loop(rx: Receiver<Request>, btx: Sender<BatchJob>,
               models: &[Arc<ModelState>], stop: &AtomicBool) {
    let mut pending: Vec<Vec<Request>> =
        models.iter().map(|_| Vec::new()).collect();
    let mut n_pending = 0usize;
    loop {
        if stop.load(Ordering::SeqCst) && n_pending == 0 {
            break;
        }
        // drain whatever is available without blocking; stop early if a
        // queue fills so heavy inflow cannot starve dispatch
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    let m = req.model;
                    pending[m].push(req);
                    n_pending += 1;
                    if pending[m].len() >= models[m].policy.max_batch {
                        break;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stop.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        let flush = stop.load(Ordering::SeqCst);
        if !dispatch_due(&mut pending, &mut n_pending, models, &btx, flush) {
            break;
        }
        if flush {
            continue; // drain the channel tail, then exit at the top
        }
        // block until the next request or the earliest batch deadline —
        // never spin: partial batches sleep exactly until they are due
        let wait = pending
            .iter()
            .enumerate()
            .filter_map(|(m, q)| {
                q.first().map(|r| r.enqueued + models[m].policy.max_wait)
            })
            .min()
            .map(|deadline| {
                deadline.saturating_duration_since(Instant::now())
            })
            .unwrap_or(ROUTER_IDLE_POLL);
        if wait.is_zero() {
            continue; // already due; dispatch on the next pass
        }
        match rx.recv_timeout(wait) {
            Ok(req) => {
                let m = req.model;
                pending[m].push(req);
                n_pending += 1;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                stop.store(true, Ordering::SeqCst);
            }
        }
    }
    // btx drops here; workers exit when the channel closes
}

fn worker_loop(brx: &Mutex<Receiver<BatchJob>>, models: &[Arc<ModelState>],
               stop: &AtomicBool, sim_opts: SimOptions) {
    // one plan executor per hosted model: the *plan* (tables, wiring,
    // schedule) is the registration-time compile shared by every worker;
    // only the scratch buffers here are private.  Each executor runs at
    // the lane width resolved for its model at startup, so every worker
    // serves a model with the same backend.  A single worker pool is
    // lent to whichever model's executor is evaluating: this worker
    // drives one batch at a time, so parked evaluation threads scale
    // with `workers`, not `workers × models`.
    let mut exs: Vec<LaneExecutor> = models
        .iter()
        .map(|m| LaneExecutor::for_width(m.lane_width, m.plan.clone(),
                                         sim_opts))
        .collect();
    let mut lent = if sim_opts.threads > 1 {
        Some(WorkerPool::new(sim_opts.threads - 1))
    } else {
        None
    };
    // reused across batches: steady-state serving allocates only the
    // per-request reply vectors
    let mut x: Vec<i32> = Vec::new();
    let mut out: Vec<i32> = Vec::new();
    loop {
        let job = {
            let guard = brx.lock().unwrap();
            guard.recv_timeout(WORKER_POLL)
        };
        let job = match job {
            Ok(job) => job,
            Err(RecvTimeoutError::Timeout) => {
                // the stop-flag check keeps workers joinable even if a
                // batch producer wedges with the channel open
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let state = &models[job.model];
        let bsz = job.reqs.len();
        let ow = state.out_width; // hoisted: one lookup per batch
        x.clear();
        for r in &job.reqs {
            x.extend_from_slice(&r.x);
        }
        let ex = &mut exs[job.model];
        let prev = ex.set_pool(lent.take());
        debug_assert!(prev.is_none(), "model executors own no pool");
        ex.eval_batch_into(&x, bsz, &mut out);
        lent = ex.set_pool(prev);
        let now = Instant::now();
        {
            // the whole batch's latencies under one lock acquisition
            let mut stats = state.stats.lock().unwrap();
            for r in &job.reqs {
                stats.record(
                    now.duration_since(r.enqueued).as_secs_f64() * 1e6);
            }
        }
        for (i, r) in job.reqs.into_iter().enumerate() {
            let _ = r.reply.send(out[i * ow..(i + 1) * ow].to_vec());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::testutil::{random_inputs, random_netlist,
                                   random_reducible_netlist};

    #[test]
    fn server_matches_direct_simulation() {
        let nl = random_netlist(31, 12, 1, &[(8, 3, 2), (4, 2, 2), (2, 2, 3)]);
        let direct = nl.clone();
        let server = InferenceServer::start_single(
            nl,
            ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100),
                           workers: 2, sim_threads: 1,
                           ..Default::default() },
        );
        let model = server.default_model().to_string();
        let x = random_inputs(31, &direct, 40);
        let rows: Vec<Vec<i32>> =
            (0..40).map(|b| x[b * 12..(b + 1) * 12].to_vec()).collect();
        let got = server.infer_many(&model, rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            let want = direct.eval_one(row).unwrap();
            assert_eq!(got[b], want, "row {b}");
        }
        let st = server.model_stats(&model).unwrap();
        assert_eq!(st.requests, 40);
        assert!(st.batches >= 5 && st.batches <= 40); // max_batch 8
        assert!(st.mean_occupancy >= 1.0 && st.mean_occupancy <= 8.0);
        assert!(st.max_batch_seen <= 8);
        assert!(st.latency.mean > 0.0);
        assert!(st.latency.p50 <= st.latency.p99
                && st.latency.p99 <= st.latency.p999);
        server.shutdown();
    }

    #[test]
    fn server_single_request() {
        let nl = random_netlist(32, 6, 2, &[(3, 2, 2)]);
        let direct = nl.clone();
        let server = InferenceServer::start_single(nl, ServerConfig::default());
        let x = random_inputs(9, &direct, 1);
        let got = server.infer(server.default_model(), x.clone()).unwrap();
        assert_eq!(got, direct.eval_one(&x).unwrap());
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly_and_is_idempotent() {
        let nl = random_netlist(33, 4, 1, &[(2, 2, 1)]);
        let server = InferenceServer::start_single(nl, ServerConfig::default());
        server.shutdown(); // no hang
        server.shutdown(); // second call is a no-op
        assert!(server
            .infer(server.default_model(), vec![0, 0, 0, 0])
            .is_err());
    }

    #[test]
    fn sim_threads_answers_match_direct_eval() {
        let nl = random_netlist(35, 16, 2, &[(12, 2, 2), (6, 2, 2), (3, 2, 2)]);
        let direct = nl.clone();
        let server = InferenceServer::start_single(
            nl,
            ServerConfig { max_batch: 128,
                           max_wait: Duration::from_micros(200),
                           workers: 1, sim_threads: 4,
                           ..Default::default() },
        );
        let model = server.default_model().to_string();
        let x = random_inputs(35, &direct, 96);
        let rows: Vec<Vec<i32>> =
            (0..96).map(|b| x[b * 16..(b + 1) * 16].to_vec()).collect();
        let got = server.infer_many(&model, rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            assert_eq!(got[b], direct.eval_one(row).unwrap(), "row {b}");
        }
        server.shutdown();
    }

    #[test]
    fn lane_config_resolves_per_model_and_stays_bit_exact() {
        // default config: max_batch 64 is under the auto threshold, so
        // workers stay on the scalar path; pinning W4 forces wide
        // execution, and served answers must stay bit-exact either way
        let nl = random_netlist(61, 12, 1, &[(8, 3, 2), (4, 2, 2)]);
        let direct = nl.clone();
        let auto = InferenceServer::start_single(nl.clone(),
                                                 ServerConfig::default());
        let model = auto.default_model().to_string();
        assert_eq!(auto.model_lane_width(&model).unwrap(), 1,
                   "auto keeps small batch ceilings scalar");
        assert!(auto.model_lane_width("nope").is_err());
        auto.shutdown();
        // a large batch ceiling under Auto goes wide on every CPU we
        // build for (widest_supported_lane is >= 4 on all targets)
        let big = InferenceServer::start_single(
            nl.clone(),
            ServerConfig { max_batch: 1024, ..Default::default() });
        assert!(big.model_lane_width(&model).unwrap() >= 4);
        big.shutdown();
        let wide = InferenceServer::start_single(
            nl,
            ServerConfig { max_batch: 16, lanes: LaneSelect::W4,
                           ..Default::default() },
        );
        assert_eq!(wide.model_lane_width(&model).unwrap(), 4);
        let x = random_inputs(61, &direct, 40);
        let rows: Vec<Vec<i32>> =
            (0..40).map(|b| x[b * 12..(b + 1) * 12].to_vec()).collect();
        let got = wide.infer_many(&model, rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            assert_eq!(got[b], direct.eval_one(row).unwrap(),
                       "wide row {b}");
        }
        wide.shutdown();
    }

    #[test]
    fn workers_observe_stop_flag_without_channel_close() {
        // the observable contract: shutdown() joins promptly even right
        // after a burst of traffic
        let nl = random_netlist(36, 6, 1, &[(3, 2, 1)]);
        let direct = nl.clone();
        let server = InferenceServer::start_single(nl, ServerConfig::default());
        let model = server.default_model().to_string();
        let x = random_inputs(36, &direct, 8);
        for b in 0..8 {
            server.infer(&model, x[b * 6..(b + 1) * 6].to_vec()).unwrap();
        }
        let t = std::time::Instant::now();
        server.shutdown();
        assert!(t.elapsed() < Duration::from_secs(2), "shutdown hung");
    }

    #[test]
    fn opt_level_knob_is_bit_exact_and_recorded() {
        // the same netlist served optimized and raw side by side: both
        // must answer exactly like the raw eval_one reference, and the
        // per-model opt reports must reflect the level actually applied
        let nl = random_reducible_netlist(
            44, 16, 2, &[(24, 3, 2), (12, 2, 2), (4, 2, 2)], 6);
        let direct = nl.clone();
        let mut registry = ModelRegistry::new();
        registry
            .register_with_opt("optimized", nl.clone(), None,
                               Some(OptLevel::Full))
            .register_with_opt("raw", nl, None, Some(OptLevel::None));
        let server = InferenceServer::start(registry,
                                            ServerConfig::default());
        let ro = server.opt_report("optimized").unwrap();
        let rr = server.opt_report("raw").unwrap();
        assert_eq!(rr.units_removed(), 0, "O0 must not touch the model");
        assert!(ro.units_after <= ro.units_before);
        assert!(ro.summary().starts_with("O2:"));
        let x = random_inputs(44, &direct, 24);
        for b in 0..24 {
            let row = x[b * 16..(b + 1) * 16].to_vec();
            let want = direct.eval_one(&row).unwrap();
            assert_eq!(server.infer("optimized", row.clone()).unwrap(),
                       want, "optimized row {b}");
            assert_eq!(server.infer("raw", row).unwrap(), want,
                       "raw row {b}");
        }
        assert!(server.opt_report("nope").is_err());
        server.shutdown();
    }

    #[test]
    fn identical_models_share_one_compiled_plan() {
        // the same netlist registered twice: the plan cache must compile
        // once, both models answer correctly, and a distinct third model
        // gets its own plan
        let nl = random_netlist(46, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let other = random_netlist(47, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let direct = nl.clone();
        let mut registry = ModelRegistry::new();
        registry
            .register("twin-a", nl.clone())
            .register("twin-b", nl)
            .register("solo", other);
        let server = InferenceServer::start(registry,
                                            ServerConfig::default());
        let pa = server.model_plan("twin-a").unwrap();
        let pb = server.model_plan("twin-b").unwrap();
        let pc = server.model_plan("solo").unwrap();
        assert!(Arc::ptr_eq(&pa, &pb), "identical content must share");
        assert!(!Arc::ptr_eq(&pa, &pc));
        let (compiled, hits) = server.plan_cache_counts();
        assert_eq!(compiled, 2, "two distinct plans for three models");
        assert_eq!(hits, 1);
        assert!(server.plan_stats("twin-a").unwrap().layers == 2);
        let x = random_inputs(46, &direct, 12);
        for b in 0..12 {
            let row = x[b * 10..(b + 1) * 10].to_vec();
            let want = direct.eval_one(&row).unwrap();
            assert_eq!(server.infer("twin-a", row.clone()).unwrap(), want);
            assert_eq!(server.infer("twin-b", row).unwrap(), want);
        }
        assert!(server.plan_stats("nope").is_err());
        server.shutdown();
    }

    #[test]
    fn two_models_route_independently() {
        // different widths so a misrouted request cannot silently pass
        let a = random_netlist(41, 12, 1, &[(8, 3, 2), (4, 2, 2)]);
        let b = random_netlist(42, 6, 2, &[(5, 2, 3), (3, 2, 2)]);
        let (da, db) = (a.clone(), b.clone());
        let mut registry = ModelRegistry::new();
        registry.register("a", a).register_with(
            "b",
            b,
            Some(BatchPolicy { max_batch: 4,
                               max_wait: Duration::from_micros(50) }),
        );
        assert_eq!(registry.names(), vec!["a".to_string(), "b".to_string()]);
        let server = InferenceServer::start(registry, ServerConfig::default());
        assert_eq!(server.default_model(), "a");
        assert_eq!(server.model_io("a").unwrap(), (12, 4));
        assert_eq!(server.model_io("b").unwrap(), (6, 3));
        let xa = random_inputs(1, &da, 30);
        let xb = random_inputs(2, &db, 30);
        // interleave the two models' traffic
        for i in 0..30 {
            let ra = server
                .infer("a", xa[i * 12..(i + 1) * 12].to_vec())
                .unwrap();
            assert_eq!(ra, da.eval_one(&xa[i * 12..(i + 1) * 12]).unwrap(),
                       "model a row {i}");
            let rb = server
                .infer("b", xb[i * 6..(i + 1) * 6].to_vec())
                .unwrap();
            assert_eq!(rb, db.eval_one(&xb[i * 6..(i + 1) * 6]).unwrap(),
                       "model b row {i}");
        }
        let sa = server.model_stats("a").unwrap();
        let sb = server.model_stats("b").unwrap();
        assert_eq!(sa.requests, 30);
        assert_eq!(sb.requests, 30);
        assert!(sb.max_batch_seen <= 4, "model b's policy caps its batches");
        assert!(server.infer("nope", vec![0; 12]).is_err());
        assert!(server.infer("a", vec![0; 5]).is_err(), "width check");
        server.shutdown();
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("nid_server_{tag}_{}", std::process::id()))
    }

    #[test]
    fn artifact_serving_is_bit_exact_with_config_serving() {
        use crate::netlist::{compile, save_nlb};
        // the export → serve round trip: optimize + compile a model,
        // save it as an .nlb with its plan image, and serve the file
        // next to the config-built registration of the same netlist
        let nl = random_reducible_netlist(
            55, 12, 2, &[(16, 3, 2), (8, 2, 2), (4, 2, 2)], 6);
        let direct = nl.clone();
        let (opt_nl, _) = optimize(&nl, OptLevel::Full);
        let plan = Arc::new(compile(&opt_nl, PlanOptions::default()));
        let path = temp_path("artifact.nlb");
        save_nlb(&path, &opt_nl, Some(&plan)).unwrap();

        let mut registry = ModelRegistry::new();
        registry.register("config", nl);
        registry.register_file("artifact", &path).unwrap();
        let server =
            InferenceServer::start(registry, ServerConfig::default());
        // the artifact's plan image was admitted, not recompiled: the
        // config model compiled once and the artifact shared it (same
        // optimized content), so exactly one plan is resident
        let (compiled, _) = server.plan_cache_counts();
        assert_eq!(compiled, 1);
        assert!(Arc::ptr_eq(&server.model_plan("config").unwrap(),
                            &server.model_plan("artifact").unwrap()));
        // artifacts skip the optimizer: the report records no passes
        let report = server.opt_report("artifact").unwrap();
        assert!(report.passes.is_empty());
        assert_eq!(report.units_removed(), 0);
        let x = random_inputs(55, &direct, 24);
        for b in 0..24 {
            let row = x[b * 12..(b + 1) * 12].to_vec();
            let want = direct.eval_one(&row).unwrap();
            assert_eq!(server.infer("config", row.clone()).unwrap(),
                       want, "config row {b}");
            assert_eq!(server.infer("artifact", row).unwrap(), want,
                       "artifact row {b}");
        }
        server.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn artifact_without_plan_image_compiles_on_registration() {
        use crate::netlist::save_nlb;
        let nl = random_netlist(56, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
        let direct = nl.clone();
        let path = temp_path("plain.nlb");
        save_nlb(&path, &nl, None).unwrap();
        let mut registry = ModelRegistry::new();
        registry.register_file("m", &path).unwrap();
        let server =
            InferenceServer::start(registry, ServerConfig::default());
        let x = random_inputs(56, &direct, 8);
        for b in 0..8 {
            let row = x[b * 8..(b + 1) * 8].to_vec();
            assert_eq!(server.infer("m", row.clone()).unwrap(),
                       direct.eval_one(&row).unwrap());
        }
        server.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn register_file_rejects_corrupt_artifacts() {
        let path = temp_path("bad.nlb");
        std::fs::write(&path, b"not an artifact").unwrap();
        let mut registry = ModelRegistry::new();
        assert!(registry.register_file("m", &path).is_err());
        let _ = std::fs::remove_file(&path);
        assert!(registry
            .register_file("m", temp_path("missing.nlb"))
            .is_err());
    }

    #[test]
    fn restarted_server_cold_loads_plans_from_cache_dir() {
        let dir = temp_path("plan_cache");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServerConfig {
            plan_cache_dir: Some(dir.clone()),
            ..Default::default()
        };
        let nl = random_reducible_netlist(
            57, 10, 2, &[(8, 3, 2), (4, 2, 2)], 6);
        let direct = nl.clone();
        {
            let server = InferenceServer::start_single(nl.clone(),
                                                       cfg.clone());
            assert_eq!(server.plan_cache_disk_hits(), 0);
            server.shutdown();
        }
        // same registration in a "new process": the plan comes off
        // disk, and the served answers are still bit-exact
        let server = InferenceServer::start_single(nl, cfg);
        assert_eq!(server.plan_cache_disk_hits(), 1);
        let model = server.default_model().to_string();
        let x = random_inputs(57, &direct, 16);
        for b in 0..16 {
            let row = x[b * 10..(b + 1) * 10].to_vec();
            assert_eq!(server.infer(&model, row.clone()).unwrap(),
                       direct.eval_one(&row).unwrap(), "row {b}");
        }
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
