//! Dynamic-batching inference server over the bit-exact netlist simulator.
//!
//! Deployment story of an ultra-low-latency LUT network: the "FPGA" (our
//! simulator) answers classification requests.  A router thread collects
//! requests into batches — dispatching either when `max_batch` is reached
//! or when the oldest waiting request exceeds `max_wait`, the standard
//! latency/throughput knob — and worker threads evaluate batches on their
//! own simulator instances.  Python is nowhere on this path.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::LatencyStats;
use crate::netlist::Netlist;

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            workers: 2,
        }
    }
}

struct Request {
    x: Vec<i32>,
    enqueued: Instant,
    reply: Sender<Vec<i32>>,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: Sender<Request>,
    n_in: usize,
    out_width: usize,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<LatencyStats>>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
}

impl InferenceServer {
    /// Spawn the router + workers for a netlist.
    pub fn start(nl: Netlist, cfg: ServerConfig) -> InferenceServer {
        let n_in = nl.n_in;
        let out_width = nl.out_width();
        let (tx, rx) = channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(LatencyStats::default()));
        let batches = Arc::new(AtomicU64::new(0));
        let requests = Arc::new(AtomicU64::new(0));

        // router: batch assembly; workers: evaluation
        let (btx, brx) = channel::<Vec<Request>>();
        let brx = Arc::new(Mutex::new(brx));
        let mut handles = Vec::new();

        {
            let stop = stop.clone();
            let cfg = cfg.clone();
            let batches = batches.clone();
            handles.push(std::thread::spawn(move || {
                router_loop(rx, btx, &cfg, &stop, &batches);
            }));
        }
        let nl = Arc::new(nl);
        for _ in 0..cfg.workers.max(1) {
            let brx = brx.clone();
            let nl = nl.clone();
            let stats = stats.clone();
            let requests = requests.clone();
            handles.push(std::thread::spawn(move || {
                let mut sim = nl.simulator();
                loop {
                    let batch = {
                        let guard = brx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    let bsz = batch.len();
                    let mut x = Vec::with_capacity(bsz * nl.n_in);
                    for r in &batch {
                        x.extend_from_slice(&r.x);
                    }
                    let out = sim.eval_batch(&x, bsz);
                    let now = Instant::now();
                    for (i, r) in batch.into_iter().enumerate() {
                        let row =
                            out[i * nl.out_width()..(i + 1) * nl.out_width()].to_vec();
                        let lat = now.duration_since(r.enqueued).as_secs_f64() * 1e6;
                        stats.lock().unwrap().record(lat);
                        let _ = r.reply.send(row);
                    }
                    requests.fetch_add(bsz as u64, Ordering::Relaxed);
                }
            }));
        }

        InferenceServer { tx, n_in, out_width, stop, handles, stats, batches, requests }
    }

    /// Synchronous request: submit one sample, wait for its output codes.
    pub fn infer(&self, x: Vec<i32>) -> Result<Vec<i32>> {
        anyhow::ensure!(x.len() == self.n_in, "bad input width");
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { x, enqueued: Instant::now(), reply: rtx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx.recv()?)
    }

    /// Fire-and-collect: submit many samples from this thread, waiting for
    /// each (used by benches together with multiple client threads).
    pub fn infer_many(&self, rows: Vec<Vec<i32>>) -> Result<Vec<Vec<i32>>> {
        let mut replies = Vec::with_capacity(rows.len());
        for x in rows {
            let (rtx, rrx) = channel();
            self.tx
                .send(Request { x, enqueued: Instant::now(), reply: rtx })
                .map_err(|_| anyhow::anyhow!("server stopped"))?;
            replies.push(rrx);
        }
        replies.into_iter().map(|r| Ok(r.recv()?)).collect()
    }

    pub fn out_width(&self) -> usize {
        self.out_width
    }

    /// (requests served, batches dispatched, mean latency us, p99 us)
    pub fn stats(&self) -> (u64, u64, f64, f64) {
        let s = self.stats.lock().unwrap();
        (
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            s.mean(),
            s.percentile(99.0),
        )
    }

    /// Stop the server and join all threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx); // closes the router's receiver eventually
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn router_loop(rx: Receiver<Request>, btx: Sender<Vec<Request>>,
               cfg: &ServerConfig, stop: &AtomicBool, batches: &AtomicU64) {
    let mut pending: Vec<Request> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) && pending.is_empty() {
            break;
        }
        let deadline = pending
            .first()
            .map(|r| r.enqueued + cfg.max_wait)
            .unwrap_or_else(|| Instant::now() + Duration::from_millis(5));
        // drain whatever is available
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    pending.push(req);
                    if pending.len() >= cfg.max_batch {
                        break;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stop.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        let now = Instant::now();
        if !pending.is_empty() && (pending.len() >= cfg.max_batch || now >= deadline) {
            let take = pending.len().min(cfg.max_batch);
            let batch: Vec<Request> = pending.drain(..take).collect();
            batches.fetch_add(1, Ordering::Relaxed);
            if btx.send(batch).is_err() {
                break;
            }
        } else if pending.is_empty() {
            // block briefly for the next request
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(req) => pending.push(req),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    stop.store(true, Ordering::SeqCst);
                }
            }
        } else {
            std::thread::sleep(Duration::from_micros(20));
        }
    }
    // btx drops here; workers exit when the channel closes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::testutil::{random_inputs, random_netlist};

    #[test]
    fn server_matches_direct_simulation() {
        let nl = random_netlist(31, 12, 1, &[(8, 3, 2), (4, 2, 2), (2, 2, 3)]);
        let direct = nl.clone();
        let server = InferenceServer::start(
            nl,
            ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100), workers: 2 },
        );
        let x = random_inputs(31, &direct, 40);
        let rows: Vec<Vec<i32>> = (0..40).map(|b| x[b * 12..(b + 1) * 12].to_vec()).collect();
        let got = server.infer_many(rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            let want = direct.eval_one(row).unwrap();
            assert_eq!(got[b], want, "row {b}");
        }
        let (reqs, batches, mean, p99) = server.stats();
        assert_eq!(reqs, 40);
        assert!(batches >= 1 && batches <= 40);
        assert!(mean > 0.0 && p99 >= mean * 0.5);
        server.shutdown();
    }

    #[test]
    fn server_single_request() {
        let nl = random_netlist(32, 6, 2, &[(3, 2, 2)]);
        let direct = nl.clone();
        let server = InferenceServer::start(nl, ServerConfig::default());
        let x = random_inputs(9, &direct, 1);
        let got = server.infer(x.clone()).unwrap();
        assert_eq!(got, direct.eval_one(&x).unwrap());
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let nl = random_netlist(33, 4, 1, &[(2, 2, 1)]);
        let server = InferenceServer::start(nl, ServerConfig::default());
        server.shutdown(); // no hang
    }
}
