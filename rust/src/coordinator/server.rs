//! Dynamic-batching inference server over the bit-exact netlist simulator.
//!
//! Deployment story of an ultra-low-latency LUT network: the "FPGA" (our
//! simulator) answers classification requests.  A router thread collects
//! requests into batches — dispatching either when `max_batch` is reached
//! or when the oldest waiting request exceeds `max_wait`, the standard
//! latency/throughput knob — and worker threads evaluate batches on their
//! own simulator instances (each with `sim_threads` evaluation threads,
//! so one big batch can fan out across cores).  Python is nowhere on this
//! path.
//!
//! # Shutdown protocol
//!
//! [`InferenceServer::shutdown`] stops the pipeline in two tiers:
//!
//! 1. the request sender is dropped and the router is joined.  The
//!    router observes the disconnect (setting the shared `stop` flag
//!    itself), flushes any pending requests as a final batch, then exits
//!    — dropping the batch sender.
//! 2. the `stop` flag is raised and workers are joined.  Workers drain
//!    the batch channel and exit when it disconnects (router gone) **or**
//!    when `stop` is set and no batch arrives within one poll interval
//!    (`WORKER_POLL`).  The flag check means workers terminate even if
//!    a batch producer wedges with the channel open, so worker joins
//!    cannot hang; raising it only *after* the router flush means no
//!    in-flight request is dropped.
//!
//! In-flight requests are answered before their worker exits; requests
//! submitted after shutdown fail with "server stopped".

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender,
                      TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::LatencyStats;
use crate::netlist::{Netlist, SimOptions};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Concurrent batch-evaluation workers (each owns a simulator).
    pub workers: usize,
    /// Evaluation threads *inside* each worker's simulator: large batches
    /// are chunked over unit ranges (`SimOptions::threads`).  1 keeps the
    /// v1 behavior; raise it when `max_batch` is large and cores outnumber
    /// concurrent batches.
    pub sim_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            workers: 2,
            sim_threads: 1,
        }
    }
}

/// How long an idle worker waits on the batch channel before re-checking
/// the stop flag.
const WORKER_POLL: Duration = Duration::from_millis(2);

struct Request {
    x: Vec<i32>,
    enqueued: Instant,
    reply: Sender<Vec<i32>>,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: Sender<Request>,
    n_in: usize,
    out_width: usize,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<LatencyStats>>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
}

impl InferenceServer {
    /// Spawn the router + workers for a netlist.
    pub fn start(nl: Netlist, cfg: ServerConfig) -> InferenceServer {
        let n_in = nl.n_in;
        let out_width = nl.out_width();
        let (tx, rx) = channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(LatencyStats::default()));
        let batches = Arc::new(AtomicU64::new(0));
        let requests = Arc::new(AtomicU64::new(0));

        // router: batch assembly; workers: evaluation
        let (btx, brx) = channel::<Vec<Request>>();
        let brx = Arc::new(Mutex::new(brx));
        let mut handles = Vec::new();

        {
            let stop = stop.clone();
            let cfg = cfg.clone();
            let batches = batches.clone();
            handles.push(std::thread::spawn(move || {
                router_loop(rx, btx, &cfg, &stop, &batches);
            }));
        }
        let nl = Arc::new(nl);
        let sim_opts = SimOptions {
            threads: cfg.sim_threads.max(1),
            ..SimOptions::default()
        };
        for _ in 0..cfg.workers.max(1) {
            let brx = brx.clone();
            let nl = nl.clone();
            let stats = stats.clone();
            let requests = requests.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut sim = nl.simulator_with(sim_opts);
                loop {
                    let batch = {
                        let guard = brx.lock().unwrap();
                        guard.recv_timeout(WORKER_POLL)
                    };
                    let batch = match batch {
                        Ok(batch) => batch,
                        Err(RecvTimeoutError::Timeout) => {
                            // the stop-flag check keeps workers joinable
                            // even if the router never closes the channel
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            continue;
                        }
                        Err(RecvTimeoutError::Disconnected) => break,
                    };
                    let bsz = batch.len();
                    let mut x = Vec::with_capacity(bsz * nl.n_in);
                    for r in &batch {
                        x.extend_from_slice(&r.x);
                    }
                    let out = sim.eval_batch(&x, bsz);
                    let now = Instant::now();
                    for (i, r) in batch.into_iter().enumerate() {
                        let row =
                            out[i * nl.out_width()..(i + 1) * nl.out_width()].to_vec();
                        let lat = now.duration_since(r.enqueued).as_secs_f64() * 1e6;
                        stats.lock().unwrap().record(lat);
                        let _ = r.reply.send(row);
                    }
                    requests.fetch_add(bsz as u64, Ordering::Relaxed);
                }
            }));
        }

        InferenceServer { tx, n_in, out_width, stop, handles, stats, batches, requests }
    }

    /// Synchronous request: submit one sample, wait for its output codes.
    pub fn infer(&self, x: Vec<i32>) -> Result<Vec<i32>> {
        anyhow::ensure!(x.len() == self.n_in, "bad input width");
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { x, enqueued: Instant::now(), reply: rtx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rrx.recv()?)
    }

    /// Fire-and-collect: submit many samples from this thread, waiting for
    /// each (used by benches together with multiple client threads).
    pub fn infer_many(&self, rows: Vec<Vec<i32>>) -> Result<Vec<Vec<i32>>> {
        let mut replies = Vec::with_capacity(rows.len());
        for x in rows {
            let (rtx, rrx) = channel();
            self.tx
                .send(Request { x, enqueued: Instant::now(), reply: rtx })
                .map_err(|_| anyhow::anyhow!("server stopped"))?;
            replies.push(rrx);
        }
        replies.into_iter().map(|r| Ok(r.recv()?)).collect()
    }

    pub fn out_width(&self) -> usize {
        self.out_width
    }

    /// (requests served, batches dispatched, mean latency us, p99 us)
    pub fn stats(&self) -> (u64, u64, f64, f64) {
        let s = self.stats.lock().unwrap();
        (
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            s.mean(),
            s.percentile(99.0),
        )
    }

    /// Stop the server and join all threads (see the module doc for the
    /// two-tier protocol).
    pub fn shutdown(mut self) {
        // tier 1: close the request channel; the router flushes pending
        // requests as a final batch and exits, closing the batch channel
        drop(self.tx);
        let mut handles = self.handles.drain(..);
        if let Some(router) = handles.next() {
            let _ = router.join();
        }
        // tier 2: raise the stop flag only after the router has flushed,
        // so workers cannot exit past an in-flight final batch; they
        // drain the (now closed) batch channel, then observe either the
        // disconnect or the flag and terminate
        self.stop.store(true, Ordering::SeqCst);
        for h in handles {
            let _ = h.join();
        }
    }
}

fn router_loop(rx: Receiver<Request>, btx: Sender<Vec<Request>>,
               cfg: &ServerConfig, stop: &AtomicBool, batches: &AtomicU64) {
    let mut pending: Vec<Request> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) && pending.is_empty() {
            break;
        }
        let deadline = pending
            .first()
            .map(|r| r.enqueued + cfg.max_wait)
            .unwrap_or_else(|| Instant::now() + Duration::from_millis(5));
        // drain whatever is available
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    pending.push(req);
                    if pending.len() >= cfg.max_batch {
                        break;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    stop.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        let now = Instant::now();
        if !pending.is_empty() && (pending.len() >= cfg.max_batch || now >= deadline) {
            let take = pending.len().min(cfg.max_batch);
            let batch: Vec<Request> = pending.drain(..take).collect();
            batches.fetch_add(1, Ordering::Relaxed);
            if btx.send(batch).is_err() {
                break;
            }
        } else if pending.is_empty() {
            // block briefly for the next request
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(req) => pending.push(req),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    stop.store(true, Ordering::SeqCst);
                }
            }
        } else {
            std::thread::sleep(Duration::from_micros(20));
        }
    }
    // btx drops here; workers exit when the channel closes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::testutil::{random_inputs, random_netlist};

    #[test]
    fn server_matches_direct_simulation() {
        let nl = random_netlist(31, 12, 1, &[(8, 3, 2), (4, 2, 2), (2, 2, 3)]);
        let direct = nl.clone();
        let server = InferenceServer::start(
            nl,
            ServerConfig { max_batch: 8, max_wait: Duration::from_micros(100),
                           workers: 2, sim_threads: 1 },
        );
        let x = random_inputs(31, &direct, 40);
        let rows: Vec<Vec<i32>> = (0..40).map(|b| x[b * 12..(b + 1) * 12].to_vec()).collect();
        let got = server.infer_many(rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            let want = direct.eval_one(row).unwrap();
            assert_eq!(got[b], want, "row {b}");
        }
        let (reqs, batches, mean, p99) = server.stats();
        assert_eq!(reqs, 40);
        assert!(batches >= 1 && batches <= 40);
        assert!(mean > 0.0 && p99 >= mean * 0.5);
        server.shutdown();
    }

    #[test]
    fn server_single_request() {
        let nl = random_netlist(32, 6, 2, &[(3, 2, 2)]);
        let direct = nl.clone();
        let server = InferenceServer::start(nl, ServerConfig::default());
        let x = random_inputs(9, &direct, 1);
        let got = server.infer(x.clone()).unwrap();
        assert_eq!(got, direct.eval_one(&x).unwrap());
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let nl = random_netlist(33, 4, 1, &[(2, 2, 1)]);
        let server = InferenceServer::start(nl, ServerConfig::default());
        server.shutdown(); // no hang
    }

    #[test]
    fn sim_threads_answers_match_direct_eval() {
        let nl = random_netlist(35, 16, 2, &[(12, 2, 2), (6, 2, 2), (3, 2, 2)]);
        let direct = nl.clone();
        let server = InferenceServer::start(
            nl,
            ServerConfig { max_batch: 128,
                           max_wait: Duration::from_micros(200),
                           workers: 1, sim_threads: 4 },
        );
        let x = random_inputs(35, &direct, 96);
        let rows: Vec<Vec<i32>> =
            (0..96).map(|b| x[b * 16..(b + 1) * 16].to_vec()).collect();
        let got = server.infer_many(rows.clone()).unwrap();
        for (b, row) in rows.iter().enumerate() {
            assert_eq!(got[b], direct.eval_one(row).unwrap(), "row {b}");
        }
        server.shutdown();
    }

    #[test]
    fn workers_observe_stop_flag_without_channel_close() {
        // drop the server handle fields by hand: set stop but keep the
        // batch channel alive via a leaked router stand-in is internal;
        // the observable contract is that shutdown() joins promptly even
        // right after a burst of traffic
        let nl = random_netlist(36, 6, 1, &[(3, 2, 1)]);
        let direct = nl.clone();
        let server = InferenceServer::start(nl, ServerConfig::default());
        let x = random_inputs(36, &direct, 8);
        for b in 0..8 {
            server.infer(x[b * 6..(b + 1) * 6].to_vec()).unwrap();
        }
        let t = std::time::Instant::now();
        server.shutdown();
        assert!(t.elapsed() < Duration::from_secs(2), "shutdown hung");
    }
}
