//! L3 coordinator: the toolflow that drives the whole reproduction.
//!
//! * [`Session`] — owns one model configuration's parameter state and its
//!   compiled PJRT executables; exposes train / evaluate / enumerate.
//! * [`flow`] — the end-to-end pipeline of the paper's Fig. 3: QAT
//!   (optionally with the dense learned-mappings pre-phase and pruning),
//!   sub-network → L-LUT conversion, netlist extraction + bit-exactness
//!   verification, technology mapping, timing under both pipelining
//!   strategies, and RTL emission.
//! * [`engine`] — the backend-agnostic [`InferenceEngine`] run
//!   interface (direct simulator or a server-hosted model) plus the
//!   conformance suite every backend must pass.
//! * [`server`] — a multi-model dynamic-batching inference server over
//!   the bit-exact netlist simulator (the deployment-side story of an
//!   ultra-low-latency NN: named models behind shared router/worker
//!   threads, answered by pure table lookups).

pub mod engine;
pub mod flow;
pub mod server;
mod session;

pub use engine::{check_conformance, InferenceEngine, ModelEngine};
pub use flow::{run_flow, FlowOptions, FlowResult};
pub use server::{BatchPolicy, InferenceServer, ModelRegistry, ModelStats,
                 Pending, ServerConfig};
pub use session::Session;
