//! A training/inference session for one compiled configuration.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{ConfigMeta, TrainConfig};
use crate::dataset::Dataset;
use crate::metrics;
use crate::netlist::{LayerSpec, Netlist};
use crate::pruning;
use crate::runtime::{lit_i32, lit_scalar_f32, to_vec_f32, to_vec_i32, Exec,
                     ParamStore, Runtime};
use crate::util::Rng;

/// Owns parameter + optimizer + connection state for one model and the
/// lazily compiled executables that operate on it.
pub struct Session {
    rt: Runtime,
    pub cfg: ConfigMeta,
    /// dense variant (learned layers see the full previous width)?
    pub dense: bool,
    pub params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    /// batch-norm running statistics (EMA-updated by train_step)
    pub stats: ParamStore,
    conn_lits: ParamStore,
    /// plain copy of the connections for netlist extraction
    pub connections: Vec<Vec<Vec<u32>>>,
    /// skip-path multiplier (1.0 normal, 0.0 = "w/o tree-level skips")
    pub skip_scale: f32,
    execs: BTreeMap<String, Exec>,
    /// 1-based Adam step counter
    t: usize,
}

impl Session {
    /// Create a session with freshly initialized parameters and the given
    /// per-layer connections for learned layers (assemble layers always
    /// use the fixed strided wiring).
    pub fn new(rt: &Runtime, cfg: &ConfigMeta, dense: bool,
               learned_conns: Option<&[Vec<Vec<u32>>]>, seed: u64,
               skip_scale: f32) -> Result<Session> {
        let top = &cfg.topology;
        let mut rng = Rng::new(seed);
        let spec = if dense { &cfg.param_spec_dense } else { &cfg.param_spec };
        let params = ParamStore::init_params(spec, &mut rng)?;
        let m = ParamStore::zeros(spec)?;
        let v = ParamStore::zeros(spec)?;
        // BN running stats: mean 0, variance 1
        let mut stats = ParamStore::new();
        for (name, shape) in &cfg.stats_spec {
            let n: usize = shape.iter().product::<usize>().max(1);
            let fill = if name.ends_with("_rv") { 1.0 } else { 0.0 };
            stats.insert(name, crate::runtime::lit_f32(&vec![fill; n], shape)?);
        }

        // connections: one Vec<Vec<u32>> per layer
        let mut connections: Vec<Vec<Vec<u32>>> = Vec::new();
        let mut li = 0usize;
        for l in 0..top.n_layers() {
            if top.a[l] == 1 {
                connections.push(top.fixed_connections(l));
            } else {
                match learned_conns {
                    Some(lc) => {
                        let c = lc
                            .get(li)
                            .with_context(|| format!("missing learned conn for layer {l}"))?;
                        anyhow::ensure!(c.len() == top.w[l], "conn row count");
                        connections.push(c.clone());
                        li += 1;
                    }
                    None => {
                        let mut crng = rng.fork(100 + l as u64);
                        connections.push(pruning::random_connections(
                            top.w[l], top.in_width(l), top.f[l], &mut crng));
                    }
                }
            }
        }
        let mut conn_lits = ParamStore::new();
        for (l, conn) in connections.iter().enumerate() {
            let flat: Vec<i32> = conn
                .iter()
                .flat_map(|row| row.iter().map(|&i| i as i32))
                .collect();
            conn_lits.insert(
                &format!("l{l}_conn"),
                lit_i32(&flat, &[top.w[l], top.f[l]])?,
            );
        }

        Ok(Session {
            rt: rt.clone(),
            cfg: cfg.clone(),
            dense,
            params,
            m,
            v,
            stats,
            conn_lits,
            connections,
            skip_scale,
            execs: BTreeMap::new(),
            t: 0,
        })
    }

    /// Learned-layer indices (in layer order).
    pub fn learned_layers(&self) -> Vec<usize> {
        (0..self.cfg.topology.n_layers())
            .filter(|&l| self.cfg.topology.a[l] == 0)
            .collect()
    }

    fn exec(&mut self, name: &str) -> Result<&Exec> {
        if !self.execs.contains_key(name) {
            let spec = self.cfg.entry(name)?.clone();
            let exec = self.rt.load(&spec)?;
            self.execs.insert(name.to_string(), exec);
        }
        Ok(&self.execs[name])
    }

    /// One optimizer step on a prepared batch. Returns the loss.
    pub fn train_step(&mut self, x: &[i32], y: &[i32], lr: f32, wd: f32,
                      lam: f32) -> Result<f32> {
        let top = &self.cfg.topology;
        let entry = if self.dense { "train_step_dense" } else { "train_step" };
        self.t += 1;
        let x_lit = lit_i32(x, &[top.batch, top.n_in])?;
        let y_lit = lit_i32(y, &[top.batch])?;
        let lr_l = lit_scalar_f32(lr);
        let wd_l = lit_scalar_f32(wd);
        let lam_l = lit_scalar_f32(lam);
        let ss_l = lit_scalar_f32(self.skip_scale);
        let t_l = lit_scalar_f32(self.t as f32);

        // assemble args (can't use run_with: params/m/v borrow self.execs)
        let spec = self.cfg.entry(entry)?.clone();
        self.exec(entry)?; // ensure compiled
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(spec.args.len());
        for tok in &spec.args {
            let lit = if let Some(name) = tok.strip_prefix("p:") {
                self.params.get(name)?
            } else if let Some(name) = tok.strip_prefix("m:") {
                self.m.get(name)?
            } else if let Some(name) = tok.strip_prefix("v:") {
                self.v.get(name)?
            } else if let Some(name) = tok.strip_prefix("s:") {
                self.stats.get(name)?
            } else if let Some(name) = tok.strip_prefix("c:") {
                self.conn_lits.get(name)?
            } else {
                match tok.as_str() {
                    "x" => &x_lit,
                    "y" => &y_lit,
                    "lr" => &lr_l,
                    "wd" => &wd_l,
                    "lam" => &lam_l,
                    "skip_scale" => &ss_l,
                    "t" => &t_l,
                    other => bail!("unknown arg token '{other}'"),
                }
            };
            args.push(lit);
        }
        let outs = self.execs[entry].run(&args)?;

        // scatter outputs back by name
        let out_names = &self.execs[entry].spec.outputs;
        let mut loss = f32::NAN;
        for (name, lit) in out_names.iter().zip(outs) {
            if let Some(p) = name.strip_prefix("p:") {
                self.params.insert(p, lit);
            } else if let Some(p) = name.strip_prefix("m:") {
                self.m.insert(p, lit);
            } else if let Some(p) = name.strip_prefix("v:") {
                self.v.insert(p, lit);
            } else if let Some(p) = name.strip_prefix("s:") {
                self.stats.insert(p, lit);
            } else if name == "loss" {
                loss = to_vec_f32(&lit)?[0];
            }
        }
        Ok(loss)
    }

    /// Full training run per the config's SGDR schedule; returns the loss
    /// trace. Batches cycle deterministically through shuffled epochs.
    pub fn train(&mut self, data: &Dataset, tc: &TrainConfig) -> Result<Vec<f32>> {
        self.train_range(data, tc, 0, tc.steps)
    }

    /// Train `count` steps starting at global SGDR step `start` (allows a
    /// caller to interleave evaluation while keeping one schedule).
    pub fn train_range(&mut self, data: &Dataset, tc: &TrainConfig,
                       start: usize, count: usize) -> Result<Vec<f32>> {
        let top = self.cfg.topology.clone();
        let mut order_rng = Rng::new(tc.seed ^ 0x0D0E ^ start as u64);
        let mut order = order_rng.permutation(data.n);
        let mut cursor = 0usize;
        let mut losses = Vec::with_capacity(count);
        for step in start..start + count {
            if cursor + top.batch > data.n {
                order_rng.shuffle(&mut order);
                cursor = 0;
            }
            let idx = &order[cursor..(cursor + top.batch).min(data.n)];
            cursor += top.batch;
            let (x, y) = data.batch(idx, top.batch);
            let lr = tc.lr_at(step);
            let loss = self.train_step(&x, &y, lr, tc.weight_decay, tc.lambda_group)?;
            losses.push(loss);
            if tc.eval_every > 0 && (step + 1) % tc.eval_every == 0 {
                log::info!("step {}: loss {:.4}", step + 1, loss);
            }
        }
        Ok(losses)
    }

    /// Quantized-forward output codes for one padded batch (row-major).
    pub fn infer_codes(&mut self, x: &[i32], entry: &str) -> Result<Vec<i32>> {
        let top = self.cfg.topology.clone();
        anyhow::ensure!(x.len() == top.batch * top.n_in, "bad batch size");
        let x_lit = lit_i32(x, &[top.batch, top.n_in])?;
        let ss_l = lit_scalar_f32(self.skip_scale);
        let spec = self.cfg.entry(entry)?.clone();
        self.exec(entry)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(spec.args.len());
        for tok in &spec.args {
            let lit = if let Some(name) = tok.strip_prefix("p:") {
                self.params.get(name)?
            } else if let Some(name) = tok.strip_prefix("s:") {
                self.stats.get(name)?
            } else if let Some(name) = tok.strip_prefix("c:") {
                self.conn_lits.get(name)?
            } else {
                match tok.as_str() {
                    "x" => &x_lit,
                    "skip_scale" => &ss_l,
                    other => bail!("unknown arg token '{other}'"),
                }
            };
            args.push(lit);
        }
        let outs = self.execs[entry].run(&args)?;
        let ci = self.execs[entry].output_index("codes")?;
        to_vec_i32(&outs[ci])
    }

    /// Accuracy of the QAT model on a dataset via the `infer` entry.
    pub fn evaluate(&mut self, data: &Dataset) -> Result<f64> {
        let top = self.cfg.topology.clone();
        let mut preds: Vec<i32> = Vec::with_capacity(data.n);
        let mut i = 0usize;
        while i < data.n {
            let idx: Vec<usize> = (i..(i + top.batch).min(data.n)).collect();
            let (x, _) = data.batch(&idx, top.batch);
            let codes = self.infer_codes(&x, "infer")?;
            let batch_preds = predictions(&top, &codes);
            preds.extend_from_slice(&batch_preds[..idx.len()]);
            i += top.batch;
        }
        Ok(metrics::accuracy(&preds, &data.y))
    }

    /// Enumerate every layer's truth tables (paper §III-B2).
    pub fn enumerate(&mut self) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(!self.dense, "enumerate requires the sparse model");
        let top = self.cfg.topology.clone();
        let mut tables = Vec::with_capacity(top.n_layers());
        for l in 0..top.n_layers() {
            let entry = format!("enum_l{l}");
            let logs_prev = if l == 0 {
                0.0
            } else {
                to_vec_f32(self.params.get(&format!("l{}_logs", l - 1))?)?[0]
            };
            let lp_l = lit_scalar_f32(logs_prev);
            let ss_l = lit_scalar_f32(self.skip_scale);
            let spec = self.cfg.entry(&entry)?.clone();
            self.exec(&entry)?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(spec.args.len());
            for tok in &spec.args {
                let lit = if let Some(name) = tok.strip_prefix("p:") {
                    self.params.get(name)?
                } else if let Some(name) = tok.strip_prefix("s:") {
                    self.stats.get(name)?
                } else {
                    match tok.as_str() {
                        "logs_prev" => &lp_l,
                        "skip_scale" => &ss_l,
                        other => bail!("unknown arg token '{other}'"),
                    }
                };
                args.push(lit);
            }
            let outs = self.execs[&entry].run(&args)?;
            tables.push(to_vec_i32(&outs[0])?);
        }
        Ok(tables)
    }

    /// Extract the LUT netlist from enumerated tables.
    pub fn to_netlist(&mut self) -> Result<Netlist> {
        let top = self.cfg.topology.clone();
        let tables = self.enumerate()?;
        let mut layers = Vec::with_capacity(top.n_layers());
        for l in 0..top.n_layers() {
            let conn: Vec<u32> = self.connections[l]
                .iter()
                .flat_map(|row| row.iter().copied())
                .collect();
            let t: Vec<u16> = tables[l].iter().map(|&v| v as u16).collect();
            layers.push(LayerSpec {
                w: top.w[l],
                fan_in: top.f[l],
                in_bits: top.in_bits(l),
                out_bits: top.beta[l],
                conn,
                tables: t,
            });
        }
        Netlist::from_parts(&top.name, top.n_in, top.beta_in, layers)
    }

    /// Group-lasso scores of a dense session's learned layers, for
    /// connection selection (paper's hardware-aware pruning).
    pub fn group_scores(&self) -> Result<Vec<Vec<Vec<f32>>>> {
        anyhow::ensure!(self.dense, "group scores come from the dense phase");
        let top = &self.cfg.topology;
        let mut all = Vec::new();
        for l in self.learned_layers() {
            let units = top.w[l];
            let p = top.in_width(l);
            let n = top.n_hidden;
            let w0 = to_vec_f32(self.params.get(&format!("l{l}_W0"))?)?;
            let wskip = to_vec_f32(self.params.get(&format!("l{l}_wskip"))?)?;
            all.push(pruning::group_scores(units, p, n, &w0, &wskip));
        }
        Ok(all)
    }
}

/// Class predictions from output codes (mirrors `model.predictions`).
pub fn predictions(top: &crate::config::Topology, codes: &[i32]) -> Vec<i32> {
    if top.n_classes > 1 {
        metrics::argmax_rows(codes, *top.w.last().unwrap())
    } else {
        metrics::binary_rows(codes, *top.beta.last().unwrap())
    }
}
