//! Backend-agnostic inference interface (the `Session` shape from
//! deli-infer, specialized to LUT netlists): anything that can answer a
//! batch of code-valued rows implements [`InferenceEngine`], so
//! batching, pooling and multi-model routing compose behind one run
//! interface instead of being welded to a concrete server.
//!
//! Implementations:
//! * [`Simulator`] — the direct in-process path (serial, scoped-thread
//!   or persistent-pool, per its `SimOptions`; executes a compiled
//!   `ExecPlan` by default);
//! * [`WidePlanExecutor`] at every lane width — a compiled execution
//!   plan with private scratch, the form server workers run (plans are
//!   compiled once per model and shared immutably; the plan may equally
//!   come from an `.nlb` artifact's plan image — the engine contract
//!   does not care which producer built it).  `PlanExecutor` is the
//!   scalar `W = 1` alias and the reference; wide executors are proven
//!   bit-exact against it by the same conformance contract;
//! * [`LaneExecutor`] — a `WidePlanExecutor` whose width was chosen at
//!   runtime (`select_backend`), which is what servers actually hold;
//! * [`ModelEngine`] — one named model hosted by an
//!   [`InferenceServer`](super::server::InferenceServer), routed through
//!   the shared router/worker pipeline.
//!
//! [`check_conformance`] is the engine contract as executable code; the
//! `engine` integration suite runs it against every backend (including
//! every lane width, and one width over TCP via `RemoteEngine`).

use anyhow::Result;

use crate::netlist::{LaneExecutor, Netlist, Simulator, WidePlanExecutor};

use super::server::InferenceServer;

/// A backend that evaluates batches of netlist inputs.
pub trait InferenceEngine {
    /// Row-major input codes (`batch * n_in` values) to row-major output
    /// codes (`batch * out_width` values).
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>>;

    /// Input width (codes per row).
    fn n_in(&self) -> usize;

    /// Output width (codes per row).
    fn out_width(&self) -> usize;

    /// Human-readable backend description for startup logs.
    fn describe(&self) -> String;
}

impl InferenceEngine for Simulator<'_> {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        let n_in = self.netlist().n_in;
        anyhow::ensure!(x.len() == batch * n_in,
                        "run_batch: input len {} != batch {batch} * n_in \
                         {n_in}", x.len());
        Ok(self.eval_batch(x, batch))
    }

    fn n_in(&self) -> usize {
        self.netlist().n_in
    }

    fn out_width(&self) -> usize {
        self.netlist().out_width()
    }

    fn describe(&self) -> String {
        let opts = self.options();
        format!("simulator[{}]: {}/{} layers bit-plane, {} threads \
                 ({:?}), {}",
                self.netlist().name, self.bitplane_layers(),
                self.netlist().layers.len(), opts.threads, opts.mode,
                if opts.compiled { "compiled plan" } else { "interpreted" })
    }
}

impl<const W: usize> InferenceEngine for WidePlanExecutor<W> {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        let n_in = self.plan().n_in();
        anyhow::ensure!(x.len() == batch * n_in,
                        "run_batch: input len {} != batch {batch} * n_in \
                         {n_in}", x.len());
        Ok(self.eval_batch(x, batch))
    }

    fn n_in(&self) -> usize {
        self.plan().n_in()
    }

    fn out_width(&self) -> usize {
        self.plan().out_width()
    }

    fn describe(&self) -> String {
        let opts = self.options();
        let st = self.plan().stats();
        format!("plan[{}]: {}, {} threads ({:?}), {}x64-sample lanes",
                self.plan().name(), st.summary(), opts.threads, opts.mode,
                self.lane_width())
    }
}

impl InferenceEngine for LaneExecutor {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        let n_in = self.plan().n_in();
        anyhow::ensure!(x.len() == batch * n_in,
                        "run_batch: input len {} != batch {batch} * n_in \
                         {n_in}", x.len());
        Ok(self.eval_batch(x, batch))
    }

    fn n_in(&self) -> usize {
        self.plan().n_in()
    }

    fn out_width(&self) -> usize {
        self.plan().out_width()
    }

    fn describe(&self) -> String {
        let opts = self.options();
        let st = self.plan().stats();
        format!("plan[{}]: {}, {} threads ({:?}), {}x64-sample lanes",
                self.plan().name(), st.summary(), opts.threads, opts.mode,
                self.width())
    }
}

/// One named model on a running [`InferenceServer`], viewed as an
/// engine: `run_batch` fans the rows through the server's router (so
/// they may be re-batched with concurrent traffic) and reassembles the
/// answers in order.
pub struct ModelEngine<'s> {
    pub(crate) server: &'s InferenceServer,
    pub(crate) model: String,
    pub(crate) n_in: usize,
    pub(crate) out_width: usize,
}

impl InferenceEngine for ModelEngine<'_> {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        anyhow::ensure!(x.len() == batch * self.n_in,
                        "run_batch: input len {} != batch {batch} * n_in {}",
                        x.len(), self.n_in);
        if batch == 0 {
            return Ok(Vec::new());
        }
        let rows: Vec<Vec<i32>> =
            x.chunks(self.n_in).map(|r| r.to_vec()).collect();
        let outs = self.server.infer_many(&self.model, rows)?;
        Ok(outs.concat())
    }

    fn n_in(&self) -> usize {
        self.n_in
    }

    fn out_width(&self) -> usize {
        self.out_width
    }

    fn describe(&self) -> String {
        format!("server model '{}': n_in {}, out_width {}", self.model,
                self.n_in, self.out_width)
    }
}

/// Engine-conformance suite, shared by every backend's tests: shape
/// agreement with the netlist, bit-exactness against `eval_one` across
/// batch sizes (including sizes that are not multiples of 64),
/// determinism across repeated calls, and input-width rejection.
pub fn check_conformance(engine: &mut dyn InferenceEngine, nl: &Netlist,
                         seed: u64) -> Result<()> {
    use crate::netlist::testutil::random_inputs;

    anyhow::ensure!(engine.n_in() == nl.n_in,
                    "n_in {} != netlist {}", engine.n_in(), nl.n_in);
    anyhow::ensure!(engine.out_width() == nl.out_width(),
                    "out_width {} != netlist {}", engine.out_width(),
                    nl.out_width());
    anyhow::ensure!(!engine.describe().is_empty(), "empty describe()");
    let ow = nl.out_width();
    for (i, batch) in [1usize, 5, 64, 130].into_iter().enumerate() {
        let x = random_inputs(seed.wrapping_add(i as u64), nl, batch);
        let got = engine.run_batch(&x, batch)?;
        anyhow::ensure!(got.len() == batch * ow,
                        "batch {batch}: output len {} != {}", got.len(),
                        batch * ow);
        for b in 0..batch {
            let want = nl.eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])?;
            anyhow::ensure!(got[b * ow..(b + 1) * ow] == want[..],
                            "batch {batch}: row {b} differs from eval_one");
        }
        let again = engine.run_batch(&x, batch)?;
        anyhow::ensure!(again == got,
                        "batch {batch}: repeated call not deterministic");
    }
    // wrong input length must be rejected, not mis-shaped
    let x = random_inputs(seed ^ 0x77, nl, 2);
    anyhow::ensure!(engine.run_batch(&x[..x.len() - 1], 2).is_err(),
                    "short input accepted");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::testutil::random_netlist;

    #[test]
    fn direct_simulator_conforms() {
        let nl = random_netlist(51, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let mut sim = nl.simulator();
        check_conformance(&mut sim, &nl, 51).unwrap();
        assert!(sim.describe().contains("simulator"));
        assert!(sim.describe().contains("compiled plan"));
    }

    #[test]
    fn plan_executor_conforms() {
        use crate::netlist::{PlanExecutor, PlanOptions};
        use std::sync::Arc;
        let nl = random_netlist(52, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let plan = Arc::new(nl.compile_plan(PlanOptions::default()));
        let mut ex = PlanExecutor::new(plan);
        check_conformance(&mut ex, &nl, 52).unwrap();
        assert!(ex.describe().starts_with("plan["));
        assert!(ex.describe().contains("1x64-sample lanes"));
    }

    #[test]
    fn wide_plan_executors_conform_at_every_width() {
        use crate::netlist::PlanOptions;
        use std::sync::Arc;
        let nl = random_netlist(54, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let plan = Arc::new(nl.compile_plan(PlanOptions::default()));
        let mut w4: WidePlanExecutor<4> =
            WidePlanExecutor::new(plan.clone());
        check_conformance(&mut w4, &nl, 54).unwrap();
        assert!(w4.describe().contains("4x64-sample lanes"));
        let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan);
        check_conformance(&mut w8, &nl, 54).unwrap();
        assert!(w8.describe().contains("8x64-sample lanes"));
    }

    #[test]
    fn lane_executor_conforms_at_every_width() {
        use crate::netlist::{PlanOptions, SimOptions};
        use std::sync::Arc;
        let nl = random_netlist(55, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let plan = Arc::new(nl.compile_plan(PlanOptions::default()));
        for width in [1usize, 4, 8] {
            let mut ex = LaneExecutor::for_width(
                width, plan.clone(), SimOptions::default());
            check_conformance(&mut ex, &nl, 55).unwrap();
            assert!(ex.describe()
                        .contains(&format!("{width}x64-sample lanes")),
                    "describe: {}", ex.describe());
        }
    }

    /// A plan revived from an `.nlb` artifact's plan image must satisfy
    /// the same contract as a freshly compiled one — this is the load
    /// path the cold-start CI smoke job exercises.
    #[test]
    fn artifact_loaded_plan_conforms() {
        use crate::netlist::{load_nlb, save_nlb, PlanExecutor, PlanOptions};
        let nl = random_netlist(53, 10, 1, &[(6, 3, 2), (3, 2, 2)]);
        let plan = nl.compile_plan(PlanOptions::default());
        let path = std::env::temp_dir().join(format!(
            "nid_engine_artifact_{}.nlb", std::process::id()));
        save_nlb(&path, &nl, Some(&plan)).unwrap();
        let model = load_nlb(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let image = model.plan.clone().expect("artifact carries a plan image");
        let mut ex = PlanExecutor::new(image);
        check_conformance(&mut ex, &model.netlist, 53).unwrap();
        // and the netlist that rode along is the one we exported
        assert_eq!(model.netlist.content_hash(), nl.content_hash());
    }
}
