//! Logical-LUT (L-LUT) representation.
//!
//! An L-LUT is a lookup table of arbitrary size (paper §I): a unit with
//! `fan_in` inputs of `in_bits` bits each and one `out_bits`-bit output,
//! i.e. a finite function over `2^(in_bits*fan_in)` addresses.  Input `f`
//! occupies address bits `[in_bits*f, in_bits*(f+1))` — the same layout as
//! `ref.pack_codes` on the python side and the RTL concatenation order.

use anyhow::{bail, Result};

/// One L-LUT truth table.  Entries are output codes (< 2^out_bits).
#[derive(Clone, Debug, PartialEq)]
pub struct TruthTable {
    pub fan_in: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    pub entries: Vec<u16>,
}

impl TruthTable {
    pub fn new(fan_in: usize, in_bits: usize, out_bits: usize,
               entries: Vec<u16>) -> Result<TruthTable> {
        let want = 1usize << (fan_in * in_bits);
        if entries.len() != want {
            bail!("table has {} entries, want {want}", entries.len());
        }
        if out_bits > 16 {
            bail!("out_bits {out_bits} > 16 unsupported");
        }
        let max = ((1u32 << out_bits) - 1) as u16;
        if let Some(bad) = entries.iter().find(|&&e| e > max) {
            bail!("entry {bad} exceeds {out_bits}-bit output");
        }
        Ok(TruthTable { fan_in, in_bits, out_bits, entries })
    }

    pub fn addr_bits(&self) -> usize {
        self.fan_in * self.in_bits
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Pack per-input codes into a table address (LSB = input 0).
    pub fn pack(&self, codes: &[u16]) -> usize {
        debug_assert_eq!(codes.len(), self.fan_in);
        let mut addr = 0usize;
        for (f, &c) in codes.iter().enumerate() {
            debug_assert!((c as usize) < (1 << self.in_bits));
            addr |= (c as usize) << (self.in_bits * f);
        }
        addr
    }

    /// Unpack a table address into per-input codes.
    pub fn unpack(&self, addr: usize) -> Vec<u16> {
        let mask = (1usize << self.in_bits) - 1;
        (0..self.fan_in)
            .map(|f| ((addr >> (self.in_bits * f)) & mask) as u16)
            .collect()
    }

    pub fn lookup(&self, codes: &[u16]) -> u16 {
        self.entries[self.pack(codes)]
    }

    /// Extract output bit `b` as a boolean function (bit-per-address).
    pub fn output_bit(&self, b: usize) -> Vec<bool> {
        assert!(b < self.out_bits);
        self.entries.iter().map(|&e| (e >> b) & 1 == 1).collect()
    }

    /// True input-variable support of output bit `b`: the set of *address
    /// bits* the function actually depends on.  Synthesis tools perform
    /// the same reduction; it is what shrinks trained tables below the
    /// worst-case P-LUT cost.
    pub fn bit_support(&self, b: usize) -> Vec<usize> {
        let f = self.output_bit(b);
        let n = self.addr_bits();
        let mut support = Vec::new();
        for v in 0..n {
            let stride = 1usize << v;
            let mut depends = false;
            'outer: for base in 0..self.entries.len() {
                if base & stride == 0 && f[base] != f[base | stride] {
                    depends = true;
                    break 'outer;
                }
            }
            if depends {
                support.push(v);
            }
        }
        support
    }

    /// Project output bit `b` onto `support` (ascending address-bit
    /// indices, as returned by [`TruthTable::bit_support`]) and pack the
    /// reduced table into a `u64`: entry `m` is the function value at the
    /// address where support bit `i` takes bit `i` of `m` and every
    /// non-support address bit is 0.  Sound only when `support` really
    /// covers the bit's dependencies; the bit-plane simulator kernel is
    /// built on exactly this reduction.
    pub fn reduced_bit_table(&self, b: usize, support: &[usize]) -> u64 {
        assert!(support.len() <= 6, "reduced table must fit in a u64");
        let mut out = 0u64;
        for m in 0..1usize << support.len() {
            let mut addr = 0usize;
            for (i, &v) in support.iter().enumerate() {
                addr |= ((m >> i) & 1) << v;
            }
            if (self.entries[addr] >> b) & 1 == 1 {
                out |= 1 << m;
            }
        }
        out
    }

    /// Is output bit `b` constant?
    pub fn bit_constant(&self, b: usize) -> Option<bool> {
        let f = self.output_bit(b);
        if f.iter().all(|&x| x) {
            Some(true)
        } else if f.iter().all(|&x| !x) {
            Some(false)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor2() -> TruthTable {
        // 2 one-bit inputs, 1-bit output: XOR
        TruthTable::new(2, 1, 1, vec![0, 1, 1, 0]).unwrap()
    }

    #[test]
    fn construct_validates() {
        assert!(TruthTable::new(2, 1, 1, vec![0, 1, 1]).is_err()); // size
        assert!(TruthTable::new(2, 1, 1, vec![0, 1, 1, 2]).is_err()); // range
        assert!(TruthTable::new(2, 2, 4, vec![0; 16]).is_ok());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let t = TruthTable::new(3, 2, 2, vec![0; 64]).unwrap();
        for addr in 0..64 {
            assert_eq!(t.pack(&t.unpack(addr)), addr);
        }
        // layout: input f at bits [2f, 2f+2)
        assert_eq!(t.pack(&[1, 2, 3]), 1 + (2 << 2) + (3 << 4));
    }

    #[test]
    fn lookup_xor() {
        let t = xor2();
        assert_eq!(t.lookup(&[0, 0]), 0);
        assert_eq!(t.lookup(&[1, 0]), 1);
        assert_eq!(t.lookup(&[0, 1]), 1);
        assert_eq!(t.lookup(&[1, 1]), 0);
    }

    #[test]
    fn support_full_for_xor() {
        assert_eq!(xor2().bit_support(0), vec![0, 1]);
    }

    #[test]
    fn support_reduced_when_input_ignored() {
        // f(a, b) = a  (ignores b)
        let t = TruthTable::new(2, 1, 1, vec![0, 1, 0, 1]).unwrap();
        assert_eq!(t.bit_support(0), vec![0]);
    }

    #[test]
    fn constant_detection() {
        let t = TruthTable::new(2, 1, 1, vec![1, 1, 1, 1]).unwrap();
        assert_eq!(t.bit_constant(0), Some(true));
        assert_eq!(xor2().bit_constant(0), None);
    }

    #[test]
    fn reduced_table_projects_onto_support() {
        // f(a, b) = a: support {0}, reduced table = identity on 1 bit
        let t = TruthTable::new(2, 1, 1, vec![0, 1, 0, 1]).unwrap();
        assert_eq!(t.reduced_bit_table(0, &[0]), 0b10);
        // xor keeps full support; reduced table is xor itself
        assert_eq!(xor2().reduced_bit_table(0, &[0, 1]), 0b0110);
        // constant bit reduces to a 1-entry table
        let c = TruthTable::new(2, 1, 1, vec![1, 1, 1, 1]).unwrap();
        assert_eq!(c.reduced_bit_table(0, &[]), 1);
    }

    #[test]
    fn reduced_table_agrees_with_lookup_on_multibit() {
        // 2 inputs x 2 bits, 2-bit output: check every bit against the
        // full table through the reduction
        let entries: Vec<u16> =
            (0..16).map(|a| ((a * 7 + 3) % 4) as u16).collect();
        let t = TruthTable::new(2, 2, 2, entries).unwrap();
        for b in 0..2 {
            let support = t.bit_support(b);
            let reduced = t.reduced_bit_table(b, &support);
            for addr in 0..t.len() {
                let mut m = 0usize;
                for (i, &v) in support.iter().enumerate() {
                    m |= ((addr >> v) & 1) << i;
                }
                let want = (t.entries[addr] >> b) & 1;
                assert_eq!(((reduced >> m) & 1) as u16, want,
                           "bit {b} addr {addr}");
            }
        }
    }

    #[test]
    fn output_bit_extraction() {
        let t = TruthTable::new(1, 2, 2, vec![0, 1, 2, 3]).unwrap();
        assert_eq!(t.output_bit(0), vec![false, true, false, true]);
        assert_eq!(t.output_bit(1), vec![false, false, true, true]);
    }
}
