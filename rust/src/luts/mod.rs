//! Logical-LUT (L-LUT) representation.
//!
//! An L-LUT is a lookup table of arbitrary size (paper §I): a unit with
//! `fan_in` inputs of `in_bits` bits each and one `out_bits`-bit output,
//! i.e. a finite function over `2^(in_bits*fan_in)` addresses.  Input `f`
//! occupies address bits `[in_bits*f, in_bits*(f+1))` — the same layout as
//! `ref.pack_codes` on the python side and the RTL concatenation order.

use anyhow::{bail, Result};

/// One L-LUT truth table.  Entries are output codes (< 2^out_bits).
#[derive(Clone, Debug, PartialEq)]
pub struct TruthTable {
    pub fan_in: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    pub entries: Vec<u16>,
}

impl TruthTable {
    pub fn new(fan_in: usize, in_bits: usize, out_bits: usize,
               entries: Vec<u16>) -> Result<TruthTable> {
        let want = 1usize << (fan_in * in_bits);
        if entries.len() != want {
            bail!("table has {} entries, want {want}", entries.len());
        }
        if out_bits > 16 {
            bail!("out_bits {out_bits} > 16 unsupported");
        }
        let max = ((1u32 << out_bits) - 1) as u16;
        if let Some(bad) = entries.iter().find(|&&e| e > max) {
            bail!("entry {bad} exceeds {out_bits}-bit output");
        }
        Ok(TruthTable { fan_in, in_bits, out_bits, entries })
    }

    pub fn addr_bits(&self) -> usize {
        self.fan_in * self.in_bits
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Pack per-input codes into a table address (LSB = input 0).
    pub fn pack(&self, codes: &[u16]) -> usize {
        debug_assert_eq!(codes.len(), self.fan_in);
        let mut addr = 0usize;
        for (f, &c) in codes.iter().enumerate() {
            debug_assert!((c as usize) < (1 << self.in_bits));
            addr |= (c as usize) << (self.in_bits * f);
        }
        addr
    }

    /// Unpack a table address into per-input codes.
    pub fn unpack(&self, addr: usize) -> Vec<u16> {
        let mask = (1usize << self.in_bits) - 1;
        (0..self.fan_in)
            .map(|f| ((addr >> (self.in_bits * f)) & mask) as u16)
            .collect()
    }

    pub fn lookup(&self, codes: &[u16]) -> u16 {
        self.entries[self.pack(codes)]
    }

    /// Extract output bit `b` as a boolean function (bit-per-address).
    pub fn output_bit(&self, b: usize) -> Vec<bool> {
        assert!(b < self.out_bits);
        self.entries.iter().map(|&e| (e >> b) & 1 == 1).collect()
    }

    /// True input-variable support of output bit `b`: the set of *address
    /// bits* the function actually depends on.  Synthesis tools perform
    /// the same reduction; it is what shrinks trained tables below the
    /// worst-case P-LUT cost.
    pub fn bit_support(&self, b: usize) -> Vec<usize> {
        let f = self.output_bit(b);
        let n = self.addr_bits();
        let mut support = Vec::new();
        for v in 0..n {
            let stride = 1usize << v;
            let mut depends = false;
            'outer: for base in 0..self.entries.len() {
                if base & stride == 0 && f[base] != f[base | stride] {
                    depends = true;
                    break 'outer;
                }
            }
            if depends {
                support.push(v);
            }
        }
        support
    }

    /// Is output bit `b` constant?
    pub fn bit_constant(&self, b: usize) -> Option<bool> {
        let f = self.output_bit(b);
        if f.iter().all(|&x| x) {
            Some(true)
        } else if f.iter().all(|&x| !x) {
            Some(false)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor2() -> TruthTable {
        // 2 one-bit inputs, 1-bit output: XOR
        TruthTable::new(2, 1, 1, vec![0, 1, 1, 0]).unwrap()
    }

    #[test]
    fn construct_validates() {
        assert!(TruthTable::new(2, 1, 1, vec![0, 1, 1]).is_err()); // size
        assert!(TruthTable::new(2, 1, 1, vec![0, 1, 1, 2]).is_err()); // range
        assert!(TruthTable::new(2, 2, 4, vec![0; 16]).is_ok());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let t = TruthTable::new(3, 2, 2, vec![0; 64]).unwrap();
        for addr in 0..64 {
            assert_eq!(t.pack(&t.unpack(addr)), addr);
        }
        // layout: input f at bits [2f, 2f+2)
        assert_eq!(t.pack(&[1, 2, 3]), 1 + (2 << 2) + (3 << 4));
    }

    #[test]
    fn lookup_xor() {
        let t = xor2();
        assert_eq!(t.lookup(&[0, 0]), 0);
        assert_eq!(t.lookup(&[1, 0]), 1);
        assert_eq!(t.lookup(&[0, 1]), 1);
        assert_eq!(t.lookup(&[1, 1]), 0);
    }

    #[test]
    fn support_full_for_xor() {
        assert_eq!(xor2().bit_support(0), vec![0, 1]);
    }

    #[test]
    fn support_reduced_when_input_ignored() {
        // f(a, b) = a  (ignores b)
        let t = TruthTable::new(2, 1, 1, vec![0, 1, 0, 1]).unwrap();
        assert_eq!(t.bit_support(0), vec![0]);
    }

    #[test]
    fn constant_detection() {
        let t = TruthTable::new(2, 1, 1, vec![1, 1, 1, 1]).unwrap();
        assert_eq!(t.bit_constant(0), Some(true));
        assert_eq!(xor2().bit_constant(0), None);
    }

    #[test]
    fn output_bit_extraction() {
        let t = TruthTable::new(1, 2, 2, vec![0, 1, 2, 3]).unwrap();
        assert_eq!(t.output_bit(0), vec![false, true, false, true]);
        assert_eq!(t.output_bit(1), vec![false, false, true, true]);
    }
}
