//! Literal helpers and the named parameter store.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::Rng;

/// f32 literal with the given dimensions ([] = scalar).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32: {} elems for dims {dims:?}", data.len());
    if dims.is_empty() {
        anyhow::ensure!(data.len() == 1);
        return Ok(xla::Literal::scalar(data[0]));
    }
    let v = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

/// i32 literal with the given dimensions.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32: {} elems for dims {dims:?}", data.len());
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let v = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

pub fn to_vec_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// Named tensor store (parameters, optimizer state, connections, tables).
/// Keeps literals keyed by name; ordering for HLO calls always comes from
/// the entry's recorded arg list, never from map order.
pub struct ParamStore {
    map: BTreeMap<String, xla::Literal>,
}

impl ParamStore {
    pub fn new() -> ParamStore {
        ParamStore { map: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, lit: xla::Literal) {
        self.map.insert(name.to_string(), lit);
    }

    pub fn get(&self, name: &str) -> Result<&xla::Literal> {
        self.map.get(name).with_context(|| format!("missing tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// He-style initialization mirroring `model.init_params` on the
    /// python side (exact distributions need not match — training happens
    /// here in rust — but shapes and magnitudes do).
    pub fn init_params(spec: &[(String, Vec<usize>)], rng: &mut Rng) -> Result<ParamStore> {
        let mut store = ParamStore::new();
        for (name, shape) in spec {
            let n: usize = shape.iter().product::<usize>().max(1);
            let data: Vec<f32> = if name.ends_with("_logs") {
                vec![0.0] // scale s = 1.0
            } else if name.ends_with("_b0")
                || name.ends_with("_bh")
                || name.ends_with("_bout")
            {
                vec![0.0; n]
            } else if name.ends_with("_wskip") {
                let fan_in = *shape.last().unwrap_or(&1) as f32;
                (0..n).map(|_| rng.normal() * 0.5 / fan_in.sqrt()).collect()
            } else {
                // dense weights: He over the contraction dim (last-but-one)
                let fan_in = if shape.len() >= 2 {
                    shape[shape.len() - 2] as f32
                } else {
                    1.0
                };
                (0..n).map(|_| rng.normal() * (2.0 / fan_in).sqrt()).collect()
            };
            store.insert(name, lit_f32(&data, shape)?);
        }
        Ok(store)
    }

    /// Zero tensors with the same shapes (Adam moment init).
    pub fn zeros(spec: &[(String, Vec<usize>)]) -> Result<ParamStore> {
        let mut store = ParamStore::new();
        for (name, shape) in spec {
            let n: usize = shape.iter().product::<usize>().max(1);
            store.insert(name, lit_f32(&vec![0.0; n], shape)?);
        }
        Ok(store)
    }

    /// Replace tensors from a parallel (names, literals) result slice.
    pub fn update_from(&mut self, names: &[String], lits: Vec<xla::Literal>) {
        for (name, lit) in names.iter().zip(lits) {
            self.map.insert(name.clone(), lit);
        }
    }

    /// Deep-copy all f32 tensors to host (checkpoint snapshot).
    pub fn snapshot(&self) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        self.map
            .iter()
            .map(|(name, lit)| {
                let dims: Vec<usize> = match lit.shape()? {
                    xla::Shape::Array(a) => {
                        a.dims().iter().map(|&d| d as usize).collect()
                    }
                    _ => anyhow::bail!("snapshot: non-array tensor {name}"),
                };
                Ok((name.clone(), dims, lit.to_vec::<f32>()?))
            })
            .collect()
    }

    /// Restore a snapshot taken with [`ParamStore::snapshot`].
    pub fn restore(&mut self, snap: &[(String, Vec<usize>, Vec<f32>)]) -> Result<()> {
        for (name, dims, data) in snap {
            self.map.insert(name.clone(), lit_f32(data, dims)?);
        }
        Ok(())
    }
}

impl Default for ParamStore {
    fn default() -> Self {
        Self::new()
    }
}
