//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and
//! execute them from the coordinator hot paths.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! All entry points were lowered with `return_tuple=True`, so every
//! execution returns one tuple buffer which is decomposed into per-output
//! literals.  Argument order is *never* guessed: it comes from
//! `EntrySpec::args` recorded in meta.json, and `Exec::run` checks arity.

mod tensor;

pub use tensor::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32, to_vec_i32, ParamStore};

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::EntrySpec;

/// Shared PJRT CPU client.
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!("PJRT platform: {}", client.platform_name());
        Ok(Runtime { client: Arc::new(client) })
    }

    /// Load + compile one artifact entry point.
    pub fn load(&self, spec: &EntrySpec) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {:?}", spec.file))?;
        Ok(Exec { exe, spec: spec.clone() })
    }
}

/// One compiled executable plus its interface description.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    pub spec: EntrySpec,
}

impl Exec {
    /// Execute with positional literal arguments (must match
    /// `spec.args` arity); returns the decomposed output tuple.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "{}: got {} args, expected {} ({:?}...)",
                self.spec.name,
                args.len(),
                self.spec.args.len(),
                &self.spec.args[..self.spec.args.len().min(4)]
            );
        }
        let bufs = self.exe.execute::<&xla::Literal>(args)?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Assemble the argument list from the entry's recorded token order.
    /// Tokens: `p:<name>` / `m:` / `v:` (param stores), `c:<name>`
    /// (connections), `t:<name>` (tables), plain names (step inputs).
    pub fn run_with<'a, F>(&self, mut resolve: F) -> Result<Vec<xla::Literal>>
    where
        F: FnMut(&str) -> Result<&'a xla::Literal>,
    {
        let args = self
            .spec
            .args
            .iter()
            .map(|tok| resolve(tok).with_context(|| format!("arg '{tok}'")))
            .collect::<Result<Vec<_>>>()?;
        self.run(&args)
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|o| o == name)
            .with_context(|| format!("{}: no output '{name}'", self.spec.name))
    }
}
