//! Training hyper-parameters and the SGDR (cosine warm restarts) learning
//! rate schedule [Loshchilov & Hutter '17] used by the paper, computed on
//! the rust side and fed to the AOT `train_step` executable as a scalar.

/// Hyper-parameters of one training phase (dense pre-training or the
/// sparse tree training / retraining).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr_max: f32,
    pub lr_min: f32,
    /// first SGDR restart period, in steps
    pub t0: usize,
    /// period multiplier at each restart
    pub t_mult: usize,
    /// decoupled weight decay
    pub weight_decay: f32,
    /// group-lasso coefficient (dense phase only)
    pub lambda_group: f32,
    /// evaluate every `eval_every` steps (0 = only at end)
    pub eval_every: usize,
    pub seed: u64,
}

impl TrainConfig {
    /// Defaults for the sparse (tree) training phase.  The first restart
    /// period is steps/7 so that with t_mult = 2 the three periods
    /// (p, 2p, 4p) end exactly at the training horizon — the run finishes
    /// at the *bottom* of the last cosine, not mid-restart.
    pub fn sparse(steps: usize) -> TrainConfig {
        TrainConfig {
            steps,
            lr_max: 0.02,
            lr_min: 1e-4,
            t0: (steps.max(7) / 7).max(1),
            t_mult: 2,
            weight_decay: 1e-4,
            lambda_group: 0.0,
            eval_every: 0,
            seed: 0xA55E,
        }
    }

    /// Defaults for the dense pre-training phase (learned mappings).
    pub fn dense(steps: usize) -> TrainConfig {
        TrainConfig {
            lambda_group: 2e-4,
            weight_decay: 0.0,
            ..TrainConfig::sparse(steps)
        }
    }

    /// SGDR learning rate at 0-based step `t`.  Past the planned horizon
    /// (all three cosine periods) the rate stays at `lr_min` so trailing
    /// steps cannot kick the model back up a restart.
    pub fn lr_at(&self, t: usize) -> f32 {
        if self.t_mult == 2 && t >= self.t0.max(1) * 7 {
            return self.lr_min;
        }
        let (mut period, mut start) = (self.t0.max(1), 0usize);
        while t >= start + period {
            start += period;
            period = period.saturating_mul(self.t_mult.max(1)).max(1);
        }
        let frac = (t - start) as f32 / period as f32;
        self.lr_min
            + 0.5 * (self.lr_max - self.lr_min)
                * (1.0 + (std::f32::consts::PI * frac).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgdr_restarts() {
        let c = TrainConfig { t0: 10, t_mult: 2, ..TrainConfig::sparse(70) };
        // at t=0 lr = lr_max
        assert!((c.lr_at(0) - c.lr_max).abs() < 1e-6);
        // just before first restart, lr near lr_min
        assert!(c.lr_at(9) < c.lr_max * 0.2);
        // restart at t=10: back to lr_max
        assert!((c.lr_at(10) - c.lr_max).abs() < 1e-6);
        // second period is 20 long: next restart at t=30
        assert!((c.lr_at(30) - c.lr_max).abs() < 1e-6);
        assert!(c.lr_at(29) < c.lr_at(30));
    }

    #[test]
    fn lr_monotone_within_period() {
        let c = TrainConfig { t0: 16, t_mult: 2, ..TrainConfig::sparse(16) };
        for t in 1..16 {
            assert!(c.lr_at(t) <= c.lr_at(t - 1) + 1e-7);
        }
    }

    #[test]
    fn lr_bounded() {
        let c = TrainConfig::sparse(100);
        for t in 0..100 {
            let lr = c.lr_at(t);
            assert!(lr >= c.lr_min - 1e-7 && lr <= c.lr_max + 1e-7);
        }
    }
}
