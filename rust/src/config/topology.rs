//! The paper's Table I architecture parameters, mirrored from
//! `python/compile/topology.py` (the python copy is authoritative at
//! build time; this struct is populated from `meta.json`).

use anyhow::{bail, Result};

use crate::util::Json;

/// Hard cap on table address bits so 2^(beta*F) enumeration stays feasible.
pub const MAX_TABLE_ADDR_BITS: usize = 16;

#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub name: String,
    pub n_in: usize,
    pub beta_in: usize,
    /// units per layer
    pub w: Vec<usize>,
    /// assemble flags per layer (fixed strided wiring)
    pub a: Vec<u8>,
    /// fan-in per layer
    pub f: Vec<usize>,
    /// output bits per layer
    pub beta: Vec<usize>,
    /// hidden layers inside each unit
    pub l_sub: usize,
    /// hidden width inside each unit
    pub n_hidden: usize,
    /// residual step inside each unit
    pub s: usize,
    pub n_classes: usize,
    pub dataset: String,
    /// AOT-fixed batch size of every compiled entry point
    pub batch: usize,
}

impl Topology {
    pub fn from_json(j: &Json) -> Result<Topology> {
        Ok(Topology {
            name: j.at("name")?.as_str()?.to_string(),
            n_in: j.at("n_in")?.as_usize()?,
            beta_in: j.at("beta_in")?.as_usize()?,
            w: j.at("w")?.usize_vec()?,
            a: j.at("a")?.usize_vec()?.iter().map(|&x| x as u8).collect(),
            f: j.at("F")?.usize_vec()?,
            beta: j.at("beta")?.usize_vec()?,
            l_sub: j.at("L_sub")?.as_usize()?,
            n_hidden: j.at("N")?.as_usize()?,
            s: j.at("S")?.as_usize()?,
            n_classes: j.at("n_classes")?.as_usize()?,
            dataset: j.at("dataset")?.as_str()?.to_string(),
            batch: j.at("batch")?.as_usize()?,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.w.len()
    }

    /// Number of producer signals feeding layer `l`.
    pub fn in_width(&self, l: usize) -> usize {
        if l == 0 {
            self.n_in
        } else {
            self.w[l - 1]
        }
    }

    /// Bit-width of each signal feeding layer `l`.
    pub fn in_bits(&self, l: usize) -> usize {
        if l == 0 {
            self.beta_in
        } else {
            self.beta[l - 1]
        }
    }

    /// Truth-table entries of each unit in layer `l`: `2^(in_bits * F)`.
    pub fn table_entries(&self, l: usize) -> usize {
        1usize << (self.in_bits(l) * self.f[l])
    }

    /// Table address width in bits for layer `l`.
    pub fn addr_bits(&self, l: usize) -> usize {
        self.in_bits(l) * self.f[l]
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.n_layers();
        if self.a.len() != n || self.f.len() != n || self.beta.len() != n {
            bail!("{}: w/a/F/beta length mismatch", self.name);
        }
        let head = if self.n_classes > 1 { self.n_classes } else { 1 };
        if *self.w.last().unwrap() != head {
            bail!("{}: final width != head width", self.name);
        }
        for l in 0..n {
            if self.a[l] == 1 {
                if l == 0 {
                    bail!("{}: layer 0 cannot assemble", self.name);
                }
                if self.w[l - 1] != self.f[l] * self.w[l] {
                    bail!(
                        "{}: assemble layer {l} needs w[l-1]=F*w[l] ({} != {}*{})",
                        self.name, self.w[l - 1], self.f[l], self.w[l]
                    );
                }
            }
            if self.addr_bits(l) > MAX_TABLE_ADDR_BITS {
                bail!("{}: layer {l} table address too wide", self.name);
            }
            if self.f[l] > self.in_width(l) {
                bail!("{}: layer {l} fan-in exceeds producer width", self.name);
            }
        }
        if self.l_sub < 2 || self.n_hidden < 1 || self.s < 1 {
            bail!("{}: bad L/N/S", self.name);
        }
        Ok(())
    }

    /// Strided wiring of an assemble layer (the black edges of Fig. 2).
    pub fn fixed_connections(&self, l: usize) -> Vec<Vec<u32>> {
        assert_eq!(self.a[l], 1);
        let f = self.f[l];
        (0..self.w[l])
            .map(|j| (0..f).map(|k| (f * j + k) as u32).collect())
            .collect()
    }

    /// Output-activation flags (ReLU at the end of every *internal* tree
    /// run; the network output layer stays linear). Mirrors
    /// `model.relu_flags`.
    pub fn relu_flags(&self) -> Vec<bool> {
        let n = self.n_layers();
        (0..n)
            .map(|l| {
                let run_end = l == n - 1 || self.a[l + 1] == 0;
                run_end && l != n - 1
            })
            .collect()
    }

    /// Maximal runs of layers forming assembled trees:
    /// each run starts at a learned layer and extends through the
    /// following assemble layers. Returned as (start, end_inclusive).
    pub fn tree_runs(&self) -> Vec<(usize, usize)> {
        let mut runs = Vec::new();
        let mut start = 0usize;
        for l in 1..self.n_layers() {
            if self.a[l] == 0 {
                runs.push((start, l - 1));
                start = l;
            }
        }
        runs.push((start, self.n_layers() - 1));
        runs
    }

    /// Total L-LUT count (one per unit).
    pub fn total_units(&self) -> usize {
        self.w.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny() -> Topology {
        Topology {
            name: "tiny".into(),
            n_in: 12,
            beta_in: 2,
            w: vec![8, 4, 2],
            a: vec![0, 1, 1],
            f: vec![3, 2, 2],
            beta: vec![2, 2, 4],
            l_sub: 2,
            n_hidden: 8,
            s: 2,
            n_classes: 2,
            dataset: "synthetic".into(),
            batch: 16,
        }
    }

    pub fn nid_like() -> Topology {
        Topology {
            name: "nid".into(),
            n_in: 593,
            beta_in: 1,
            w: vec![60, 20, 9, 3, 1],
            a: vec![0, 1, 0, 1, 1],
            f: vec![6, 3, 3, 3, 3],
            beta: vec![2, 2, 2, 2, 2],
            l_sub: 2,
            n_hidden: 16,
            s: 2,
            n_classes: 1,
            dataset: "nid".into(),
            batch: 128,
        }
    }

    #[test]
    fn tiny_validates() {
        tiny().validate().unwrap();
        nid_like().validate().unwrap();
    }

    #[test]
    fn widths_and_bits() {
        let t = tiny();
        assert_eq!(t.in_width(0), 12);
        assert_eq!(t.in_width(1), 8);
        assert_eq!(t.in_bits(0), 2);
        assert_eq!(t.in_bits(2), 2);
        assert_eq!(t.table_entries(0), 64);
        assert_eq!(t.addr_bits(2), 4);
    }

    #[test]
    fn assemble_constraint_checked() {
        let mut t = tiny();
        t.w = vec![8, 5, 2];
        t.n_classes = 2;
        t.w[2] = 2;
        assert!(t.validate().is_err());
    }

    #[test]
    fn fixed_connections_strided() {
        let t = tiny();
        let c = t.fixed_connections(1);
        assert_eq!(c.len(), 4);
        assert_eq!(c[0], vec![0, 1]);
        assert_eq!(c[3], vec![6, 7]);
    }

    #[test]
    fn relu_flags_match_python_semantics() {
        assert_eq!(tiny().relu_flags(), vec![false, false, false]);
        assert_eq!(
            nid_like().relu_flags(),
            vec![false, true, false, false, false]
        );
    }

    #[test]
    fn tree_runs() {
        assert_eq!(tiny().tree_runs(), vec![(0, 2)]);
        assert_eq!(nid_like().tree_runs(), vec![(0, 1), (2, 4)]);
    }
}
