//! Configuration system: network topologies (the paper's Table I
//! parameters), training hyper-parameters, and the AOT artifact metadata
//! emitted by `python/compile/aot.py`.
//!
//! The rust side never re-derives shapes on its own: everything about the
//! compiled HLO interfaces (parameter names/shapes, argument order per
//! entry point, truth-table shapes) comes from `artifacts/meta.json`, so
//! the two languages cannot drift apart silently.

mod topology;
mod train;

pub use topology::Topology;
pub use train::TrainConfig;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One lowered entry point (e.g. `train_step`): its HLO file and flat
/// argument/output name lists.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<String>,
    pub outputs: Vec<String>,
}

/// Everything `aot.py` recorded about one compiled configuration.
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    pub topology: Topology,
    pub relu_flags: Vec<bool>,
    /// (name, shape) of sparse-model trainable parameters, in HLO order.
    pub param_spec: Vec<(String, Vec<usize>)>,
    /// (name, shape) of dense-variant parameters, in HLO order.
    pub param_spec_dense: Vec<(String, Vec<usize>)>,
    /// (name, shape) of batch-norm running statistics.
    pub stats_spec: Vec<(String, Vec<usize>)>,
    /// (name, shape) of connection-index inputs.
    pub conn_spec: Vec<(String, Vec<usize>)>,
    /// (name, shape) of per-layer truth tables.
    pub table_spec: Vec<(String, Vec<usize>)>,
    pub entries: BTreeMap<String, EntrySpec>,
}

/// The parsed `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigMeta>,
}

fn parse_spec(j: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    j.as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            if p.len() != 2 {
                bail!("bad spec entry");
            }
            Ok((p[0].as_str()?.to_string(), p[1].usize_vec()?))
        })
        .collect()
}

impl Meta {
    /// Load and validate `<dir>/meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Meta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing meta.json")?;
        let mut configs = BTreeMap::new();
        for (name, cj) in j.at("configs")?.as_obj()? {
            let topology = Topology::from_json(cj.at("topology")?)
                .with_context(|| format!("config {name}"))?;
            topology.validate()?;
            let relu_flags = cj
                .at("relu_flags")?
                .as_arr()?
                .iter()
                .map(|b| b.as_bool())
                .collect::<Result<Vec<_>>>()?;
            let mut entries = BTreeMap::new();
            for (ename, ej) in cj.at("entries")?.as_obj()? {
                let args = ej
                    .at("args")?
                    .as_arr()?
                    .iter()
                    .map(|a| Ok(a.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?;
                let outputs = ej
                    .at("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|a| Ok(a.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?;
                entries.insert(
                    ename.clone(),
                    EntrySpec {
                        name: ename.clone(),
                        file: dir.join(ej.at("file")?.as_str()?),
                        args,
                        outputs,
                    },
                );
            }
            configs.insert(
                name.clone(),
                ConfigMeta {
                    topology,
                    relu_flags,
                    param_spec: parse_spec(cj.at("param_spec")?)?,
                    param_spec_dense: parse_spec(cj.at("param_spec_dense")?)?,
                    stats_spec: parse_spec(cj.at("stats_spec")?)?,
                    conn_spec: parse_spec(cj.at("conn_spec")?)?,
                    table_spec: parse_spec(cj.at("table_spec")?)?,
                    entries,
                },
            );
        }
        Ok(Meta { dir, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigMeta> {
        self.configs
            .get(name)
            .with_context(|| format!("unknown config '{name}' in meta.json"))
    }

    /// Default artifacts directory: `$NLA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("NLA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

impl ConfigMeta {
    /// Entry spec lookup with a good error.
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact entry '{name}' missing"))
    }

    /// Shape of parameter `name` (sparse spec).
    pub fn param_shape(&self, name: &str) -> Result<&[usize]> {
        self.param_spec
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
            .with_context(|| format!("unknown param '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta_json() -> String {
        r#"{
 "adam": {"b1": 0.9, "b2": 0.999, "eps": 1e-08},
 "configs": {
  "tiny": {
   "topology": {"name":"tiny","n_in":12,"beta_in":2,"w":[8,4,2],
     "a":[0,1,1],"F":[3,2,2],"beta":[2,2,4],"L_sub":2,"N":8,"S":2,
     "n_classes":2,"dataset":"synthetic","batch":16},
   "relu_flags": [false,false,false],
   "param_spec": [["l0_W0",[8,3,8]],["l0_logs",[]]],
   "param_spec_dense": [["l0_W0",[8,12,8]],["l0_logs",[]]],
   "stats_spec": [["l0_rm",[8]],["l0_rv",[8]]],
   "conn_spec": [["l0_conn",[8,3]]],
   "table_spec": [["l0_tables",[8,64]]],
   "entries": {
    "infer": {"file":"tiny/infer.hlo.txt","args":["p:l0_W0","x"],
              "outputs":["codes","logits"]}
   }
  }
 }
}"#
        .to_string()
    }

    #[test]
    fn parse_sample_meta() {
        let dir = std::env::temp_dir().join("nla_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), sample_meta_json()).unwrap();
        let meta = Meta::load(&dir).unwrap();
        let cfg = meta.config("tiny").unwrap();
        assert_eq!(cfg.topology.w, vec![8, 4, 2]);
        assert_eq!(cfg.param_shape("l0_W0").unwrap(), &[8, 3, 8]);
        assert_eq!(cfg.entry("infer").unwrap().args.len(), 2);
        assert!(cfg.entry("nope").is_err());
        assert!(meta.config("missing").is_err());
    }
}
