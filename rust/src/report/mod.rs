//! Paper-style table rendering for the bench harnesses.

/// A fixed-width text table (markdown-compatible).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Scientific-notation formatting matching the paper's ADP column
/// (e.g. 1.06e4 for 1.06x10^4).
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{mant:.2}e{exp}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// "NxM reduction" ratio line used for the paper's headline claims.
pub fn ratio_line(label: &str, ours: f64, theirs: f64) -> String {
    if ours <= 0.0 {
        return format!("{label}: n/a");
    }
    format!("{label}: {:.2}x", theirs / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "acc"]);
        t.row(&["ours".into(), "98.6%".into()]);
        t.row(&["baseline-with-long-name".into(), "96%".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| ours"));
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(10600.0), "1.06e4");
        assert_eq!(sci(127.0), "1.27e2");
        assert_eq!(sci(0.0), "0");
    }

    #[test]
    fn ratio_and_pct() {
        assert_eq!(pct(0.986), "98.6%");
        assert!(ratio_line("vs X", 100.0, 842.0).contains("8.42x"));
    }
}
