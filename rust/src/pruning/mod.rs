//! Hardware-aware structured pruning — the "learned mappings" stage.
//!
//! Following PolyLUT's extended method (paper §II-F, §III-A): after dense
//! training with the group-lasso regularizer (which lives in the L2
//! `train_step_dense` artifact), each learned layer's units keep only
//! their top-`F` candidate inputs by *group norm* — the l2 norm of all
//! first-layer weights attached to one (unit, input) pair, including the
//! skip weight.  The sparse tree model is then retrained from scratch on
//! the selected connectivity.
//!
//! The "w/o Learned Mappings" ablation of Fig. 5 replaces the selection
//! with seeded random connectivity.

use crate::util::Rng;

/// Group-norm score of every (unit, candidate input) pair of a dense
/// learned layer.
///
/// * `w0_dense`: `[units, p, n_hidden]` flattened row-major
/// * `wskip_dense`: `[units, p]` flattened row-major
///
/// Returns `[units][p]` scores.
pub fn group_scores(units: usize, p: usize, n_hidden: usize,
                    w0_dense: &[f32], wskip_dense: &[f32]) -> Vec<Vec<f32>> {
    assert_eq!(w0_dense.len(), units * p * n_hidden);
    assert_eq!(wskip_dense.len(), units * p);
    (0..units)
        .map(|u| {
            (0..p)
                .map(|i| {
                    let base = (u * p + i) * n_hidden;
                    let mut acc = 0f64;
                    for k in 0..n_hidden {
                        let w = w0_dense[base + k] as f64;
                        acc += w * w;
                    }
                    let s = wskip_dense[u * p + i] as f64;
                    (acc + s * s).sqrt() as f32
                })
                .collect()
        })
        .collect()
}

/// Keep the top-`f` inputs per unit by score (ties broken by lower index,
/// result sorted ascending for deterministic wiring).
pub fn select_top_f(scores: &[Vec<f32>], f: usize) -> Vec<Vec<u32>> {
    scores
        .iter()
        .map(|row| {
            let mut idx: Vec<u32> = (0..row.len() as u32).collect();
            idx.sort_by(|&a, &b| {
                row[b as usize]
                    .partial_cmp(&row[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut top: Vec<u32> = idx.into_iter().take(f).collect();
            top.sort_unstable();
            top
        })
        .collect()
}

/// Random connectivity baseline (the Fig. 5 "w/o Learned Mappings"
/// ablation, and the LogicNets-style fixed random sparsity).
/// Connections are distinct per unit when `p >= f`.
pub fn random_connections(units: usize, p: usize, f: usize,
                          rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..units)
        .map(|_| {
            let mut c: Vec<u32> = if f <= p {
                rng.sample_distinct(p, f).into_iter().map(|i| i as u32).collect()
            } else {
                (0..f).map(|_| rng.below(p) as u32).collect()
            };
            c.sort_unstable();
            c
        })
        .collect()
}

/// Fraction of selected connections that land in a reference index set —
/// used to quantify how well learned mappings find informative inputs
/// (the paper's NID argument).
pub fn selection_hit_rate(selected: &[Vec<u32>], reference: &[usize]) -> f64 {
    let refset: std::collections::HashSet<u32> =
        reference.iter().map(|&i| i as u32).collect();
    let total: usize = selected.iter().map(|s| s.len()).sum();
    if total == 0 {
        return 0.0;
    }
    let hits: usize = selected
        .iter()
        .flat_map(|s| s.iter())
        .filter(|&&i| refset.contains(&i))
        .count();
    hits as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_scores_math() {
        // 1 unit, 2 inputs, 2 hidden: input0 weights (3,4), skip 0 -> 5
        //                             input1 weights (0,0), skip 2 -> 2
        let s = group_scores(1, 2, 2, &[3.0, 4.0, 0.0, 0.0], &[0.0, 2.0]);
        assert!((s[0][0] - 5.0).abs() < 1e-6);
        assert!((s[0][1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn top_f_selects_largest_sorted() {
        let scores = vec![vec![0.1, 5.0, 0.3, 4.0, 0.2]];
        let sel = select_top_f(&scores, 2);
        assert_eq!(sel[0], vec![1, 3]);
    }

    #[test]
    fn top_f_deterministic_on_ties() {
        let scores = vec![vec![1.0, 1.0, 1.0, 1.0]];
        assert_eq!(select_top_f(&scores, 2)[0], vec![0, 1]);
    }

    #[test]
    fn random_connections_distinct_and_in_range() {
        let mut rng = Rng::new(1);
        let conns = random_connections(50, 30, 6, &mut rng);
        for c in &conns {
            assert_eq!(c.len(), 6);
            assert!(c.windows(2).all(|w| w[0] < w[1])); // sorted distinct
            assert!(c.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn random_connections_with_repetition_when_f_gt_p() {
        let mut rng = Rng::new(2);
        let conns = random_connections(4, 3, 5, &mut rng);
        for c in &conns {
            assert_eq!(c.len(), 5);
            assert!(c.iter().all(|&i| i < 3));
        }
    }

    #[test]
    fn hit_rate() {
        let sel = vec![vec![0, 1, 2], vec![3, 9]];
        assert!((selection_hit_rate(&sel, &[0, 1, 3]) - 0.6).abs() < 1e-9);
        assert_eq!(selection_hit_rate(&[], &[1]), 0.0);
    }

    #[test]
    fn learned_beats_random_on_planted_signal() {
        // scores peaked on a known informative set: selection must hit it
        let informative: Vec<usize> = (10..16).collect();
        let scores: Vec<Vec<f32>> = (0..8)
            .map(|u| {
                (0..100)
                    .map(|i| {
                        if informative.contains(&i) { 2.0 + u as f32 * 0.01 }
                        else { 0.1 }
                    })
                    .collect()
            })
            .collect();
        let sel = select_top_f(&scores, 6);
        assert!((selection_hit_rate(&sel, &informative) - 1.0).abs() < 1e-9);
        let mut rng = Rng::new(3);
        let rand = random_connections(8, 100, 6, &mut rng);
        assert!(selection_hit_rate(&rand, &informative) < 0.3);
    }
}
