//! Procedural MNIST substitute: 28x28 handwritten-digit-like glyphs.
//!
//! Each digit class is a set of stroke polylines/arcs in a unit box,
//! rasterized with a pen radius and distorted per sample by a random
//! affine transform (rotation, scale, shear, translation), pen-width
//! jitter and pixel noise — enough intra-class variability that the
//! classification task is non-trivial, while staying fully deterministic
//! from the seed.  Data augmentation (the paper's `+aug` MNIST row)
//! re-renders training samples with stronger distortions.

use super::{Dataset, GenOpts, Splits};
use crate::util::Rng;

const SIDE: usize = 28;
const N_IN: usize = SIDE * SIDE;

/// Stroke = polyline through (x, y) control points in [0,1]^2 glyph space.
type Stroke = &'static [(f32, f32)];

fn glyph(digit: usize) -> &'static [Stroke] {
    // Hand-laid control points, loosely following handwritten shapes.
    const D0: &[Stroke] = &[&[
        (0.50, 0.08), (0.78, 0.18), (0.85, 0.50), (0.78, 0.82),
        (0.50, 0.92), (0.22, 0.82), (0.15, 0.50), (0.22, 0.18), (0.50, 0.08),
    ]];
    const D1: &[Stroke] = &[&[(0.35, 0.25), (0.55, 0.10), (0.55, 0.90)],
                            &[(0.35, 0.90), (0.75, 0.90)]];
    const D2: &[Stroke] = &[&[
        (0.22, 0.28), (0.35, 0.10), (0.65, 0.10), (0.78, 0.30),
        (0.60, 0.55), (0.30, 0.75), (0.20, 0.90), (0.82, 0.90),
    ]];
    const D3: &[Stroke] = &[&[
        (0.22, 0.15), (0.70, 0.12), (0.55, 0.45), (0.75, 0.60),
        (0.70, 0.85), (0.40, 0.93), (0.20, 0.82),
    ]];
    const D4: &[Stroke] = &[&[(0.65, 0.92), (0.65, 0.08), (0.20, 0.62), (0.85, 0.62)]];
    const D5: &[Stroke] = &[&[
        (0.75, 0.10), (0.30, 0.10), (0.26, 0.48), (0.55, 0.42),
        (0.78, 0.60), (0.72, 0.85), (0.35, 0.93), (0.20, 0.82),
    ]];
    const D6: &[Stroke] = &[&[
        (0.68, 0.10), (0.38, 0.30), (0.24, 0.62), (0.32, 0.86),
        (0.62, 0.92), (0.76, 0.72), (0.62, 0.55), (0.32, 0.60),
    ]];
    const D7: &[Stroke] = &[&[(0.18, 0.12), (0.82, 0.12), (0.45, 0.92)],
                            &[(0.35, 0.55), (0.70, 0.55)]];
    const D8: &[Stroke] = &[&[
        (0.50, 0.10), (0.72, 0.22), (0.60, 0.45), (0.50, 0.50),
        (0.28, 0.40), (0.32, 0.18), (0.50, 0.10),
    ], &[
        (0.50, 0.50), (0.75, 0.62), (0.70, 0.86), (0.50, 0.92),
        (0.28, 0.84), (0.25, 0.62), (0.50, 0.50),
    ]];
    const D9: &[Stroke] = &[&[
        (0.72, 0.40), (0.48, 0.48), (0.26, 0.35), (0.34, 0.12),
        (0.62, 0.08), (0.74, 0.25), (0.72, 0.40), (0.66, 0.70), (0.52, 0.92),
    ]];
    [D0, D1, D2, D3, D4, D5, D6, D7, D8, D9][digit]
}

struct Affine {
    a: f32, b: f32, c: f32, d: f32, tx: f32, ty: f32,
}

impl Affine {
    fn sample(rng: &mut Rng, strong: bool) -> Affine {
        let k = if strong { 1.6 } else { 1.0 };
        let rot = rng.range(-0.22, 0.22) * k;
        let scale = 1.0 + rng.range(-0.12, 0.12) * k;
        let shear = rng.range(-0.15, 0.15) * k;
        let (sin, cos) = rot.sin_cos();
        Affine {
            a: scale * cos,
            b: scale * (shear * cos - sin),
            c: scale * sin,
            d: scale * (shear * sin + cos),
            tx: rng.range(-0.07, 0.07) * k,
            ty: rng.range(-0.07, 0.07) * k,
        }
    }

    fn apply(&self, (x, y): (f32, f32)) -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        (
            0.5 + self.a * cx + self.b * cy + self.tx,
            0.5 + self.c * cx + self.d * cy + self.ty,
        )
    }
}

fn dist_to_segment(p: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (p.0 - a.0, p.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 <= 1e-12 { 0.0 } else { ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0) };
    let (dx, dy) = (p.0 - (a.0 + t * vx), p.1 - (a.1 + t * vy));
    (dx * dx + dy * dy).sqrt()
}

/// Render one digit sample as N_IN features in [-1, 1) (ink = positive).
pub fn render(digit: usize, rng: &mut Rng, strong_aug: bool) -> Vec<f32> {
    let aff = Affine::sample(rng, strong_aug);
    let pen = rng.range(0.035, 0.055) * if strong_aug { 1.2 } else { 1.0 };
    let strokes = glyph(digit);
    // transform control points once
    let tstrokes: Vec<Vec<(f32, f32)>> = strokes
        .iter()
        .map(|s| s.iter().map(|&p| aff.apply(p)).collect())
        .collect();
    let mut out = vec![0.0f32; N_IN];
    for py in 0..SIDE {
        for px in 0..SIDE {
            let p = ((px as f32 + 0.5) / SIDE as f32, (py as f32 + 0.5) / SIDE as f32);
            let mut dmin = f32::MAX;
            for s in &tstrokes {
                for seg in s.windows(2) {
                    dmin = dmin.min(dist_to_segment(p, seg[0], seg[1]));
                }
            }
            // smooth ink profile then noise; threshold lives in the encoder
            let ink = (1.0 - (dmin / pen)).clamp(-1.0, 1.0);
            let noise = rng.normal_ms(0.0, 0.08);
            out[py * SIDE + px] = (ink + noise).clamp(-1.0, 0.999);
        }
    }
    out
}

fn gen_split(n: usize, beta_in: usize, rng: &mut Rng, augment: bool) -> Dataset {
    let mut x = Vec::with_capacity(n * N_IN);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let digit = i % 10; // balanced classes
        let strong = augment && rng.bernoulli(0.5);
        let feats = render(digit, rng, strong);
        x.extend(Dataset::encode_features(&feats, beta_in));
        y.push(digit as i32);
    }
    Dataset { x, y, n, n_in: N_IN, beta_in, n_classes: 10 }
}

pub fn generate(beta_in: usize, opts: &GenOpts) -> Splits {
    let mut rng = Rng::new(opts.seed ^ 0x4D4E_4953_54u64);
    let train = gen_split(opts.n_train, beta_in, &mut rng.fork(1), opts.augment);
    let test = gen_split(opts.n_test, beta_in, &mut rng.fork(2), false);
    Splits { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_produces_ink() {
        let mut rng = Rng::new(3);
        for d in 0..10 {
            let img = render(d, &mut rng, false);
            let ink = img.iter().filter(|&&v| v > 0.0).count();
            assert!(ink > 20 && ink < 500, "digit {d}: ink {ink}");
        }
    }

    #[test]
    fn distinct_digits_differ() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = render(0, &mut r1, false);
        let b = render(1, &mut r2, false);
        let diff = a
            .iter()
            .zip(&b)
            .filter(|(x, y)| (**x > 0.0) != (**y > 0.0))
            .count();
        assert!(diff > 30, "0 vs 1 differ in {diff} pixels");
    }

    #[test]
    fn same_class_varies() {
        let mut rng = Rng::new(7);
        let a = render(3, &mut rng, false);
        let b = render(3, &mut rng, false);
        assert_ne!(
            Dataset::encode_features(&a, 1),
            Dataset::encode_features(&b, 1)
        );
    }

    #[test]
    fn split_balanced() {
        let opts = GenOpts { n_train: 200, n_test: 50, ..Default::default() };
        let s = generate(1, &opts);
        let counts = s.train.class_counts();
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn augmentation_changes_training_split() {
        let base = GenOpts { n_train: 50, n_test: 10, ..Default::default() };
        let plain = generate(1, &base);
        let aug = generate(1, &GenOpts { augment: true, ..base });
        assert_ne!(plain.train.x, aug.train.x);
        // test split identical: augmentation must not leak into eval
        assert_eq!(plain.test.x, aug.test.x);
    }
}
