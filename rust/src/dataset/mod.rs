//! Synthetic dataset substrates.
//!
//! The paper evaluates on MNIST, two jet-substructure sources (CERNBox /
//! OpenML) and UNSW-NB15 network-intrusion data; none are downloadable in
//! this offline environment, so each is replaced by a procedurally
//! generated equivalent that preserves dimensionality, class structure and
//! the properties the paper's arguments rely on (see DESIGN.md §2).
//!
//! Features are produced in [-1, 1) and quantized to `beta_in`-bit codes
//! with the same midrise quantizer the JAX model uses; the codes are the
//! single source of truth consumed by both the PJRT executables and the
//! rust netlist simulator.

mod jsc_synth;
mod mnist_synth;
mod nid_synth;

pub use jsc_synth::JscVariant;
pub use nid_synth::informative_positions as nid_informative_positions;

use anyhow::{bail, Result};

use crate::util::Rng;

/// A labelled, quantized dataset split.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n * n_in` input codes, row-major, each in `[0, 2^beta_in)`.
    pub x: Vec<i32>,
    /// `n` class labels (binary tasks use {0, 1}).
    pub y: Vec<i32>,
    pub n: usize,
    pub n_in: usize,
    pub beta_in: usize,
    pub n_classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[i32] {
        &self.x[i * self.n_in..(i + 1) * self.n_in]
    }

    /// Encode a real-valued feature vector into codes (midrise, scale 1.0 —
    /// mirrors `quant.encode` in python; self-consistency is what matters).
    pub fn encode_features(feats: &[f32], beta: usize) -> Vec<i32> {
        let half = (1i64 << (beta - 1)) as f32;
        let max_code = (1i64 << beta) - 1;
        feats
            .iter()
            .map(|&v| {
                let c = (v * half).floor() as i64 + half as i64;
                c.clamp(0, max_code) as i32
            })
            .collect()
    }

    /// Pack rows `idx` into a fixed-size batch, padding by repeating row 0.
    pub fn batch(&self, idx: &[usize], batch: usize) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * self.n_in);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let i = if b < idx.len() { idx[b] } else { idx.get(0).copied().unwrap_or(0) };
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Class balance histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let k = self.n_classes.max(2);
        let mut counts = vec![0usize; k];
        for &y in &self.y {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// Train/test pair.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
}

/// Generation options.
#[derive(Clone, Debug)]
pub struct GenOpts {
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
    /// MNIST only: apply data augmentation to the training split
    pub augment: bool,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts { n_train: 8192, n_test: 2048, seed: 0xDA7A, augment: false }
    }
}

/// Generate the dataset named by a topology's `dataset` field.
pub fn generate(name: &str, beta_in: usize, opts: &GenOpts) -> Result<Splits> {
    match name {
        "mnist" => Ok(mnist_synth::generate(beta_in, opts)),
        "jsc_cernbox" => Ok(jsc_synth::generate(JscVariant::CernBox, beta_in, opts)),
        "jsc_openml" => Ok(jsc_synth::generate(JscVariant::OpenMl, beta_in, opts)),
        "nid" => Ok(nid_synth::generate(beta_in, opts)),
        "synthetic" => Ok(synthetic_blobs(12, 2, beta_in, opts)),
        other => bail!("unknown dataset '{other}'"),
    }
}

/// Tiny gaussian-blob dataset for tests.
pub fn synthetic_blobs(n_in: usize, n_classes: usize, beta_in: usize,
                       opts: &GenOpts) -> Splits {
    let mut rng = Rng::new(opts.seed);
    let centers: Vec<Vec<f32>> = (0..n_classes)
        .map(|_| (0..n_in).map(|_| rng.range(-0.6, 0.6)).collect())
        .collect();
    let mut gen = |n: usize, rng: &mut Rng| {
        let mut x = Vec::with_capacity(n * n_in);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(n_classes);
            let feats: Vec<f32> = centers[c]
                .iter()
                .map(|&m| (m + rng.normal_ms(0.0, 0.25)).clamp(-1.0, 0.999))
                .collect();
            x.extend(Dataset::encode_features(&feats, beta_in));
            y.push(c as i32);
        }
        Dataset { x, y, n, n_in, beta_in, n_classes }
    };
    let train = gen(opts.n_train, &mut rng);
    let test = gen(opts.n_test, &mut rng);
    Splits { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_features_saturates() {
        let c = Dataset::encode_features(&[-5.0, -1.0, -0.1, 0.0, 0.5, 5.0], 2);
        assert_eq!(c, vec![0, 0, 1, 2, 3, 3]);
    }

    #[test]
    fn encode_features_beta1_sign() {
        let c = Dataset::encode_features(&[-0.7, -0.01, 0.0, 0.3], 1);
        assert_eq!(c, vec![0, 0, 1, 1]);
    }

    #[test]
    fn blobs_shapes_and_determinism() {
        let opts = GenOpts { n_train: 100, n_test: 40, ..Default::default() };
        let a = synthetic_blobs(12, 3, 2, &opts);
        let b = synthetic_blobs(12, 3, 2, &opts);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.n, 100);
        assert_eq!(a.test.n, 40);
        assert_eq!(a.train.x.len(), 100 * 12);
        assert!(a.train.x.iter().all(|&c| (0..4).contains(&c)));
    }

    #[test]
    fn batch_pads_by_repeating() {
        let opts = GenOpts { n_train: 10, n_test: 4, ..Default::default() };
        let s = synthetic_blobs(4, 2, 1, &opts);
        let (x, y) = s.train.batch(&[1, 2], 5);
        assert_eq!(x.len(), 20);
        assert_eq!(y.len(), 5);
        assert_eq!(&x[8..12], s.train.row(1)); // padding repeats idx[0]
        assert_eq!(y[4], s.train.y[1]);
    }

    #[test]
    fn all_named_datasets_generate() {
        let opts = GenOpts { n_train: 64, n_test: 32, ..Default::default() };
        for (name, beta) in [("mnist", 1), ("jsc_cernbox", 4),
                             ("jsc_openml", 3), ("nid", 1)] {
            let s = generate(name, beta, &opts).unwrap();
            assert_eq!(s.train.n, 64, "{name}");
            assert_eq!(s.test.n, 32, "{name}");
            let max = (1 << beta) - 1;
            assert!(s.train.x.iter().all(|&c| c >= 0 && c <= max), "{name}");
        }
    }

    #[test]
    fn class_counts_cover_all_classes() {
        let opts = GenOpts { n_train: 2000, n_test: 200, ..Default::default() };
        for (name, beta, k) in [("mnist", 1, 10), ("jsc_cernbox", 4, 5),
                                ("nid", 1, 2)] {
            let s = generate(name, beta, &opts).unwrap();
            let counts = s.train.class_counts();
            assert_eq!(counts.len(), k);
            assert!(counts.iter().all(|&c| c > 0), "{name}: {counts:?}");
        }
    }
}
