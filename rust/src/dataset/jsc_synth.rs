//! Synthetic jet-substructure classification (JSC) data.
//!
//! The real task: 16 high-level jet substructure observables, 5 jet
//! classes (q, g, W, Z, t).  Our substitute draws each class from a
//! class-conditional latent-factor model — `x = mu_c + A_c z + eps` with a
//! few shared nonlinear features (pairwise products, squared norms) mixed
//! in, mimicking the correlated, partially-overlapping distributions of
//! the physics observables.  Class overlap is tuned so a dense
//! floating-point MLP lands in the paper's ~76% regime.
//!
//! Two variants model the paper's two data sources: `CernBox` (more
//! instances, noisier labels — the paper reports lower accuracy on it)
//! and `OpenMl` (cleaner curation, higher accuracy).

use super::{Dataset, GenOpts, Splits};
use crate::util::Rng;

pub const N_FEATURES: usize = 16;
pub const N_CLASSES: usize = 5;
const N_LATENT: usize = 6;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JscVariant {
    CernBox,
    OpenMl,
}

impl JscVariant {
    fn label_noise(self) -> f64 {
        match self {
            JscVariant::CernBox => 0.09,
            JscVariant::OpenMl => 0.04,
        }
    }

    fn feature_noise(self) -> f32 {
        // calibrated (DESIGN.md §2) so a dense FP MLP lands near the
        // paper's ~76-77% ceiling on each source
        match self {
            JscVariant::CernBox => 0.47,
            JscVariant::OpenMl => 0.43,
        }
    }

    fn seed_tag(self) -> u64 {
        match self {
            JscVariant::CernBox => 0xCE57,
            JscVariant::OpenMl => 0x09E7,
        }
    }
}

struct ClassModel {
    mu: [f32; N_FEATURES],
    /// mixing matrix latent -> features
    a: [[f32; N_LATENT]; N_FEATURES],
}

fn build_models(rng: &mut Rng) -> Vec<ClassModel> {
    (0..N_CLASSES)
        .map(|_| {
            let mut mu = [0.0f32; N_FEATURES];
            for m in mu.iter_mut() {
                *m = rng.range(-0.45, 0.45);
            }
            let mut a = [[0.0f32; N_LATENT]; N_FEATURES];
            for row in a.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.normal_ms(0.0, 0.22);
                }
            }
            ClassModel { mu, a }
        })
        .collect()
}

fn sample(model: &ClassModel, rng: &mut Rng, feat_noise: f32) -> [f32; N_FEATURES] {
    let mut z = [0.0f32; N_LATENT];
    for v in z.iter_mut() {
        *v = rng.normal();
    }
    let mut x = [0.0f32; N_FEATURES];
    for i in 0..N_FEATURES {
        let mut acc = model.mu[i];
        for k in 0..N_LATENT {
            acc += model.a[i][k] * z[k];
        }
        x[i] = acc;
    }
    // physics-like nonlinear observables on a few coordinates:
    // jet "mass" ~ quadratic in latents, n-subjettiness ratios ~ products
    x[13] = 0.35 * (z[0] * z[0] + z[1] * z[1]) + 0.3 * model.mu[13] - 0.35;
    x[14] = 0.5 * z[0] * z[1] + model.mu[14];
    x[15] = 0.4 * (z[2] * z[3]).tanh() + model.mu[15];
    for v in x.iter_mut() {
        *v = (*v + rng.normal_ms(0.0, feat_noise)).tanh() * 0.999;
    }
    x
}

fn gen_split(n: usize, beta_in: usize, models: &[ClassModel],
             variant: JscVariant, rng: &mut Rng) -> Dataset {
    let mut x = Vec::with_capacity(n * N_FEATURES);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % N_CLASSES; // balanced
        let feats = sample(&models[c], rng, variant.feature_noise());
        x.extend(Dataset::encode_features(&feats, beta_in));
        let label = if rng.bernoulli(variant.label_noise()) {
            rng.below(N_CLASSES) as i32
        } else {
            c as i32
        };
        y.push(label);
    }
    Dataset { x, y, n, n_in: N_FEATURES, beta_in, n_classes: N_CLASSES }
}

pub fn generate(variant: JscVariant, beta_in: usize, opts: &GenOpts) -> Splits {
    // The two variants share the same underlying class models (same task,
    // different curation), exactly like the paper's two data sources.
    let mut model_rng = Rng::new(0x4A53_4300 ^ opts.seed);
    let models = build_models(&mut model_rng);
    let mut rng = Rng::new(opts.seed ^ variant.seed_tag());
    let train = gen_split(opts.n_train, beta_in, &models, variant, &mut rng.fork(1));
    let test = gen_split(opts.n_test, beta_in, &models, variant, &mut rng.fork(2));
    Splits { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let opts = GenOpts { n_train: 500, n_test: 100, ..Default::default() };
        let s = generate(JscVariant::CernBox, 4, &opts);
        assert_eq!(s.train.n_in, 16);
        assert_eq!(s.train.n_classes, 5);
        assert_eq!(s.train.class_counts().len(), 5);
    }

    #[test]
    fn variants_share_structure_but_differ_in_noise() {
        let opts = GenOpts { n_train: 2000, n_test: 100, ..Default::default() };
        let cb = generate(JscVariant::CernBox, 4, &opts);
        let om = generate(JscVariant::OpenMl, 4, &opts);
        // noisier labels in cernbox: count label != i%5 disagreements
        let noisy = |d: &Dataset| {
            d.y.iter().enumerate().filter(|(i, &y)| y as usize != i % 5).count()
        };
        assert!(noisy(&cb.train) > noisy(&om.train));
    }

    #[test]
    fn classes_are_separable_by_nearest_centroid() {
        // sanity: a trivial classifier must beat chance by a wide margin,
        // otherwise the task carries no signal for the NN comparison.
        let opts = GenOpts { n_train: 3000, n_test: 1000, ..Default::default() };
        let s = generate(JscVariant::OpenMl, 8, &opts);
        let d = &s.train;
        let mut cent = vec![vec![0.0f64; d.n_in]; 5];
        let mut cnt = [0usize; 5];
        for i in 0..d.n {
            let c = d.y[i] as usize;
            cnt[c] += 1;
            for (j, &v) in d.row(i).iter().enumerate() {
                cent[c][j] += v as f64;
            }
        }
        for c in 0..5 {
            for v in cent[c].iter_mut() {
                *v /= cnt[c].max(1) as f64;
            }
        }
        let t = &s.test;
        let correct = (0..t.n)
            .filter(|&i| {
                let row = t.row(i);
                let best = (0..5)
                    .min_by(|&a, &b| {
                        let da: f64 = row.iter().zip(&cent[a])
                            .map(|(&x, &m)| (x as f64 - m).powi(2)).sum();
                        let db: f64 = row.iter().zip(&cent[b])
                            .map(|(&x, &m)| (x as f64 - m).powi(2)).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                best == t.y[i] as usize
            })
            .count();
        let acc = correct as f64 / t.n as f64;
        assert!(acc > 0.45, "nearest-centroid acc only {acc}");
    }
}
