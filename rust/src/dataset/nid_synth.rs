//! Synthetic network-intrusion detection (NID) data.
//!
//! Models the UNSW-NB15 setup used by the paper (and [Murovič & Trost]):
//! 593 one-bit inputs derived from 49 packet features, binary benign(0) /
//! malicious(1) labels.  The paper's key observation — "it is likely that
//! only a small subset of these inputs is truly relevant" — is baked in:
//! only `N_INFORMATIVE` bit positions carry the label signal (through a
//! planted noisy rule over several bit-groups), a block of bits is
//! redundant copies of informative ones (as one-hot/thermometer encodings
//! of shared fields produce in the real data), and the rest is noise.
//! Learned input mappings should discover the informative subset; random
//! fan-in wastes logic on noise bits — exactly the paper's NID argument.

use super::{Dataset, GenOpts, Splits};
use crate::util::Rng;

pub const N_BITS: usize = 593;
const N_INFORMATIVE: usize = 24;
const N_REDUNDANT: usize = 48;
const LABEL_NOISE: f64 = 0.03;

struct NidModel {
    /// positions of the informative bits
    informative: Vec<usize>,
    /// (source informative slot, destination position, invert)
    redundant: Vec<(usize, usize, bool)>,
    /// planted rule: weights over informative slots + threshold
    weights: Vec<f32>,
    threshold: f32,
}

fn build_model(rng: &mut Rng) -> NidModel {
    let picks = rng.sample_distinct(N_BITS, N_INFORMATIVE + N_REDUNDANT);
    let informative = picks[..N_INFORMATIVE].to_vec();
    let redundant = picks[N_INFORMATIVE..]
        .iter()
        .map(|&pos| (rng.below(N_INFORMATIVE), pos, rng.bernoulli(0.5)))
        .collect();
    // planted rule: signed integer-ish weights, a few strong bits
    let weights: Vec<f32> = (0..N_INFORMATIVE)
        .map(|i| {
            let base = if i < 6 { 2.2 } else { 1.0 };
            base * if rng.bernoulli(0.5) { 1.0 } else { -1.0 }
                * rng.range(0.6, 1.4)
        })
        .collect();
    NidModel { informative, redundant, weights, threshold: 0.0 }
}

fn sample(model: &NidModel, rng: &mut Rng) -> (Vec<f32>, i32) {
    // attack prevalence ~ 45%: informative bits are drawn biased by the
    // label in proportion to their planted weight, so strong bits carry a
    // large, learnable correlation and weak bits a small one.
    let label = rng.bernoulli(0.45);
    let sign = if label { 1.0 } else { -1.0 };
    let mut info_bits = vec![false; N_INFORMATIVE];
    for (i, b) in info_bits.iter_mut().enumerate() {
        let w = model.weights[i];
        // strong bits carry ~0.3-0.45 bias, weak ones ~0.1: the task is
        // learnable to the paper's ~93% by a model that *finds* the bits
        let strength = (0.16 * w.abs()).min(0.45) * w.signum();
        let p = (0.5 + sign * strength as f64).clamp(0.05, 0.95);
        *b = rng.bernoulli(p);
    }
    let _ = model.threshold;
    let mut feats = vec![0.0f32; N_BITS];
    for f in feats.iter_mut() {
        *f = if rng.bernoulli(0.5) { 0.5 } else { -0.5 };
    }
    for (slot, &pos) in model.informative.iter().enumerate() {
        feats[pos] = if info_bits[slot] { 0.5 } else { -0.5 };
    }
    for &(slot, pos, invert) in &model.redundant {
        let v = info_bits[slot] ^ invert;
        feats[pos] = if v { 0.5 } else { -0.5 };
    }
    let noisy_label = if rng.bernoulli(LABEL_NOISE) { !label } else { label };
    (feats, noisy_label as i32)
}

fn gen_split(n: usize, beta_in: usize, model: &NidModel, rng: &mut Rng) -> Dataset {
    let mut x = Vec::with_capacity(n * N_BITS);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let (feats, label) = sample(model, rng);
        x.extend(Dataset::encode_features(&feats, beta_in));
        y.push(label);
    }
    Dataset { x, y, n, n_in: N_BITS, beta_in, n_classes: 2 }
}

pub fn generate(beta_in: usize, opts: &GenOpts) -> Splits {
    let mut rng = Rng::new(opts.seed ^ 0x6E1D);
    let model = build_model(&mut rng.fork(0));
    let train = gen_split(opts.n_train, beta_in, &model, &mut rng.fork(1));
    let test = gen_split(opts.n_test, beta_in, &model, &mut rng.fork(2));
    Splits { train, test }
}

/// Positions of informative + redundant bits for the given seed (used by
/// tests and the pruning-quality analysis in the fig5/nid harnesses).
pub fn informative_positions(seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ 0x6E1D);
    let model = build_model(&mut rng.fork(0));
    let mut pos = model.informative.clone();
    pos.extend(model.redundant.iter().map(|&(_, p, _)| p));
    pos
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_binary_codes() {
        let opts = GenOpts { n_train: 300, n_test: 100, ..Default::default() };
        let s = generate(1, &opts);
        assert_eq!(s.train.n_in, N_BITS);
        assert!(s.train.x.iter().all(|&c| c == 0 || c == 1));
        let counts = s.train.class_counts();
        assert!(counts[0] > 50 && counts[1] > 50, "{counts:?}");
    }

    #[test]
    fn informative_bits_predict_label() {
        // A linear probe on the informative bits must beat chance easily;
        // a probe on random noise bits must not.
        let opts = GenOpts { n_train: 3000, n_test: 1000, ..Default::default() };
        let s = generate(1, &opts);
        let pos = informative_positions(opts.seed);
        let informative = &pos[..N_INFORMATIVE];

        // per-bit correlation with the label
        let corr_at = |d: &Dataset, j: usize| {
            let mut c = 0i64;
            for i in 0..d.n {
                let b = d.x[i * d.n_in + j] * 2 - 1;
                let y = d.y[i] * 2 - 1;
                c += (b * y) as i64;
            }
            (c as f64 / d.n as f64).abs()
        };
        let info_corr: f64 = informative.iter().map(|&j| corr_at(&s.train, j)).sum::<f64>()
            / informative.len() as f64;
        let noise_positions: Vec<usize> =
            (0..N_BITS).filter(|j| !pos.contains(j)).take(24).collect();
        let noise_corr: f64 = noise_positions.iter().map(|&j| corr_at(&s.train, j)).sum::<f64>()
            / noise_positions.len() as f64;
        assert!(
            info_corr > 5.0 * noise_corr.max(1e-3),
            "info {info_corr} vs noise {noise_corr}"
        );
    }

    #[test]
    fn deterministic() {
        let opts = GenOpts { n_train: 100, n_test: 50, ..Default::default() };
        assert_eq!(generate(1, &opts).train.x, generate(1, &opts).train.x);
    }
}
