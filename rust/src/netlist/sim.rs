//! Batched netlist simulation — the L3 request-path hot loop.
//!
//! Two execution strategies per layer:
//!
//! * **gather**: signal-major scratch buffers (`prev[signal][batch]`), one
//!   table read per (unit, sample) with the address assembled from the
//!   unit's producers.  Works for any layer.
//! * **bit-plane**: the layer is decomposed into one boolean function per
//!   (unit, output bit) — a *plane*.  Each plane's true support is found
//!   with `TruthTable::bit_support` and the table is projected onto it
//!   (`TruthTable::reduced_bit_table`), so a plane qualifies whenever its
//!   *reduced* support fits in [`MAX_PLANE_SUPPORT`] address bits even if
//!   the raw address width is larger.  Signals are kept packed 64
//!   samples/word and every plane is evaluated with a Shannon mux-tree
//!   over whole words — ~64 samples per table evaluation.  Pure-boolean
//!   layers (the original "bitsliced" kernel) are the β=1 special case;
//!   see DESIGN.md §Netlist simulator.
//!
//! The packed representation survives across consecutive bit-plane layers
//! (no unpack at multi-bit boundaries — that is what v2 adds over the
//! boolean-only bitsliced kernel), and evaluation can be chunked across
//! worker threads per layer ([`SimOptions::threads`], plumbed from
//! `ServerConfig::sim_threads` on the serving path).
//!
//! Threading comes in two flavors ([`ThreadMode`]): the original
//! *scoped* path spawns `std::thread::scope` workers per layer per
//! `eval_batch` call, while the default *pooled* path parks a persistent
//! [`WorkerPool`] inside the `Simulator` and wakes it per layer.  Both
//! chunk a layer over identical disjoint unit ranges, so they are
//! bit-exact with each other; the pool merely replaces a spawn/join
//! (~tens of µs) with a condvar wake (~µs), which lets much smaller
//! layers parallelize profitably ([`PAR_MIN_WORK_POOLED`] vs
//! [`PAR_MIN_WORK`]) — the regime of high request rates with small
//! batches.
//!
//! By default ([`SimOptions::compiled`]) the hot loops do not run the
//! object-graph walk below at all: construction lowers the netlist into
//! an arena-backed [`ExecPlan`] (`netlist::plan`) and `eval_batch` /
//! `eval_one` execute the compiled program.  The interpreted walk is
//! kept behind `compiled: false` as the bit-exactness reference; the
//! two are compared by the `prop_compiled_plan_*` property suite and
//! raced by the `netlist_hotpath` compiled-vs-interpreted rows.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::plan::{self, ExecPlan, LaneExecutor, PlanOptions};
use super::{LayerSpec, Netlist};

/// Widest reduced support a plane may have and still use the packed
/// kernel: the reduced table must fit in a `u64` (2^6 entries).  This is
/// also the physical LUT input width of the target fabric, so trained
/// tables that map to single P-LUTs always qualify.
pub const MAX_PLANE_SUPPORT: usize = 6;

/// Raw address widths past this are never worth the support scan.
/// Shared with the plan compiler, which applies the same qualification
/// rule (`netlist::plan`).
pub(super) const MAX_BUILD_ADDR_BITS: usize = 16;

/// Below this many output words/codes per layer, spawning scoped
/// threads costs more than it saves and the layer runs single-threaded.
pub(super) const PAR_MIN_WORK: usize = 1 << 12;

/// Pooled threshold for the bit-plane kernel, in packed output *words*
/// (64 samples each, a Shannon-tree evaluation per word): waking a
/// parked worker is ~µs, not the tens of µs a spawn/join costs, so far
/// smaller layers amortize the handoff.
pub(super) const PAR_MIN_WORK_POOLED: usize = 1 << 8;

/// Pooled threshold for the gather kernel, in output *codes*.  A code
/// is a single table read — roughly an order of magnitude cheaper than
/// a packed word — so the floor sits proportionally higher to keep
/// tiny-batch layers from paying a wake for ~µs of work.
pub(super) const PAR_MIN_WORK_POOLED_GATHER: usize = 1 << 11;

/// Which kernel a layer was compiled to (introspection for benches and
/// the server's startup log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    Gather,
    BitPlane,
}

/// How multi-threaded layers get their workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThreadMode {
    /// Spawn `std::thread::scope` workers per layer per call (the v2
    /// behavior; kept as the bit-exactness reference and for one-shot
    /// simulators where a resident pool is not worth holding).
    Scoped,
    /// Wake a persistent [`WorkerPool`] owned by the `Simulator`
    /// (default): no spawn/join on the request path.
    Pooled,
}

/// Lane-width request for the compiled executor (CLI `--lanes`,
/// `ServerConfig::lanes`, [`SimOptions::lanes`]).  The compiled
/// bit-plane kernel is width-polymorphic over `W` consecutive packed
/// words per operation (`netlist::plan::WidePlanExecutor`); this enum
/// is how callers ask for a width before one is resolved to a concrete
/// executor by `netlist::plan::select_backend`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LaneSelect {
    /// Resolve at runtime: scalar for small batch hints, else the
    /// widest lane the CPU profits from (feature-probed on x86-64).
    #[default]
    Auto,
    /// Pin the one-word scalar reference path (W = 1).
    W1,
    /// Pin 4-word (256-bit) lanes.
    W4,
    /// Pin 8-word (512-bit) lanes.
    W8,
}

impl LaneSelect {
    /// The pinned width, or `None` for [`LaneSelect::Auto`].
    pub fn fixed_width(self) -> Option<usize> {
        match self {
            LaneSelect::Auto => None,
            LaneSelect::W1 => Some(1),
            LaneSelect::W4 => Some(4),
            LaneSelect::W8 => Some(8),
        }
    }
}

impl std::str::FromStr for LaneSelect {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<LaneSelect, Self::Err> {
        match s {
            "auto" => Ok(LaneSelect::Auto),
            "1" => Ok(LaneSelect::W1),
            "4" => Ok(LaneSelect::W4),
            "8" => Ok(LaneSelect::W8),
            other => anyhow::bail!(
                "bad lane width {other:?} (expected auto|1|4|8)"),
        }
    }
}

impl std::fmt::Display for LaneSelect {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaneSelect::Auto => write!(f, "auto"),
            LaneSelect::W1 => write!(f, "1"),
            LaneSelect::W4 => write!(f, "4"),
            LaneSelect::W8 => write!(f, "8"),
        }
    }
}

/// Simulator construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Compile qualifying layers to the bit-plane kernel (default true;
    /// disable to measure the gather baseline).
    pub bitplane: bool,
    /// Worker threads per `eval_batch` call (1 = single-threaded).
    /// Layers are chunked over unit ranges; with [`ThreadMode::Pooled`]
    /// the chunks run on `threads - 1` parked pool workers plus the
    /// calling thread, with [`ThreadMode::Scoped`] on freshly spawned
    /// scoped threads.  `PAR_MIN_WORK`/`PAR_MIN_WORK_POOLED` keep small
    /// layers serial so handoff cost cannot dominate.
    pub threads: usize,
    /// Scoped vs pooled workers (default pooled).
    pub mode: ThreadMode,
    /// Smallest batch for which word packing amortizes; below it the
    /// gather path runs even on bit-plane layers.
    pub min_bitplane_batch: usize,
    /// Execute through a compiled [`ExecPlan`] (default true): the
    /// netlist is lowered once at construction into arena-backed form
    /// (`netlist::plan`) and the hot loops run the plan.  `false` keeps
    /// the original object-graph walk — the bit-exactness reference and
    /// the interpreted baseline the `netlist_hotpath` bench compares
    /// against.
    pub compiled: bool,
    /// Lane width for the compiled bit-plane kernel: how many packed
    /// 64-sample words each table evaluation processes at once
    /// (default [`LaneSelect::Auto`] — resolved per executor by
    /// `netlist::plan::select_backend`).  Every width is bit-exact
    /// with every other; this is purely a throughput knob.
    pub lanes: LaneSelect,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            bitplane: true,
            threads: 1,
            mode: ThreadMode::Pooled,
            min_bitplane_batch: 32,
            compiled: true,
            lanes: LaneSelect::Auto,
        }
    }
}

/// A persistent pool of parked worker threads that cooperate with the
/// calling thread on jobs of independent, indexed tasks.
///
/// `run(n, f)` posts a job of `n` tasks; pool workers and the caller
/// claim indices from a shared cursor and each executes `f(i)`.  `run`
/// returns only once every task has completed, which is what makes the
/// internal lifetime erasure sound: no worker can still hold the closure
/// after `run` returns.  Workers park on a condvar between jobs — waking
/// them costs microseconds, versus tens of microseconds for a thread
/// spawn/join, which is the entire point (ROADMAP: persistent pool for
/// high request rates with small batches).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Lifetime-erased pointer to the job closure.  Valid only while the
/// posting `run` call is blocked in its completion wait.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
}

// SAFETY: the pointee is `Sync` (shared calls are fine) and the posting
// thread keeps it alive until `pending == 0`, enforced in `run`.
#[allow(unsafe_code)]
unsafe impl Send for Job {}

#[derive(Default)]
struct PoolState {
    job: Option<Job>,
    /// next unclaimed task index
    next: usize,
    /// tasks claimed-or-unclaimed but not yet completed
    pending: usize,
    /// a worker's task panicked during the current job; `run` re-raises
    /// after the drain so a broken kernel fails as loudly as the scoped
    /// path (never silently serving unwritten output)
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// workers park here between jobs
    work_cv: Condvar,
    /// the posting caller parks here while workers finish the tail
    done_cv: Condvar,
}

/// Lock that shrugs off poisoning: every critical section below only
/// moves the counters between consistent states, so a panicked peer
/// cannot leave `PoolState` torn.
fn pool_lock(shared: &PoolShared) -> MutexGuard<'_, PoolState> {
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

fn pool_claim(st: &mut PoolState) -> Option<usize> {
    match &st.job {
        Some(job) if st.next < job.n => {
            let i = st.next;
            st.next += 1;
            Some(i)
        }
        _ => None,
    }
}

/// Decrements `pending` on drop, clearing the job and waking the poster
/// when the last task completes — *even if the task panicked*, so a
/// buggy kernel cannot wedge the pool.
struct FinishGuard<'p> {
    shared: &'p PoolShared,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        let mut st = pool_lock(self.shared);
        st.pending -= 1;
        if st.pending == 0 {
            st.job = None;
            self.shared.done_cv.notify_all();
        }
    }
}

/// Blocks on drop until the current job has fully drained.  Held by
/// `run` so that even a panic unwinding through it cannot free the
/// erased closure (or the output buffer it writes) while a worker is
/// still executing a task.
struct DrainGuard<'p> {
    shared: &'p PoolShared,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        let mut st = pool_lock(self.shared);
        while st.pending > 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

// audited unsafe island: dereferences the lifetime-erased job pointer
// (see the SAFETY comment at the use site)
#[allow(unsafe_code)]
fn pool_worker_loop(shared: &PoolShared) {
    let mut st = pool_lock(shared);
    loop {
        if st.shutdown {
            return;
        }
        if let Some(i) = pool_claim(&mut st) {
            let fptr = st.job.as_ref().unwrap().f;
            drop(st);
            {
                let _fin = FinishGuard { shared };
                // SAFETY: the posting `run` call claims nothing beyond
                // its `DrainGuard`, which keeps the closure and its
                // captures alive until `pending == 0`; we claimed this
                // task before that could happen.
                let f = unsafe { &*fptr };
                // catch task panics so the worker thread survives (the
                // pool must not shrink) and the flag is raised *before*
                // `_fin` drops — the poster observes it no later than
                // the final pending decrement
                if std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f(i)))
                    .is_err()
                {
                    pool_lock(shared).panicked = true;
                }
            }
            st = pool_lock(shared);
        } else {
            st = shared
                .work_cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl WorkerPool {
    /// Pool with `workers` parked threads; `run` adds the caller, so a
    /// pool built for `SimOptions::threads = t` holds `t - 1` workers.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sim-pool-{i}"))
                    .spawn(move || pool_worker_loop(&shared))
                    .expect("spawn simulator pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of parked worker threads (excluding the caller).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Execute `f(0) .. f(n-1)` across the pool plus the calling thread;
    /// returns once every index has completed.  Tasks must be
    /// independent (they run concurrently in arbitrary order).  Takes
    /// `&mut self`: jobs must never overlap (the internal lifetime
    /// erasure depends on it), and the exclusive borrow makes that a
    /// compile-time guarantee rather than a protocol.
    // a plain `as` cast cannot widen the trait object's lifetime bound,
    // so the transmute below is not expressible as a pointer cast
    #[allow(unsafe_code,
            clippy::useless_transmute,
            clippy::transmutes_expressible_as_ptr_casts)]
    pub fn run<F: Fn(usize) + Sync>(&mut self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: lifetime erasure only; the `DrainGuard` below keeps
        // this frame (and therefore `f` and its captures) alive until
        // every worker has finished with the pointer.
        let f_erased: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        {
            let mut st = pool_lock(&self.shared);
            debug_assert!(st.job.is_none(), "pool jobs must not overlap");
            st.job = Some(Job { f: f_erased, n });
            st.next = 0;
            st.pending = n;
            st.panicked = false;
        }
        self.shared.work_cv.notify_all();
        let _drain = DrainGuard { shared: &self.shared };
        let mut st = pool_lock(&self.shared);
        loop {
            if let Some(i) = pool_claim(&mut st) {
                drop(st);
                {
                    let _fin = FinishGuard { shared: &self.shared };
                    f(i);
                }
                st = pool_lock(&self.shared);
            } else if st.pending > 0 {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            } else {
                break;
            }
        }
        let panicked = std::mem::take(&mut st.panicked);
        drop(st);
        if panicked {
            // fail as loudly as the scoped path would: a worker's task
            // panicked, so this job's output cannot be trusted
            panic!("simulator pool worker task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // pool_lock, not .lock(): a poisoned mutex must still deliver
        // the shutdown flag or the joins below would hang forever
        pool_lock(&self.shared).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Evaluate a packed truth table (entry `m` at bit `m`) over 64 samples
/// at once via Shannon expansion: split on the highest input; cofactors
/// are bit-ranges of the packed table.
///
/// The table must fit in the `u64`: at most [`MAX_PLANE_SUPPORT`] (6)
/// inputs.  More inputs would need `table >> 64`, which is not a shift
/// a `u64` can express — enforced unconditionally here (once per call,
/// not per recursion step).
#[inline(always)]
pub fn eval_packed(table: u64, inputs: &[u64]) -> u64 {
    assert!(inputs.len() <= MAX_PLANE_SUPPORT,
            "packed table holds at most 2^6 entries");
    eval_packed_rec(table, inputs)
}

#[inline(always)]
pub(super) fn eval_packed_rec(table: u64, inputs: &[u64]) -> u64 {
    match inputs.len() {
        0 => {
            if table & 1 == 1 { !0u64 } else { 0u64 }
        }
        _ => {
            let x = inputs[inputs.len() - 1];
            let half = 1usize << (inputs.len() - 1);
            let mask = (1u64 << half) - 1;
            let f0 = table & mask;
            let f1 = (table >> half) & mask;
            let lo = eval_packed_rec(f0, &inputs[..inputs.len() - 1]);
            let hi = eval_packed_rec(f1, &inputs[..inputs.len() - 1]);
            (!x & lo) | (x & hi)
        }
    }
}

/// Precomputed bit-plane form of a layer: per (unit, output bit) a
/// support-reduced packed table plus the input-plane indices it reads.
/// Input planes are indexed `producer_signal * in_bits + bit`.
#[derive(Clone, Debug)]
pub struct BitPlaneLayer {
    pub w: usize,
    pub out_bits: usize,
    /// per-plane reduced support size (<= MAX_PLANE_SUPPORT)
    arity: Vec<u8>,
    /// per-plane reduced truth table packed into a u64
    tables: Vec<u64>,
    /// per-plane offset into `srcs`
    src_off: Vec<u32>,
    /// concatenated input-plane indices, plane-major
    srcs: Vec<u32>,
}

impl BitPlaneLayer {
    /// Build if every output bit of every unit has reduced support
    /// <= [`MAX_PLANE_SUPPORT`].  Dead address bits are pruned here, so a
    /// layer with raw `addr_bits > 6` still qualifies when its trained
    /// tables ignore enough inputs; constant output bits become
    /// zero-arity planes.
    pub fn try_build(layer: &LayerSpec) -> Option<BitPlaneLayer> {
        if layer.in_bits * layer.fan_in > MAX_BUILD_ADDR_BITS {
            return None;
        }
        let planes = layer.w * layer.out_bits;
        let mut arity = Vec::with_capacity(planes);
        let mut tables = Vec::with_capacity(planes);
        let mut src_off = Vec::with_capacity(planes);
        let mut srcs = Vec::new();
        for u in 0..layer.w {
            let tt = layer.truth_table(u);
            let conn = layer.unit_conn(u);
            for b in 0..layer.out_bits {
                let support = tt.bit_support(b);
                if support.len() > MAX_PLANE_SUPPORT {
                    return None;
                }
                src_off.push(srcs.len() as u32);
                arity.push(support.len() as u8);
                tables.push(tt.reduced_bit_table(b, &support));
                for &v in &support {
                    let f = v / layer.in_bits;
                    let k = v % layer.in_bits;
                    srcs.push(conn[f] * layer.in_bits as u32 + k as u32);
                }
            }
        }
        Some(BitPlaneLayer {
            w: layer.w,
            out_bits: layer.out_bits,
            arity,
            tables,
            src_off,
            srcs,
        })
    }

    /// Number of output planes (`w * out_bits`).
    pub fn planes(&self) -> usize {
        self.w * self.out_bits
    }

    /// Mean reduced support per plane (introspection).
    pub fn mean_support(&self) -> f64 {
        if self.arity.is_empty() {
            return 0.0;
        }
        self.arity.iter().map(|&a| a as usize).sum::<usize>() as f64
            / self.arity.len() as f64
    }

    /// Evaluate planes of units `[u0, u1)`.  `prev` holds the producer
    /// planes (plane-major, `nwords` words each); `out` covers exactly
    /// this unit range so disjoint ranges can run on separate threads.
    pub fn eval_units(&self, prev: &[u64], nwords: usize,
                      u0: usize, u1: usize, out: &mut [u64]) {
        debug_assert_eq!(out.len(), (u1 - u0) * self.out_bits * nwords);
        let mut ins = [0u64; MAX_PLANE_SUPPORT];
        let p0 = u0 * self.out_bits;
        for p in p0..u1 * self.out_bits {
            let a = self.arity[p] as usize;
            let off = self.src_off[p] as usize;
            let srcs = &self.srcs[off..off + a];
            let table = self.tables[p];
            let dst = &mut out[(p - p0) * nwords..(p - p0 + 1) * nwords];
            for (wd, slot) in dst.iter_mut().enumerate() {
                for (i, &s) in srcs.iter().enumerate() {
                    ins[i] = prev[s as usize * nwords + wd];
                }
                // arity is capped at build time; skip the entry assert
                *slot = eval_packed_rec(table, &ins[..a]);
            }
        }
    }

    /// Evaluate the whole layer single-threaded.
    pub fn eval(&self, prev: &[u64], nwords: usize, out: &mut [u64]) {
        self.eval_units(prev, nwords, 0, self.w, out)
    }
}

enum LayerKernel {
    Gather,
    BitPlane(BitPlaneLayer),
}

/// Pack signal-major codes into bit-planes (64 samples/word):
/// plane `s * bits + k` holds bit `k` of signal `s`.
fn pack_planes(cur: &[u16], w: usize, bits: usize, batch: usize,
               nwords: usize, out: &mut Vec<u64>) {
    out.clear();
    out.resize(w * bits * nwords, 0);
    for s in 0..w {
        let row = &cur[s * batch..(s + 1) * batch];
        for (b, &c) in row.iter().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            for k in 0..bits {
                out[(s * bits + k) * nwords + wd] |=
                    (((c >> k) & 1) as u64) << sh;
            }
        }
    }
}

/// Inverse of [`pack_planes`]: reassemble codes from bit-planes.
fn unpack_planes(planes: &[u64], w: usize, bits: usize, batch: usize,
                 nwords: usize, cur: &mut [u16]) {
    for s in 0..w {
        let row = &mut cur[s * batch..(s + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            let mut c = 0u16;
            for k in 0..bits {
                c |= (((planes[(s * bits + k) * nwords + wd] >> sh) & 1)
                    as u16) << k;
            }
            *slot = c;
        }
    }
}

/// Gather-kernel evaluation of units `[u0, u1)`; `dst` covers exactly
/// that unit range (unit-major, `batch` codes per unit).
fn gather_units(layer: &LayerSpec, cur: &[u16], batch: usize,
                u0: usize, u1: usize, dst: &mut [u16]) {
    debug_assert_eq!(dst.len(), (u1 - u0) * batch);
    let t = layer.entries_per_unit();
    for u in u0..u1 {
        let conn = layer.unit_conn(u);
        let table = &layer.tables[u * t..(u + 1) * t];
        let row = &mut dst[(u - u0) * batch..(u - u0 + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let mut addr = 0usize;
            for (f, &src) in conn.iter().enumerate() {
                addr |= (cur[src as usize * batch + b] as usize)
                    << (layer.in_bits * f);
            }
            *slot = table[addr];
        }
    }
}

/// How many threads to actually use for a layer of `units` units with
/// `work` output words/codes total, given the kernel/mode-specific
/// profitability `floor`: waking a parked pool worker amortizes at much
/// smaller layers than spawning a scoped thread does.
pub(super) fn par_threads(requested: usize, units: usize, work: usize,
                          floor: usize) -> usize {
    if requested <= 1 || units < 2 || work < floor {
        1
    } else {
        requested.min(units)
    }
}

/// Raw-pointer wrapper so disjoint chunk slices of one output buffer can
/// be reconstructed on pool workers.
struct SendPtr<T>(*mut T);

// SAFETY: access is restricted to disjoint index ranges per task, and
// the buffer outlives the pool job (`WorkerPool::run` blocks).
#[allow(unsafe_code)]
unsafe impl<T: Send> Send for SendPtr<T> {}
#[allow(unsafe_code)]
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(u0, u1, dst)` over unit ranges of a layer with `w` units whose
/// output occupies `stride` elements per unit, fanning the disjoint
/// `dst` chunks across up to `threads` workers — the persistent `pool`
/// when one is provided, scoped spawn-per-call threads otherwise
/// (serial when `threads <= 1`).  Chunk boundaries are identical in
/// every mode, and each mode hands each worker exactly one disjoint
/// range, so all three execution paths are bit-exact by construction.
// audited unsafe island: reconstructs disjoint output sub-slices from a
// raw pointer on pool workers (see the SAFETY comment at the use site)
#[allow(unsafe_code)]
pub(super) fn chunked_units<T: Send, F>(out: &mut [T], w: usize,
                                        stride: usize, threads: usize,
                                        pool: Option<&mut WorkerPool>,
                                        f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    debug_assert_eq!(out.len(), w * stride);
    if threads <= 1 {
        f(0, w, out);
        return;
    }
    let chunk = w.div_ceil(threads);
    match pool {
        Some(pool) => {
            let n_chunks = w.div_ceil(chunk);
            let base = SendPtr(out.as_mut_ptr());
            let f = &f;
            pool.run(n_chunks, move |i| {
                let u0 = i * chunk;
                let u1 = (u0 + chunk).min(w);
                // SAFETY: tasks receive disjoint `[u0, u1)` ranges and
                // `out` outlives the blocking `run` call.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        base.0.add(u0 * stride), (u1 - u0) * stride)
                };
                f(u0, u1, dst);
            });
        }
        None => {
            std::thread::scope(|s| {
                for (i, dst) in out.chunks_mut(chunk * stride).enumerate() {
                    let u0 = i * chunk;
                    let u1 = (u0 + chunk).min(w);
                    let f = &f;
                    s.spawn(move || f(u0, u1, dst));
                }
            });
        }
    }
}

/// Reusable-buffer simulator bound to a netlist.
///
/// By default ([`SimOptions::compiled`]) construction lowers the netlist
/// into an [`ExecPlan`] and every hot loop runs the compiled program; the
/// original interpreted walk is kept behind `compiled: false` as the
/// bit-exactness reference.
pub struct Simulator<'a> {
    nl: &'a Netlist,
    opts: SimOptions,
    /// interpreted per-layer kernels (empty when compiled)
    kernels: Vec<LayerKernel>,
    /// compiled execution ([`SimOptions::compiled`], the default) at
    /// the lane width [`SimOptions::lanes`] resolves to
    plan_exec: Option<LaneExecutor>,
    /// persistent workers ([`ThreadMode::Pooled`] with `threads > 1`);
    /// lives inside `plan_exec` when compiled
    pool: Option<WorkerPool>,
    /// scratch: signal-major u16 codes
    buf_a: Vec<u16>,
    buf_b: Vec<u16>,
    /// scratch: packed bit-plane words
    bits_a: Vec<u64>,
    bits_b: Vec<u64>,
}

impl<'a> Simulator<'a> {
    pub fn new(nl: &'a Netlist) -> Simulator<'a> {
        Self::with_options(nl, SimOptions::default())
    }

    /// Build with explicit kernel/threading options (benches use this to
    /// pin the gather baseline; the server plumbs `sim_threads` here).
    pub fn with_options(nl: &'a Netlist, opts: SimOptions) -> Simulator<'a> {
        let (kernels, plan_exec) = if opts.compiled {
            let p = Arc::new(plan::compile(
                nl, PlanOptions { bitplane: opts.bitplane }));
            // no batch hint here: a simulator serves any batch size, so
            // `Auto` resolves straight to the CPU's widest lane
            (Vec::new(), Some(LaneExecutor::select(p, opts, 0)))
        } else {
            let kernels = nl
                .layers
                .iter()
                .map(|l| {
                    if !opts.bitplane {
                        return LayerKernel::Gather;
                    }
                    match BitPlaneLayer::try_build(l) {
                        Some(b) => LayerKernel::BitPlane(b),
                        None => LayerKernel::Gather,
                    }
                })
                .collect();
            (kernels, None)
        };
        // the pool is created lazily on first parallel use (or lent in
        // via `set_pool`), so construction never spawns threads
        Simulator { nl, opts, kernels, plan_exec, pool: None,
                    buf_a: Vec::new(), buf_b: Vec::new(),
                    bits_a: Vec::new(), bits_b: Vec::new() }
    }

    /// The pool this simulator should hold for its current options, or
    /// 0 workers when serial or scoped.
    fn wanted_pool_workers(&self) -> usize {
        match self.opts.mode {
            ThreadMode::Pooled if self.opts.threads > 1 => {
                self.opts.threads - 1
            }
            _ => 0,
        }
    }

    /// Create the persistent pool on first parallel use if pooled mode
    /// wants one and none is resident (and none was lent in).
    fn ensure_pool(&mut self) {
        if self.pool.is_none() {
            let want = self.wanted_pool_workers();
            if want > 0 {
                self.pool = Some(WorkerPool::new(want));
            }
        }
    }

    /// Change the worker-thread count after construction.  A resident
    /// pool of the wrong size is dropped and lazily recreated on next
    /// use.
    pub fn set_threads(&mut self, threads: usize) {
        self.opts.threads = threads.max(1);
        if let Some(pe) = &mut self.plan_exec {
            pe.set_threads(threads);
            return;
        }
        let want = self.wanted_pool_workers();
        let have = self.pool.as_ref().map(|p| p.workers()).unwrap_or(0);
        if self.pool.is_some() && want != have {
            self.pool = None;
        }
    }

    /// Lend a pool in (or take the resident one out), returning the
    /// previous one.  Lets one thread share a single `WorkerPool`
    /// across several simulators it drives one-at-a-time — the server's
    /// workers do this per batch, so parked threads scale with workers,
    /// not workers × models.  A lent pool is used as-is regardless of
    /// size; `None` restores lazy self-creation.
    pub fn set_pool(&mut self, pool: Option<WorkerPool>)
                    -> Option<WorkerPool> {
        if let Some(pe) = &mut self.plan_exec {
            return pe.set_pool(pool);
        }
        std::mem::replace(&mut self.pool, pool)
    }

    /// The netlist this simulator is bound to.
    pub fn netlist(&self) -> &'a Netlist {
        self.nl
    }

    /// The options this simulator was built with.
    pub fn options(&self) -> SimOptions {
        self.opts
    }

    /// The compiled plan, when this simulator executes one
    /// ([`SimOptions::compiled`]).
    pub fn plan(&self) -> Option<&Arc<ExecPlan>> {
        self.plan_exec.as_ref().map(|pe| pe.plan())
    }

    /// Lane width of the compiled executor (`None` when interpreted):
    /// how many packed 64-sample words each bit-plane table evaluation
    /// processes at once.
    pub fn lane_width(&self) -> Option<usize> {
        self.plan_exec.as_ref().map(|pe| pe.width())
    }

    /// Per-layer kernel choice (introspection for benches/logs).
    pub fn layer_kernels(&self) -> Vec<KernelChoice> {
        if let Some(pe) = &self.plan_exec {
            return pe.plan().layer_kernels();
        }
        self.kernels
            .iter()
            .map(|k| match k {
                LayerKernel::Gather => KernelChoice::Gather,
                LayerKernel::BitPlane(_) => KernelChoice::BitPlane,
            })
            .collect()
    }

    /// How many layers compiled to the bit-plane kernel.
    pub fn bitplane_layers(&self) -> usize {
        if let Some(pe) = &self.plan_exec {
            return pe.plan().bitplane_layers();
        }
        self.kernels
            .iter()
            .filter(|k| matches!(k, LayerKernel::BitPlane(_)))
            .count()
    }

    /// Legacy name for [`Simulator::bitplane_layers`] (the v1 kernel only
    /// handled boolean layers and was called "bitsliced").
    pub fn bitsliced_layers(&self) -> usize {
        self.bitplane_layers()
    }

    /// Row-major input codes -> row-major output codes.
    ///
    /// Representation-aware execution (EXPERIMENTS.md §Hot path): signals
    /// stay *packed* (one plane per signal bit, 64 samples/word) across
    /// consecutive bit-plane layers — including multi-bit ones — and are
    /// only materialized as codes at gather-layer boundaries.  Small
    /// batches skip the packed machinery entirely (word packing doesn't
    /// amortize).  With `opts.threads > 1`, each sufficiently large layer
    /// is chunked over unit ranges onto scoped threads.
    pub fn eval_batch(&mut self, x: &[i32], batch: usize) -> Vec<i32> {
        assert_eq!(x.len(), batch * self.nl.n_in);
        // empty batch: nothing to transpose or pack, and no pool to
        // create or wake
        if batch == 0 {
            return Vec::new();
        }
        if let Some(pe) = &mut self.plan_exec {
            return pe.eval_batch(x, batch);
        }
        self.ensure_pool();
        let use_bits = self.opts.bitplane
            && batch >= self.opts.min_bitplane_batch;
        let max_w = self
            .nl
            .layers
            .iter()
            .map(|l| l.w)
            .max()
            .unwrap_or(0)
            .max(self.nl.n_in);
        self.buf_a.resize(max_w * batch, 0);
        self.buf_b.resize(max_w * batch, 0);
        // transpose input to signal-major
        for s in 0..self.nl.n_in {
            for b in 0..batch {
                self.buf_a[s * batch + b] = x[b * self.nl.n_in + s] as u16;
            }
        }
        let nwords = batch.div_ceil(64);
        // own the ping-pong buffers locally to keep borrows disjoint
        let mut cur = std::mem::take(&mut self.buf_a);
        let mut next = std::mem::take(&mut self.buf_b);
        let mut bits_cur = std::mem::take(&mut self.bits_a);
        let mut bits_next = std::mem::take(&mut self.bits_b);
        let mut packed = false; // is the live value in bits_cur?
        for (l, layer) in self.nl.layers.iter().enumerate() {
            let prev_w =
                if l == 0 { self.nl.n_in } else { self.nl.layers[l - 1].w };
            match &self.kernels[l] {
                LayerKernel::BitPlane(bl) if use_bits => {
                    if !packed {
                        pack_planes(&cur, prev_w, layer.in_bits, batch,
                                    nwords, &mut bits_cur);
                        packed = true;
                    }
                    bits_next.clear();
                    bits_next.resize(bl.planes() * nwords, 0);
                    let floor = if self.pool.is_some() {
                        PAR_MIN_WORK_POOLED
                    } else {
                        PAR_MIN_WORK
                    };
                    let t = par_threads(self.opts.threads, bl.w,
                                        bl.planes() * nwords, floor);
                    let prev: &[u64] = &bits_cur;
                    chunked_units(
                        &mut bits_next[..bl.planes() * nwords], bl.w,
                        bl.out_bits * nwords, t, self.pool.as_mut(),
                        |u0, u1, dst| bl.eval_units(prev, nwords, u0, u1, dst),
                    );
                    std::mem::swap(&mut bits_cur, &mut bits_next);
                }
                _ => {
                    if packed {
                        unpack_planes(&bits_cur, prev_w, layer.in_bits,
                                      batch, nwords, &mut cur);
                        packed = false;
                    }
                    let floor = if self.pool.is_some() {
                        PAR_MIN_WORK_POOLED_GATHER
                    } else {
                        PAR_MIN_WORK
                    };
                    let t = par_threads(self.opts.threads, layer.w,
                                        layer.w * batch, floor);
                    let prev: &[u16] = &cur;
                    chunked_units(
                        &mut next[..layer.w * batch], layer.w, batch, t,
                        self.pool.as_mut(),
                        |u0, u1, dst| gather_units(layer, prev, batch, u0, u1,
                                                   dst),
                    );
                    std::mem::swap(&mut cur, &mut next);
                }
            }
        }
        let ow = self.nl.out_width();
        if packed {
            unpack_planes(&bits_cur, ow, self.nl.out_bits(), batch, nwords,
                          &mut cur);
        }
        // transpose back to row-major
        let mut out = vec![0i32; batch * ow];
        for u in 0..ow {
            for b in 0..batch {
                out[b * ow + u] = cur[u * batch + b] as i32;
            }
        }
        self.buf_a = cur;
        self.buf_b = next;
        self.bits_a = bits_cur;
        self.bits_b = bits_next;
        out
    }

    /// Single-sample evaluation: the compiled plan's transpose-free
    /// gather program when this simulator carries one, the reference
    /// object walk otherwise.
    pub fn eval_one(&mut self, x: &[i32]) -> Vec<i32> {
        assert_eq!(x.len(), self.nl.n_in);
        match &mut self.plan_exec {
            Some(pe) => pe.eval_one(x),
            None => self
                .nl
                .eval_one(x)
                .expect("input width checked above"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    fn assert_matches_eval_one(nl: &Netlist, sim: &mut Simulator,
                               seed: u64, batch: usize) {
        let x = random_inputs(seed, nl, batch);
        let got = sim.eval_batch(&x, batch);
        let ow = nl.out_width();
        for b in 0..batch {
            let one =
                nl.eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..], "row {b}");
        }
    }

    #[test]
    fn eval_packed_matches_table() {
        // exhaustive over all 2^(2^3) 3-input functions is large; sample
        for seed in 0..32u64 {
            let table = seed.wrapping_mul(0x9E3779B97F4A7C15);
            let masked = table & ((1u64 << 8) - 1);
            for v in 0..8usize {
                let ins: Vec<u64> = (0..3)
                    .map(|f| if (v >> f) & 1 == 1 { !0u64 } else { 0 })
                    .collect();
                let got = eval_packed(masked, &ins) & 1;
                let want = (masked >> v) & 1;
                assert_eq!(got, want, "table {masked:08b} v {v}");
            }
        }
    }

    #[test]
    fn boolean_netlist_all_bitplane() {
        let nl = random_netlist(11, 32, 1, &[(16, 6, 1), (8, 2, 1), (4, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_eq!(sim.bitsliced_layers(), 3); // legacy alias
        // batch not a multiple of 64: exercises tail handling
        assert_matches_eval_one(&nl, &mut sim, 11, 200);
    }

    #[test]
    fn mixed_width_netlist_uses_bitplane() {
        // multi-bit signals, raw addr width 4 <= 6: every layer packs
        let nl = random_netlist(13, 16, 2, &[(8, 2, 2), (4, 2, 1), (2, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_eq!(sim.layer_kernels(),
                   vec![KernelChoice::BitPlane; 3]);
        assert_matches_eval_one(&nl, &mut sim, 13, 65);
    }

    #[test]
    fn wide_address_layer_qualifies_after_support_reduction() {
        // raw addr width 4*2 = 8 > 6, but true support <= 6 per plane
        let nl = random_reducible_netlist(
            19, 12, 2, &[(8, 4, 2), (4, 4, 2), (2, 2, 2)], 6);
        assert!(nl.layers[0].in_bits * nl.layers[0].fan_in > 6);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_matches_eval_one(&nl, &mut sim, 19, 130);
    }

    #[test]
    fn full_support_wide_layer_falls_back_to_gather() {
        // random dense tables on 8 address bits: support reduction finds
        // nothing, so the layer must stay on the gather kernel
        let nl = random_netlist(23, 16, 4, &[(8, 2, 4), (4, 2, 4)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 0);
        assert_matches_eval_one(&nl, &mut sim, 23, 70);
    }

    #[test]
    fn constant_output_bits_evaluate_correctly() {
        // force a constant plane: all table entries share output bit 1
        let mut nl = random_netlist(29, 8, 1, &[(4, 2, 2), (2, 2, 2)]);
        for e in nl.layers[0].tables.iter_mut() {
            *e |= 0b10;
        }
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 2);
        assert_matches_eval_one(&nl, &mut sim, 29, 100);
    }

    #[test]
    fn gather_only_option_matches() {
        let nl = random_netlist(17, 16, 2, &[(8, 2, 2), (4, 2, 2)]);
        let mut sim = Simulator::with_options(
            &nl, SimOptions { bitplane: false, ..Default::default() });
        assert_eq!(sim.bitplane_layers(), 0);
        assert_matches_eval_one(&nl, &mut sim, 17, 96);
    }

    #[test]
    fn threaded_eval_matches_serial() {
        let nl = random_reducible_netlist(
            37, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        let mut sim = Simulator::new(&nl);
        sim.set_threads(4);
        // batch large enough that the work floors let the big layers fan
        // out, and not a multiple of 64 (tail words in every plane)
        assert_matches_eval_one(&nl, &mut sim, 37, 2100);
    }

    #[test]
    fn pooled_and_scoped_threads_are_bit_exact() {
        let nl = random_reducible_netlist(
            43, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        let mut scoped = Simulator::with_options(
            &nl,
            SimOptions { threads: 4, mode: ThreadMode::Scoped,
                         ..Default::default() },
        );
        let mut pooled = Simulator::with_options(
            &nl,
            SimOptions { threads: 4, mode: ThreadMode::Pooled,
                         ..Default::default() },
        );
        // small batches stay serial, large ones fan out; every size must
        // agree across modes (and with eval_one via the scoped suite)
        for (seed, batch) in [(1u64, 33usize), (2, 600), (3, 2100)] {
            let x = random_inputs(seed, &nl, batch);
            assert_eq!(scoped.eval_batch(&x, batch),
                       pooled.eval_batch(&x, batch), "batch {batch}");
        }
        assert_matches_eval_one(&nl, &mut pooled, 9, 130);
    }

    #[test]
    fn worker_pool_runs_every_task_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        for n in [0usize, 1, 2, 7, 64] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits
                .iter()
                .all(|h| h.load(Ordering::Relaxed) == 1), "n = {n}");
        }
        // rapid job reuse: workers park and wake cleanly between jobs
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(5, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn worker_pool_propagates_task_panics_and_survives() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(8, |i| {
                    if i == 3 {
                        panic!("injected task panic");
                    }
                });
            }));
        assert!(res.is_err(), "a task panic must propagate from run()");
        // the pool must remain fully functional: no dead workers, no
        // stale job state, no sticky panic flag
        let total = AtomicUsize::new(0);
        pool.run(16, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn set_threads_resizes_pool() {
        let nl = random_netlist(17, 8, 1, &[(4, 3, 2), (2, 2, 3)]);
        let mut sim = nl.simulator();
        assert_matches_eval_one(&nl, &mut sim, 1, 64);
        sim.set_threads(4);
        assert_matches_eval_one(&nl, &mut sim, 2, 300);
        sim.set_threads(1);
        assert_matches_eval_one(&nl, &mut sim, 3, 100);
        assert_eq!(sim.options().threads, 1);
    }

    #[test]
    fn empty_batch_early_returns() {
        let nl = random_netlist(59, 8, 1, &[(4, 3, 2), (2, 2, 3)]);
        // every execution mode must return an empty batch without
        // packing planes or waking (or even creating) a worker pool
        for opts in [
            SimOptions::default(),
            SimOptions { compiled: false, ..Default::default() },
            SimOptions { threads: 4, ..Default::default() },
            SimOptions { threads: 4, mode: ThreadMode::Scoped,
                         compiled: false, ..Default::default() },
        ] {
            let mut sim = nl.simulator_with(opts);
            assert!(sim.eval_batch(&[], 0).is_empty());
            // and a normal batch still works afterwards
            assert_matches_eval_one(&nl, &mut sim, 59, 7);
        }
        assert!(nl.eval_batch(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn interpreted_walk_still_bit_exact() {
        // `compiled: false` keeps the original object-graph walk as the
        // reference; it must keep passing the same suite as the plan
        let nl = random_reducible_netlist(
            47, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        for opts in [
            SimOptions { compiled: false, ..Default::default() },
            SimOptions { compiled: false, bitplane: false,
                         ..Default::default() },
            SimOptions { compiled: false, threads: 4,
                         ..Default::default() },
            SimOptions { compiled: false, threads: 4,
                         mode: ThreadMode::Scoped, ..Default::default() },
        ] {
            let mut sim = nl.simulator_with(opts);
            for (seed, batch) in [(1u64, 1usize), (2, 33), (3, 2100)] {
                assert_matches_eval_one(&nl, &mut sim, seed, batch);
            }
        }
    }

    #[test]
    fn pinned_lane_widths_are_bit_exact() {
        let nl = random_reducible_netlist(
            49, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
        let mut w1 = nl.simulator_with(
            SimOptions { lanes: LaneSelect::W1, ..Default::default() });
        assert_eq!(w1.lane_width(), Some(1));
        for lanes in [LaneSelect::W4, LaneSelect::W8, LaneSelect::Auto] {
            let mut wide = nl.simulator_with(
                SimOptions { lanes, ..Default::default() });
            let w = wide.lane_width().unwrap();
            assert_eq!(lanes.fixed_width().unwrap_or(w), w);
            // ragged batches: full lanes plus scalar tail words
            for (seed, batch) in
                [(1u64, 1usize), (2, 63), (3, 257), (4, 64 * 8 * 3 + 5)]
            {
                let x = random_inputs(seed, &nl, batch);
                assert_eq!(w1.eval_batch(&x, batch),
                           wide.eval_batch(&x, batch),
                           "lanes {lanes} batch {batch}");
            }
        }
        // the interpreted walk never carries a lane width
        let interp = nl.simulator_with(
            SimOptions { compiled: false, ..Default::default() });
        assert_eq!(interp.lane_width(), None);
    }

    #[test]
    fn lane_select_parses_and_displays() {
        for (s, want) in [("auto", LaneSelect::Auto), ("1", LaneSelect::W1),
                          ("4", LaneSelect::W4), ("8", LaneSelect::W8)] {
            let got: LaneSelect = s.parse().unwrap();
            assert_eq!(got, want);
            assert_eq!(got.to_string(), s);
        }
        assert!("2".parse::<LaneSelect>().is_err());
        assert!("wide".parse::<LaneSelect>().is_err());
        assert_eq!(LaneSelect::default(), LaneSelect::Auto);
        assert_eq!(LaneSelect::Auto.fixed_width(), None);
        assert_eq!(LaneSelect::W8.fixed_width(), Some(8));
    }

    #[test]
    fn compiled_and_interpreted_agree_exactly() {
        let nl = random_reducible_netlist(
            49, 20, 2, &[(48, 3, 2), (32, 2, 2), (8, 2, 2)], 6);
        let mut compiled = nl.simulator();
        let mut interp = nl.simulator_with(SimOptions {
            compiled: false, ..Default::default()
        });
        assert!(compiled.plan().is_some());
        assert!(interp.plan().is_none());
        assert_eq!(compiled.layer_kernels(), interp.layer_kernels());
        for (seed, batch) in [(1u64, 1usize), (2, 17), (3, 64), (4, 321)] {
            let x = random_inputs(seed, &nl, batch);
            assert_eq!(compiled.eval_batch(&x, batch),
                       interp.eval_batch(&x, batch), "batch {batch}");
        }
        let x = random_inputs(5, &nl, 1);
        assert_eq!(compiled.eval_one(&x), interp.eval_one(&x));
    }

    #[test]
    fn simulator_reuse_across_batches() {
        let nl = random_netlist(17, 8, 1, &[(4, 3, 2), (2, 2, 3)]);
        let mut sim = nl.simulator();
        for (seed, batch) in [(1u64, 5usize), (2, 64), (3, 129)] {
            assert_matches_eval_one(&nl, &mut sim, seed, batch);
        }
    }

    #[test]
    fn bitplane_layer_direct_eval() {
        // drive BitPlaneLayer::eval directly on a packed input
        let nl = random_netlist(41, 6, 2, &[(3, 2, 2)]);
        let bl = BitPlaneLayer::try_build(&nl.layers[0]).unwrap();
        assert_eq!(bl.planes(), 6);
        assert!(bl.mean_support() <= 4.0 + 1e-9);
        let batch = 64;
        let x = random_inputs(41, &nl, batch);
        // pack input codes into planes by hand
        let nwords = 1;
        let mut planes = vec![0u64; 6 * 2 * nwords];
        for s in 0..6 {
            for b in 0..batch {
                let c = x[b * 6 + s] as u64;
                for k in 0..2 {
                    planes[(s * 2 + k) * nwords] |= ((c >> k) & 1) << b;
                }
            }
        }
        let mut out = vec![0u64; bl.planes() * nwords];
        bl.eval(&planes, nwords, &mut out);
        for b in 0..batch {
            let one = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
            for u in 0..3 {
                let mut c = 0i32;
                for k in 0..2 {
                    c |= (((out[(u * 2 + k) * nwords] >> b) & 1) as i32) << k;
                }
                assert_eq!(c, one[u], "unit {u} row {b}");
            }
        }
    }
}
