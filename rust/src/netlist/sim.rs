//! Batched netlist simulation — the L3 request-path hot loop.
//!
//! Two execution strategies per layer:
//!
//! * **gather**: signal-major scratch buffers (`prev[signal][batch]`), one
//!   table read per (unit, sample) with the address assembled from the
//!   unit's producers.  Works for any layer.
//! * **bitsliced**: for pure-boolean layers (`in_bits == out_bits == 1`,
//!   `fan_in <= 6`) each signal is packed 64 samples/word and every unit's
//!   truth table is evaluated with a Shannon mux-tree over whole words —
//!   ~64 samples per table evaluation.  This is the FPGA-netlist analogue
//!   of SIMD bit-parallel simulation and the main §Perf optimization.

use super::{LayerSpec, Netlist};

/// Precomputed bitsliced form of a boolean layer.
#[derive(Clone, Debug)]
pub struct BitslicedLayer {
    pub w: usize,
    pub fan_in: usize,
    /// per-unit producer indices
    conn: Vec<u32>,
    /// per-unit truth table packed into a u64 (addr bit -> table bit)
    packed: Vec<u64>,
}

impl BitslicedLayer {
    /// Build if the layer qualifies (boolean signals, fan_in <= 6).
    pub fn try_build(layer: &LayerSpec) -> Option<BitslicedLayer> {
        if layer.in_bits != 1 || layer.out_bits != 1 || layer.fan_in > 6 {
            return None;
        }
        let packed = (0..layer.w)
            .map(|u| {
                let t = layer.unit_table(u);
                t.iter()
                    .enumerate()
                    .fold(0u64, |acc, (addr, &e)| acc | ((e as u64 & 1) << addr))
            })
            .collect();
        Some(BitslicedLayer {
            w: layer.w,
            fan_in: layer.fan_in,
            conn: layer.conn.clone(),
            packed,
        })
    }

    /// Evaluate one unit's truth table over 64 samples at once via a
    /// Shannon expansion on the packed table.
    #[inline(always)]
    fn eval_unit(table: u64, inputs: &[u64]) -> u64 {
        // mux tree: split on the highest input; cofactors are bit-ranges
        // of the packed table.  Iterative form: start with 2^F table
        // "lanes" of 1 bit and combine.
        match inputs.len() {
            0 => {
                if table & 1 == 1 { !0u64 } else { 0u64 }
            }
            _ => {
                let x = inputs[inputs.len() - 1];
                let half = 1usize << (inputs.len() - 1);
                let mask = if half >= 64 { !0u64 } else { (1u64 << half) - 1 };
                let f0 = table & mask;
                let f1 = (table >> half) & mask;
                let lo = Self::eval_unit(f0, &inputs[..inputs.len() - 1]);
                let hi = Self::eval_unit(f1, &inputs[..inputs.len() - 1]);
                (!x & lo) | (x & hi)
            }
        }
    }

    /// prev: signal-major packed words `[signal][word]`; out likewise.
    pub fn eval(&self, prev: &[u64], nwords: usize, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.w * nwords);
        let mut ins = [0u64; 6];
        for u in 0..self.w {
            let conn = &self.conn[u * self.fan_in..(u + 1) * self.fan_in];
            let table = self.packed[u];
            for wd in 0..nwords {
                for (f, &src) in conn.iter().enumerate() {
                    ins[f] = prev[src as usize * nwords + wd];
                }
                out[u * nwords + wd] =
                    Self::eval_unit(table, &ins[..self.fan_in]);
            }
        }
    }
}

enum LayerKernel {
    Gather,
    Bitsliced(BitslicedLayer),
}

/// Reusable-buffer simulator bound to a netlist.
pub struct Simulator<'a> {
    nl: &'a Netlist,
    kernels: Vec<LayerKernel>,
    /// scratch: signal-major u16 codes
    buf_a: Vec<u16>,
    buf_b: Vec<u16>,
    /// scratch: packed boolean words
    bits_a: Vec<u64>,
    bits_b: Vec<u64>,
}

impl<'a> Simulator<'a> {
    pub fn new(nl: &'a Netlist) -> Simulator<'a> {
        let kernels = nl
            .layers
            .iter()
            .map(|l| match BitslicedLayer::try_build(l) {
                Some(b) => LayerKernel::Bitsliced(b),
                None => LayerKernel::Gather,
            })
            .collect();
        Simulator { nl, kernels, buf_a: Vec::new(), buf_b: Vec::new(),
                    bits_a: Vec::new(), bits_b: Vec::new() }
    }

    /// How many layers run the bitsliced kernel (introspection for benches).
    pub fn bitsliced_layers(&self) -> usize {
        self.kernels
            .iter()
            .filter(|k| matches!(k, LayerKernel::Bitsliced(_)))
            .count()
    }

    /// Row-major input codes -> row-major output codes.
    ///
    /// Representation-aware execution (§Perf, EXPERIMENTS.md): signals stay
    /// *packed* (64 samples/word) across consecutive bitsliced layers and
    /// are only materialized as codes at gather-layer boundaries.  The
    /// first version of this function re-packed/unpacked at every layer
    /// and was slower than the naive per-sample loop; this one is ~10x
    /// faster on boolean-dominated netlists.  Small batches skip the
    /// bitsliced machinery entirely (word packing doesn't amortize).
    pub fn eval_batch(&mut self, x: &[i32], batch: usize) -> Vec<i32> {
        assert_eq!(x.len(), batch * self.nl.n_in);
        let use_bits = batch >= 32;
        let max_w = self
            .nl
            .layers
            .iter()
            .map(|l| l.w)
            .max()
            .unwrap_or(0)
            .max(self.nl.n_in);
        self.buf_a.resize(max_w * batch, 0);
        self.buf_b.resize(max_w * batch, 0);
        // transpose input to signal-major
        for s in 0..self.nl.n_in {
            for b in 0..batch {
                self.buf_a[s * batch + b] = x[b * self.nl.n_in + s] as u16;
            }
        }
        let nwords = (batch + 63) / 64;
        // own the ping-pong buffers locally to keep borrows disjoint
        let mut cur = std::mem::take(&mut self.buf_a);
        let mut next = std::mem::take(&mut self.buf_b);
        let mut bits_cur = std::mem::take(&mut self.bits_a);
        let mut bits_next = std::mem::take(&mut self.bits_b);
        let mut packed = false; // is the live value in bits_cur?
        for (l, layer) in self.nl.layers.iter().enumerate() {
            let prev_w = if l == 0 { self.nl.n_in } else { self.nl.layers[l - 1].w };
            match &self.kernels[l] {
                LayerKernel::Bitsliced(bl) if use_bits => {
                    if !packed {
                        // pack codes (0/1) into words once per boolean run
                        bits_cur.clear();
                        bits_cur.resize(prev_w * nwords, 0);
                        for s in 0..prev_w {
                            let row = &cur[s * batch..(s + 1) * batch];
                            let dst = &mut bits_cur[s * nwords..(s + 1) * nwords];
                            for (b, &c) in row.iter().enumerate() {
                                dst[b / 64] |= ((c & 1) as u64) << (b % 64);
                            }
                        }
                        packed = true;
                    }
                    bits_next.clear();
                    bits_next.resize(bl.w * nwords, 0);
                    bl.eval(&bits_cur, nwords, &mut bits_next);
                    std::mem::swap(&mut bits_cur, &mut bits_next);
                }
                _ => {
                    if packed {
                        // unpack the boolean run's output back to codes
                        for s in 0..prev_w {
                            let src = &bits_cur[s * nwords..(s + 1) * nwords];
                            let row = &mut cur[s * batch..(s + 1) * batch];
                            for (b, slot) in row.iter_mut().enumerate() {
                                *slot = ((src[b / 64] >> (b % 64)) & 1) as u16;
                            }
                        }
                        packed = false;
                    }
                    let t = layer.entries_per_unit();
                    for u in 0..layer.w {
                        let conn = layer.unit_conn(u);
                        let table = &layer.tables[u * t..(u + 1) * t];
                        let dst = &mut next[u * batch..(u + 1) * batch];
                        for b in 0..batch {
                            let mut addr = 0usize;
                            for (f, &src) in conn.iter().enumerate() {
                                addr |= (cur[src as usize * batch + b] as usize)
                                    << (layer.in_bits * f);
                            }
                            dst[b] = table[addr];
                        }
                    }
                    std::mem::swap(&mut cur, &mut next);
                }
            }
        }
        let ow = self.nl.out_width();
        if packed {
            for s in 0..ow {
                let src = &bits_cur[s * nwords..(s + 1) * nwords];
                let row = &mut cur[s * batch..(s + 1) * batch];
                for (b, slot) in row.iter_mut().enumerate() {
                    *slot = ((src[b / 64] >> (b % 64)) & 1) as u16;
                }
            }
        }
        // transpose back to row-major
        let mut out = vec![0i32; batch * ow];
        for u in 0..ow {
            for b in 0..batch {
                out[b * ow + u] = cur[u * batch + b] as i32;
            }
        }
        self.buf_a = cur;
        self.buf_b = next;
        self.bits_a = bits_cur;
        self.bits_b = bits_next;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn bitsliced_eval_unit_matches_table() {
        // exhaustive over all 2^(2^3) 3-input functions is large; sample
        for seed in 0..32u64 {
            let table = seed.wrapping_mul(0x9E3779B97F4A7C15);
            let masked = table & ((1u64 << 8) - 1);
            for v in 0..8usize {
                let ins: Vec<u64> = (0..3)
                    .map(|f| if (v >> f) & 1 == 1 { !0u64 } else { 0 })
                    .collect();
                let got = BitslicedLayer::eval_unit(masked, &ins) & 1;
                let want = (masked >> v) & 1;
                assert_eq!(got, want, "table {masked:08b} v {v}");
            }
        }
    }

    #[test]
    fn bitsliced_layer_matches_gather() {
        // boolean netlist: bitsliced path must agree with eval_one
        let nl = random_netlist(11, 32, 1, &[(16, 6, 1), (8, 2, 1), (4, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitsliced_layers(), 3);
        let batch = 200; // not a multiple of 64: exercises tail handling
        let x = random_inputs(11, &nl, batch);
        let got = sim.eval_batch(&x, batch);
        let ow = nl.out_width();
        for b in 0..batch {
            let one = nl.eval_one(&x[b * 32..(b + 1) * 32]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..], "row {b}");
        }
    }

    #[test]
    fn mixed_width_netlist_uses_gather() {
        let nl = random_netlist(13, 16, 2, &[(8, 2, 2), (4, 2, 1), (2, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        // first two layers have multi-bit signals -> gather; last is boolean
        // but fed by 1-bit outputs so it can bitslice
        assert!(sim.bitsliced_layers() >= 1);
        let x = random_inputs(13, &nl, 65);
        let got = sim.eval_batch(&x, 65);
        for b in 0..65 {
            let one = nl.eval_one(&x[b * 16..(b + 1) * 16]).unwrap();
            let ow = nl.out_width();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..]);
        }
    }

    #[test]
    fn simulator_reuse_across_batches() {
        let nl = random_netlist(17, 8, 1, &[(4, 3, 2), (2, 2, 3)]);
        let mut sim = nl.simulator();
        for (seed, batch) in [(1u64, 5usize), (2, 64), (3, 129)] {
            let x = random_inputs(seed, &nl, batch);
            let got = sim.eval_batch(&x, batch);
            let ow = nl.out_width();
            for b in 0..batch {
                let one = nl.eval_one(&x[b * 8..(b + 1) * 8]).unwrap();
                assert_eq!(&got[b * ow..(b + 1) * ow], &one[..]);
            }
        }
    }
}
