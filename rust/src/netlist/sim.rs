//! Batched netlist simulation — the L3 request-path hot loop.
//!
//! Two execution strategies per layer:
//!
//! * **gather**: signal-major scratch buffers (`prev[signal][batch]`), one
//!   table read per (unit, sample) with the address assembled from the
//!   unit's producers.  Works for any layer.
//! * **bit-plane**: the layer is decomposed into one boolean function per
//!   (unit, output bit) — a *plane*.  Each plane's true support is found
//!   with `TruthTable::bit_support` and the table is projected onto it
//!   (`TruthTable::reduced_bit_table`), so a plane qualifies whenever its
//!   *reduced* support fits in [`MAX_PLANE_SUPPORT`] address bits even if
//!   the raw address width is larger.  Signals are kept packed 64
//!   samples/word and every plane is evaluated with a Shannon mux-tree
//!   over whole words — ~64 samples per table evaluation.  Pure-boolean
//!   layers (the original "bitsliced" kernel) are the β=1 special case;
//!   see DESIGN.md §Netlist simulator.
//!
//! The packed representation survives across consecutive bit-plane layers
//! (no unpack at multi-bit boundaries — that is what v2 adds over the
//! boolean-only bitsliced kernel), and evaluation can be chunked across
//! worker threads per layer ([`SimOptions::threads`], plumbed from
//! `ServerConfig::sim_threads` on the serving path).

use super::{LayerSpec, Netlist};

/// Widest reduced support a plane may have and still use the packed
/// kernel: the reduced table must fit in a `u64` (2^6 entries).  This is
/// also the physical LUT input width of the target fabric, so trained
/// tables that map to single P-LUTs always qualify.
pub const MAX_PLANE_SUPPORT: usize = 6;

/// Raw address widths past this are never worth the support scan.
const MAX_BUILD_ADDR_BITS: usize = 16;

/// Below this many output words per layer, spawning threads costs more
/// than it saves and the layer runs single-threaded.
const PAR_MIN_WORK: usize = 1 << 12;

/// Which kernel a layer was compiled to (introspection for benches and
/// the server's startup log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    Gather,
    BitPlane,
}

/// Simulator construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Compile qualifying layers to the bit-plane kernel (default true;
    /// disable to measure the gather baseline).
    pub bitplane: bool,
    /// Worker threads per `eval_batch` call (1 = single-threaded).
    /// Layers are chunked over unit ranges with scoped threads, spawned
    /// per layer per call; `PAR_MIN_WORK` keeps small layers serial so
    /// spawn cost cannot dominate.  A persistent pool is future work
    /// (ROADMAP) for very high request rates with small batches.
    pub threads: usize,
    /// Smallest batch for which word packing amortizes; below it the
    /// gather path runs even on bit-plane layers.
    pub min_bitplane_batch: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { bitplane: true, threads: 1, min_bitplane_batch: 32 }
    }
}

/// Evaluate a packed truth table (entry `m` at bit `m`) over 64 samples
/// at once via Shannon expansion: split on the highest input; cofactors
/// are bit-ranges of the packed table.
///
/// The table must fit in the `u64`: at most [`MAX_PLANE_SUPPORT`] (6)
/// inputs.  More inputs would need `table >> 64`, which is not a shift
/// a `u64` can express — enforced unconditionally here (once per call,
/// not per recursion step).
#[inline(always)]
pub fn eval_packed(table: u64, inputs: &[u64]) -> u64 {
    assert!(inputs.len() <= MAX_PLANE_SUPPORT,
            "packed table holds at most 2^6 entries");
    eval_packed_rec(table, inputs)
}

#[inline(always)]
fn eval_packed_rec(table: u64, inputs: &[u64]) -> u64 {
    match inputs.len() {
        0 => {
            if table & 1 == 1 { !0u64 } else { 0u64 }
        }
        _ => {
            let x = inputs[inputs.len() - 1];
            let half = 1usize << (inputs.len() - 1);
            let mask = (1u64 << half) - 1;
            let f0 = table & mask;
            let f1 = (table >> half) & mask;
            let lo = eval_packed_rec(f0, &inputs[..inputs.len() - 1]);
            let hi = eval_packed_rec(f1, &inputs[..inputs.len() - 1]);
            (!x & lo) | (x & hi)
        }
    }
}

/// Precomputed bit-plane form of a layer: per (unit, output bit) a
/// support-reduced packed table plus the input-plane indices it reads.
/// Input planes are indexed `producer_signal * in_bits + bit`.
#[derive(Clone, Debug)]
pub struct BitPlaneLayer {
    pub w: usize,
    pub out_bits: usize,
    /// per-plane reduced support size (<= MAX_PLANE_SUPPORT)
    arity: Vec<u8>,
    /// per-plane reduced truth table packed into a u64
    tables: Vec<u64>,
    /// per-plane offset into `srcs`
    src_off: Vec<u32>,
    /// concatenated input-plane indices, plane-major
    srcs: Vec<u32>,
}

impl BitPlaneLayer {
    /// Build if every output bit of every unit has reduced support
    /// <= [`MAX_PLANE_SUPPORT`].  Dead address bits are pruned here, so a
    /// layer with raw `addr_bits > 6` still qualifies when its trained
    /// tables ignore enough inputs; constant output bits become
    /// zero-arity planes.
    pub fn try_build(layer: &LayerSpec) -> Option<BitPlaneLayer> {
        if layer.in_bits * layer.fan_in > MAX_BUILD_ADDR_BITS {
            return None;
        }
        let planes = layer.w * layer.out_bits;
        let mut arity = Vec::with_capacity(planes);
        let mut tables = Vec::with_capacity(planes);
        let mut src_off = Vec::with_capacity(planes);
        let mut srcs = Vec::new();
        for u in 0..layer.w {
            let tt = layer.truth_table(u);
            let conn = layer.unit_conn(u);
            for b in 0..layer.out_bits {
                let support = tt.bit_support(b);
                if support.len() > MAX_PLANE_SUPPORT {
                    return None;
                }
                src_off.push(srcs.len() as u32);
                arity.push(support.len() as u8);
                tables.push(tt.reduced_bit_table(b, &support));
                for &v in &support {
                    let f = v / layer.in_bits;
                    let k = v % layer.in_bits;
                    srcs.push(conn[f] * layer.in_bits as u32 + k as u32);
                }
            }
        }
        Some(BitPlaneLayer {
            w: layer.w,
            out_bits: layer.out_bits,
            arity,
            tables,
            src_off,
            srcs,
        })
    }

    /// Number of output planes (`w * out_bits`).
    pub fn planes(&self) -> usize {
        self.w * self.out_bits
    }

    /// Mean reduced support per plane (introspection).
    pub fn mean_support(&self) -> f64 {
        if self.arity.is_empty() {
            return 0.0;
        }
        self.arity.iter().map(|&a| a as usize).sum::<usize>() as f64
            / self.arity.len() as f64
    }

    /// Evaluate planes of units `[u0, u1)`.  `prev` holds the producer
    /// planes (plane-major, `nwords` words each); `out` covers exactly
    /// this unit range so disjoint ranges can run on separate threads.
    pub fn eval_units(&self, prev: &[u64], nwords: usize,
                      u0: usize, u1: usize, out: &mut [u64]) {
        debug_assert_eq!(out.len(), (u1 - u0) * self.out_bits * nwords);
        let mut ins = [0u64; MAX_PLANE_SUPPORT];
        let p0 = u0 * self.out_bits;
        for p in p0..u1 * self.out_bits {
            let a = self.arity[p] as usize;
            let off = self.src_off[p] as usize;
            let srcs = &self.srcs[off..off + a];
            let table = self.tables[p];
            let dst = &mut out[(p - p0) * nwords..(p - p0 + 1) * nwords];
            for (wd, slot) in dst.iter_mut().enumerate() {
                for (i, &s) in srcs.iter().enumerate() {
                    ins[i] = prev[s as usize * nwords + wd];
                }
                // arity is capped at build time; skip the entry assert
                *slot = eval_packed_rec(table, &ins[..a]);
            }
        }
    }

    /// Evaluate the whole layer single-threaded.
    pub fn eval(&self, prev: &[u64], nwords: usize, out: &mut [u64]) {
        self.eval_units(prev, nwords, 0, self.w, out)
    }
}

enum LayerKernel {
    Gather,
    BitPlane(BitPlaneLayer),
}

/// Pack signal-major codes into bit-planes (64 samples/word):
/// plane `s * bits + k` holds bit `k` of signal `s`.
fn pack_planes(cur: &[u16], w: usize, bits: usize, batch: usize,
               nwords: usize, out: &mut Vec<u64>) {
    out.clear();
    out.resize(w * bits * nwords, 0);
    for s in 0..w {
        let row = &cur[s * batch..(s + 1) * batch];
        for (b, &c) in row.iter().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            for k in 0..bits {
                out[(s * bits + k) * nwords + wd] |=
                    (((c >> k) & 1) as u64) << sh;
            }
        }
    }
}

/// Inverse of [`pack_planes`]: reassemble codes from bit-planes.
fn unpack_planes(planes: &[u64], w: usize, bits: usize, batch: usize,
                 nwords: usize, cur: &mut [u16]) {
    for s in 0..w {
        let row = &mut cur[s * batch..(s + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            let mut c = 0u16;
            for k in 0..bits {
                c |= (((planes[(s * bits + k) * nwords + wd] >> sh) & 1)
                    as u16) << k;
            }
            *slot = c;
        }
    }
}

/// Gather-kernel evaluation of units `[u0, u1)`; `dst` covers exactly
/// that unit range (unit-major, `batch` codes per unit).
fn gather_units(layer: &LayerSpec, cur: &[u16], batch: usize,
                u0: usize, u1: usize, dst: &mut [u16]) {
    debug_assert_eq!(dst.len(), (u1 - u0) * batch);
    let t = layer.entries_per_unit();
    for u in u0..u1 {
        let conn = layer.unit_conn(u);
        let table = &layer.tables[u * t..(u + 1) * t];
        let row = &mut dst[(u - u0) * batch..(u - u0 + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let mut addr = 0usize;
            for (f, &src) in conn.iter().enumerate() {
                addr |= (cur[src as usize * batch + b] as usize)
                    << (layer.in_bits * f);
            }
            *slot = table[addr];
        }
    }
}

/// How many threads to actually use for a layer of `units` units with
/// `work` output words/codes total.
fn par_threads(requested: usize, units: usize, work: usize) -> usize {
    if requested <= 1 || units < 2 || work < PAR_MIN_WORK {
        1
    } else {
        requested.min(units)
    }
}

/// Run `f(u0, u1, dst)` over unit ranges of a layer with `w` units whose
/// output occupies `stride` elements per unit, fanning the disjoint
/// `dst` chunks across up to `threads` scoped workers (serial when
/// `threads <= 1`).  Both kernels share this scaffold so the chunk math
/// lives in one place.
fn chunked_units<T: Send, F>(out: &mut [T], w: usize, stride: usize,
                             threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    debug_assert_eq!(out.len(), w * stride);
    if threads <= 1 {
        f(0, w, out);
        return;
    }
    let chunk = (w + threads - 1) / threads;
    std::thread::scope(|s| {
        for (i, dst) in out.chunks_mut(chunk * stride).enumerate() {
            let u0 = i * chunk;
            let u1 = (u0 + chunk).min(w);
            let f = &f;
            s.spawn(move || f(u0, u1, dst));
        }
    });
}

/// Reusable-buffer simulator bound to a netlist.
pub struct Simulator<'a> {
    nl: &'a Netlist,
    opts: SimOptions,
    kernels: Vec<LayerKernel>,
    /// scratch: signal-major u16 codes
    buf_a: Vec<u16>,
    buf_b: Vec<u16>,
    /// scratch: packed bit-plane words
    bits_a: Vec<u64>,
    bits_b: Vec<u64>,
}

impl<'a> Simulator<'a> {
    pub fn new(nl: &'a Netlist) -> Simulator<'a> {
        Self::with_options(nl, SimOptions::default())
    }

    /// Build with explicit kernel/threading options (benches use this to
    /// pin the gather baseline; the server plumbs `sim_threads` here).
    pub fn with_options(nl: &'a Netlist, opts: SimOptions) -> Simulator<'a> {
        let kernels = nl
            .layers
            .iter()
            .map(|l| {
                if !opts.bitplane {
                    return LayerKernel::Gather;
                }
                match BitPlaneLayer::try_build(l) {
                    Some(b) => LayerKernel::BitPlane(b),
                    None => LayerKernel::Gather,
                }
            })
            .collect();
        Simulator { nl, opts, kernels, buf_a: Vec::new(), buf_b: Vec::new(),
                    bits_a: Vec::new(), bits_b: Vec::new() }
    }

    /// Change the worker-thread count after construction.
    pub fn set_threads(&mut self, threads: usize) {
        self.opts.threads = threads.max(1);
    }

    /// Per-layer kernel choice (introspection for benches/logs).
    pub fn layer_kernels(&self) -> Vec<KernelChoice> {
        self.kernels
            .iter()
            .map(|k| match k {
                LayerKernel::Gather => KernelChoice::Gather,
                LayerKernel::BitPlane(_) => KernelChoice::BitPlane,
            })
            .collect()
    }

    /// How many layers compiled to the bit-plane kernel.
    pub fn bitplane_layers(&self) -> usize {
        self.kernels
            .iter()
            .filter(|k| matches!(k, LayerKernel::BitPlane(_)))
            .count()
    }

    /// Legacy name for [`Simulator::bitplane_layers`] (the v1 kernel only
    /// handled boolean layers and was called "bitsliced").
    pub fn bitsliced_layers(&self) -> usize {
        self.bitplane_layers()
    }

    /// Row-major input codes -> row-major output codes.
    ///
    /// Representation-aware execution (EXPERIMENTS.md §Hot path): signals
    /// stay *packed* (one plane per signal bit, 64 samples/word) across
    /// consecutive bit-plane layers — including multi-bit ones — and are
    /// only materialized as codes at gather-layer boundaries.  Small
    /// batches skip the packed machinery entirely (word packing doesn't
    /// amortize).  With `opts.threads > 1`, each sufficiently large layer
    /// is chunked over unit ranges onto scoped threads.
    pub fn eval_batch(&mut self, x: &[i32], batch: usize) -> Vec<i32> {
        assert_eq!(x.len(), batch * self.nl.n_in);
        let use_bits = self.opts.bitplane
            && batch >= self.opts.min_bitplane_batch;
        let max_w = self
            .nl
            .layers
            .iter()
            .map(|l| l.w)
            .max()
            .unwrap_or(0)
            .max(self.nl.n_in);
        self.buf_a.resize(max_w * batch, 0);
        self.buf_b.resize(max_w * batch, 0);
        // transpose input to signal-major
        for s in 0..self.nl.n_in {
            for b in 0..batch {
                self.buf_a[s * batch + b] = x[b * self.nl.n_in + s] as u16;
            }
        }
        let nwords = (batch + 63) / 64;
        // own the ping-pong buffers locally to keep borrows disjoint
        let mut cur = std::mem::take(&mut self.buf_a);
        let mut next = std::mem::take(&mut self.buf_b);
        let mut bits_cur = std::mem::take(&mut self.bits_a);
        let mut bits_next = std::mem::take(&mut self.bits_b);
        let mut packed = false; // is the live value in bits_cur?
        for (l, layer) in self.nl.layers.iter().enumerate() {
            let prev_w =
                if l == 0 { self.nl.n_in } else { self.nl.layers[l - 1].w };
            match &self.kernels[l] {
                LayerKernel::BitPlane(bl) if use_bits => {
                    if !packed {
                        pack_planes(&cur, prev_w, layer.in_bits, batch,
                                    nwords, &mut bits_cur);
                        packed = true;
                    }
                    bits_next.clear();
                    bits_next.resize(bl.planes() * nwords, 0);
                    let t = par_threads(self.opts.threads, bl.w,
                                        bl.planes() * nwords);
                    let prev: &[u64] = &bits_cur;
                    chunked_units(
                        &mut bits_next[..bl.planes() * nwords], bl.w,
                        bl.out_bits * nwords, t,
                        |u0, u1, dst| bl.eval_units(prev, nwords, u0, u1, dst),
                    );
                    std::mem::swap(&mut bits_cur, &mut bits_next);
                }
                _ => {
                    if packed {
                        unpack_planes(&bits_cur, prev_w, layer.in_bits,
                                      batch, nwords, &mut cur);
                        packed = false;
                    }
                    let t = par_threads(self.opts.threads, layer.w,
                                        layer.w * batch);
                    let prev: &[u16] = &cur;
                    chunked_units(
                        &mut next[..layer.w * batch], layer.w, batch, t,
                        |u0, u1, dst| gather_units(layer, prev, batch, u0, u1,
                                                   dst),
                    );
                    std::mem::swap(&mut cur, &mut next);
                }
            }
        }
        let ow = self.nl.out_width();
        if packed {
            unpack_planes(&bits_cur, ow, self.nl.out_bits(), batch, nwords,
                          &mut cur);
        }
        // transpose back to row-major
        let mut out = vec![0i32; batch * ow];
        for u in 0..ow {
            for b in 0..batch {
                out[b * ow + u] = cur[u * batch + b] as i32;
            }
        }
        self.buf_a = cur;
        self.buf_b = next;
        self.bits_a = bits_cur;
        self.bits_b = bits_next;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    fn assert_matches_eval_one(nl: &Netlist, sim: &mut Simulator,
                               seed: u64, batch: usize) {
        let x = random_inputs(seed, nl, batch);
        let got = sim.eval_batch(&x, batch);
        let ow = nl.out_width();
        for b in 0..batch {
            let one =
                nl.eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..], "row {b}");
        }
    }

    #[test]
    fn eval_packed_matches_table() {
        // exhaustive over all 2^(2^3) 3-input functions is large; sample
        for seed in 0..32u64 {
            let table = seed.wrapping_mul(0x9E3779B97F4A7C15);
            let masked = table & ((1u64 << 8) - 1);
            for v in 0..8usize {
                let ins: Vec<u64> = (0..3)
                    .map(|f| if (v >> f) & 1 == 1 { !0u64 } else { 0 })
                    .collect();
                let got = eval_packed(masked, &ins) & 1;
                let want = (masked >> v) & 1;
                assert_eq!(got, want, "table {masked:08b} v {v}");
            }
        }
    }

    #[test]
    fn boolean_netlist_all_bitplane() {
        let nl = random_netlist(11, 32, 1, &[(16, 6, 1), (8, 2, 1), (4, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_eq!(sim.bitsliced_layers(), 3); // legacy alias
        // batch not a multiple of 64: exercises tail handling
        assert_matches_eval_one(&nl, &mut sim, 11, 200);
    }

    #[test]
    fn mixed_width_netlist_uses_bitplane() {
        // multi-bit signals, raw addr width 4 <= 6: every layer packs
        let nl = random_netlist(13, 16, 2, &[(8, 2, 2), (4, 2, 1), (2, 2, 1)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_eq!(sim.layer_kernels(),
                   vec![KernelChoice::BitPlane; 3]);
        assert_matches_eval_one(&nl, &mut sim, 13, 65);
    }

    #[test]
    fn wide_address_layer_qualifies_after_support_reduction() {
        // raw addr width 4*2 = 8 > 6, but true support <= 6 per plane
        let nl = random_reducible_netlist(
            19, 12, 2, &[(8, 4, 2), (4, 4, 2), (2, 2, 2)], 6);
        assert!(nl.layers[0].in_bits * nl.layers[0].fan_in > 6);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 3);
        assert_matches_eval_one(&nl, &mut sim, 19, 130);
    }

    #[test]
    fn full_support_wide_layer_falls_back_to_gather() {
        // random dense tables on 8 address bits: support reduction finds
        // nothing, so the layer must stay on the gather kernel
        let nl = random_netlist(23, 16, 4, &[(8, 2, 4), (4, 2, 4)]);
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 0);
        assert_matches_eval_one(&nl, &mut sim, 23, 70);
    }

    #[test]
    fn constant_output_bits_evaluate_correctly() {
        // force a constant plane: all table entries share output bit 1
        let mut nl = random_netlist(29, 8, 1, &[(4, 2, 2), (2, 2, 2)]);
        for e in nl.layers[0].tables.iter_mut() {
            *e |= 0b10;
        }
        let mut sim = Simulator::new(&nl);
        assert_eq!(sim.bitplane_layers(), 2);
        assert_matches_eval_one(&nl, &mut sim, 29, 100);
    }

    #[test]
    fn gather_only_option_matches() {
        let nl = random_netlist(17, 16, 2, &[(8, 2, 2), (4, 2, 2)]);
        let mut sim = Simulator::with_options(
            &nl, SimOptions { bitplane: false, ..Default::default() });
        assert_eq!(sim.bitplane_layers(), 0);
        assert_matches_eval_one(&nl, &mut sim, 17, 96);
    }

    #[test]
    fn threaded_eval_matches_serial() {
        let nl = random_reducible_netlist(
            37, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        let mut sim = Simulator::new(&nl);
        sim.set_threads(4);
        // batch large enough that PAR_MIN_WORK lets the big layers fan
        // out, and not a multiple of 64 (tail words in every plane)
        assert_matches_eval_one(&nl, &mut sim, 37, 2100);
    }

    #[test]
    fn simulator_reuse_across_batches() {
        let nl = random_netlist(17, 8, 1, &[(4, 3, 2), (2, 2, 3)]);
        let mut sim = nl.simulator();
        for (seed, batch) in [(1u64, 5usize), (2, 64), (3, 129)] {
            assert_matches_eval_one(&nl, &mut sim, seed, batch);
        }
    }

    #[test]
    fn bitplane_layer_direct_eval() {
        // drive BitPlaneLayer::eval directly on a packed input
        let nl = random_netlist(41, 6, 2, &[(3, 2, 2)]);
        let bl = BitPlaneLayer::try_build(&nl.layers[0]).unwrap();
        assert_eq!(bl.planes(), 6);
        assert!(bl.mean_support() <= 4.0 + 1e-9);
        let batch = 64;
        let x = random_inputs(41, &nl, batch);
        // pack input codes into planes by hand
        let nwords = 1;
        let mut planes = vec![0u64; 6 * 2 * nwords];
        for s in 0..6 {
            for b in 0..batch {
                let c = x[b * 6 + s] as u64;
                for k in 0..2 {
                    planes[(s * 2 + k) * nwords] |= ((c >> k) & 1) << b;
                }
            }
        }
        let mut out = vec![0u64; bl.planes() * nwords];
        bl.eval(&planes, nwords, &mut out);
        for b in 0..batch {
            let one = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
            for u in 0..3 {
                let mut c = 0i32;
                for k in 0..2 {
                    c |= (((out[(u * 2 + k) * nwords] >> b) & 1) as i32) << k;
                }
                assert_eq!(c, one[u], "unit {u} row {b}");
            }
        }
    }
}
