//! Compiled execution plans — the netlist flattened into arena-backed
//! programs.
//!
//! The paper's core bet is that LUT networks are cheap *because their
//! structure is static*: NeuraLUT-Assemble fixes fan-in and topology at
//! training time, so everything about how a netlist executes — gather
//! strides, table locations, per-plane reduced supports, the layer
//! schedule — is a constant of the artifact, not of the request.  The
//! interpreted simulator ([`super::sim`]) still pays dynamic-structure
//! costs the hardware never would: it walks `Vec<LutUnit>`-shaped layers,
//! chases per-unit `conn`/`table` slices and re-derives offsets per call.
//! [`compile`] lowers a (typically optimizer-output) netlist **once**
//! into an [`ExecPlan`]:
//!
//! * all truth tables live in one shared `Vec<u64>` **word arena**,
//!   deduplicated by content — gather tables are packed four u16 codes
//!   per word, bit-plane reduced tables are one word each, and units or
//!   planes with identical tables share storage (trained netlists repeat
//!   small functions constantly);
//! * all connections live in one flat **conn arena** addressed CSR-style
//!   (a per-layer `conn_off` for the fixed-fan-in gather side, per-plane
//!   `src_off` for the variable-arity plane side);
//! * per-layer gather strides and support-reduced plane tables are
//!   precomputed at compile time (the work `sim.rs` redoes per
//!   `Simulator`), and the layer schedule is static;
//! * a [`PlanExecutor`] owns double-buffered, pre-sized activation
//!   planes, so steady-state `eval_batch` performs **zero heap
//!   allocation** (observable via [`WidePlanExecutor::buffer_grows`]).
//!
//! A plan is immutable and shareable (`Arc<ExecPlan>`): the server
//! compiles each model once at registration through a [`PlanCache`]
//! keyed by [`Netlist::content_hash`] and every router worker executes
//! the same plan with private scratch.  Execution is bit-exact with the
//! interpreted walk by construction — same tables, same address
//! assembly, same Shannon evaluation — and the property suite
//! (`prop_compiled_plan_*`) enforces it across seeds, optimizer levels,
//! thread modes and batch sizes.
//!
//! The executor additionally fuses the row-major input boundary into the
//! first layer (gathering straight from the request buffer, or packing
//! bit-planes straight from it) and runs a transpose-free single-sample
//! path at batch 1 — which is where interpretation overhead dominates
//! and the compiled path wins outright (`netlist_hotpath`
//! compiled-vs-interpreted rows).
//!
//! **Wide-word execution.**  The executor core is width-polymorphic:
//! [`WidePlanExecutor<W>`] runs the *same* plan over [`Lane<W>`]
//! registers — `W` consecutive packed words of one bit-plane, i.e.
//! `W * 64` samples per table evaluation — and [`PlanExecutor`] is the
//! `W = 1` alias that remains the scalar reference.  Because the packed
//! buffer is plane-major, widening needs no layout change: a lane is
//! just the next `W` words of the plane a scalar kernel would have
//! visited one at a time, and the trailing `nwords % W` words of each
//! plane (a batch that is not a multiple of `64 * W`) fall through to
//! the scalar Shannon kernel.  The lane ops are plain fixed-size array
//! bitwise loops the compiler auto-vectorizes (SSE2/AVX2/AVX-512/NEON
//! — no intrinsics, no unsafe), and one generic kernel serves every
//! width, so the scalar and wide paths cannot drift.  Runtime width
//! selection lives in [`select_backend`] (batch-size hint plus a CPU
//! feature probe) and the width-erased [`LaneExecutor`] carries the
//! chosen executor behind one API for servers and CLIs; gather, pack
//! and unpack are code-major and width-independent, so the wide win is
//! the bit-plane kernel, which is where large batches spend their
//! time (`netlist_hotpath` scalar-vs-wide rows).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::format::{self, ByteReader};
use super::mapped::{Arena, MappedFile};
use super::sim::{chunked_units, eval_packed_rec, par_threads,
                 KernelChoice, LaneSelect, SimOptions, ThreadMode,
                 WorkerPool, MAX_BUILD_ADDR_BITS, MAX_PLANE_SUPPORT,
                 PAR_MIN_WORK, PAR_MIN_WORK_POOLED,
                 PAR_MIN_WORK_POOLED_GATHER};
use super::{LayerSpec, Netlist};

/// Compilation knobs.  Execution-time knobs (threads, mode, the packed
/// batch floor) stay in [`SimOptions`]; only what changes the compiled
/// artifact lives here, because it is part of the [`PlanCache`] key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanOptions {
    /// Build bit-plane steps for qualifying layers (default true;
    /// disable to compile a gather-only plan, the measurement baseline).
    pub bitplane: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { bitplane: true }
    }
}

/// The gather side of one compiled layer: fixed fan-in, so connections
/// are a dense `w * fan_in` block at `conn_off` in the plan's conn arena
/// and only the per-unit table offsets vary.  Every layer has one (it is
/// the any-layer fallback and the small-batch kernel); tables are packed
/// four u16 codes per arena word.
struct GatherStep {
    w: usize,
    fan_in: usize,
    in_bits: usize,
    out_bits: usize,
    /// producer width (n_in for layer 0)
    prev_w: usize,
    /// start of this layer's `w * fan_in` conn block
    conn_off: usize,
    /// per-unit word offset into the table arena
    table_off: Vec<u32>,
    /// per-slot address shift `in_bits * f` — the gather stride,
    /// precomputed instead of re-derived per (unit, sample)
    shifts: Vec<u32>,
}

/// The bit-plane side of one compiled layer: per (unit, output bit) a
/// support-reduced single-word table plus a CSR run of input-plane
/// indices in the conn arena (`src_off[p] .. src_off[p] + arity[p]`).
struct BitPlaneStep {
    w: usize,
    out_bits: usize,
    /// per-plane reduced support size (<= [`MAX_PLANE_SUPPORT`])
    arity: Vec<u8>,
    /// per-plane word offset into the table arena (one word per plane)
    table_off: Vec<u32>,
    /// per-plane absolute offset into the conn arena
    src_off: Vec<u32>,
}

struct PlanLayer {
    gather: GatherStep,
    /// present iff every plane's reduced support fits a packed word
    bitplane: Option<BitPlaneStep>,
}

/// A netlist lowered to arena-backed form: immutable, `Send + Sync`,
/// shared across executors via `Arc`.  See the module doc for layout.
pub struct ExecPlan {
    name: String,
    n_in: usize,
    in_bits: usize,
    out_width: usize,
    out_bits: usize,
    /// cache key this plan was compiled under ([`Netlist::content_hash`]
    /// mixed with [`PlanOptions`])
    key: u64,
    /// shared truth-table word arena (deduplicated) — owned after a
    /// compile or copying load, borrowed from the artifact file after a
    /// zero-copy load (see `netlist::mapped`)
    words: Arena<u64>,
    /// shared connection / plane-source arena
    conn: Arena<u32>,
    layers: Vec<PlanLayer>,
    /// widest signal plane (incl. the input), for code-buffer sizing
    max_w: usize,
    /// most bit-planes live at once (incl. the input planes)
    max_planes: usize,
    /// logical tables compiled (gather tables + plane tables)
    tables_total: usize,
    /// distinct arena entries after dedup
    tables_unique: usize,
}

/// Point-in-time plan statistics (CLI `--plan`, server startup logs).
#[derive(Clone, Copy, Debug)]
pub struct PlanStats {
    pub layers: usize,
    pub bitplane_layers: usize,
    /// bit-planes across all compiled bit-plane steps
    pub planes: usize,
    /// logical tables compiled (units + planes)
    pub tables_total: usize,
    /// distinct tables after arena dedup
    pub tables_unique: usize,
    /// table arena length in u64 words
    pub table_words: usize,
    /// conn arena length in u32 entries
    pub conn_entries: usize,
    /// arena footprint (tables + connections), bytes
    pub arena_bytes: usize,
}

impl PlanStats {
    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!("{}/{} layers bit-plane ({} planes), {} tables -> {} \
                 unique ({} words), {} conn entries, {} arena bytes",
                self.bitplane_layers, self.layers, self.planes,
                self.tables_total, self.tables_unique, self.table_words,
                self.conn_entries, self.arena_bytes)
    }
}

impl ExecPlan {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn in_bits(&self) -> usize {
        self.in_bits
    }

    pub fn out_width(&self) -> usize {
        self.out_width
    }

    pub fn out_bits(&self) -> usize {
        self.out_bits
    }

    /// The cache key this plan was compiled under.
    pub fn key(&self) -> u64 {
        self.key
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// How many layers carry a bit-plane step.
    pub fn bitplane_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.bitplane.is_some()).count()
    }

    /// Per-layer kernel availability, mirroring
    /// `Simulator::layer_kernels` (a layer with a bit-plane step still
    /// runs gather below the packed batch floor).
    pub fn layer_kernels(&self) -> Vec<KernelChoice> {
        self.layers
            .iter()
            .map(|l| {
                if l.bitplane.is_some() {
                    KernelChoice::BitPlane
                } else {
                    KernelChoice::Gather
                }
            })
            .collect()
    }

    /// Was this plan compiled from exactly `nl`'s content?  Full
    /// structural comparison — dimensions, wiring and every table entry
    /// (read back through the packed arena) — so a content-hash
    /// collision can never smuggle the wrong plan past the cache.  Only
    /// called on cache hits (registration time), never on the hot path.
    fn matches(&self, nl: &Netlist) -> bool {
        if self.n_in != nl.n_in
            || self.in_bits != nl.in_bits
            || self.layers.len() != nl.layers.len()
        {
            return false;
        }
        let words: &[u64] = &self.words;
        let conn: &[u32] = &self.conn;
        for (pl, layer) in self.layers.iter().zip(&nl.layers) {
            let g = &pl.gather;
            if g.w != layer.w
                || g.fan_in != layer.fan_in
                || g.in_bits != layer.in_bits
                || g.out_bits != layer.out_bits
            {
                return false;
            }
            let c0 = g.conn_off;
            if conn[c0..c0 + layer.w * layer.fan_in] != layer.conn[..] {
                return false;
            }
            let entries = layer.entries_per_unit();
            for u in 0..layer.w {
                let toff = g.table_off[u] as usize;
                let table = layer.unit_table(u);
                for (i, &want) in table.iter().enumerate() {
                    if table_read(words, toff, i) != want {
                        return false;
                    }
                }
                debug_assert_eq!(table.len(), entries);
            }
        }
        true
    }

    /// Does this plan borrow its arenas from a memory-mapped artifact
    /// file (zero-copy load) rather than own them?
    pub fn is_mapped(&self) -> bool {
        self.words.is_mapped() || self.conn.is_mapped()
    }

    pub fn stats(&self) -> PlanStats {
        PlanStats {
            layers: self.layers.len(),
            bitplane_layers: self.bitplane_layers(),
            planes: self
                .layers
                .iter()
                .filter_map(|l| l.bitplane.as_ref())
                .map(|b| b.w * b.out_bits)
                .sum(),
            tables_total: self.tables_total,
            tables_unique: self.tables_unique,
            table_words: self.words.len(),
            conn_entries: self.conn.len(),
            arena_bytes: self.words.len() * 8 + self.conn.len() * 4,
        }
    }
}

/// Plan-image (de)serialization — the `.nlb` optional section and the
/// persistent [`PlanCache`] file body.  Lives here (not in
/// `netlist::format`) because it reads private arena fields; the byte
/// helpers come from there so both sections share one encoding.
impl ExecPlan {
    /// Append this plan's image to `out`.  The arenas are dumped
    /// verbatim (they are already flat, position-independent buffers);
    /// everything derivable from the owning netlist — gather dims,
    /// shifts, `prev_w`, `max_w`, `max_planes`, `tables_total` — is
    /// recomputed at load instead of stored.
    ///
    /// ```text
    /// key            u64   (plan_key the plan was compiled under)
    /// tables_unique  u64
    /// words          u64 count + count x u64
    /// conn           u64 count + count x u32
    /// n_layers       u32   (cross-checked against the netlist)
    /// per layer:
    ///   conn_off     u64
    ///   table_off    w x u32
    ///   bp flag      u8    (0 = gather only, 1 = bit-plane step)
    ///   if bp: arity planes x u8; table_off planes x u32;
    ///          src_off planes x u32      (planes = w * out_bits)
    /// ```
    pub(super) fn write_image(&self, out: &mut Vec<u8>) {
        format::put_u64(out, self.key);
        format::put_u64(out, self.tables_unique as u64);
        format::put_u64(out, self.words.len() as u64);
        for &w in self.words.iter() {
            format::put_u64(out, w);
        }
        format::put_u64(out, self.conn.len() as u64);
        for &c in self.conn.iter() {
            format::put_u32(out, c);
        }
        format::put_u32(out, self.layers.len() as u32);
        for pl in &self.layers {
            format::put_u64(out, pl.gather.conn_off as u64);
            for &t in &pl.gather.table_off {
                format::put_u32(out, t);
            }
            match &pl.bitplane {
                None => format::put_u8(out, 0),
                Some(bp) => {
                    format::put_u8(out, 1);
                    for &a in &bp.arity {
                        format::put_u8(out, a);
                    }
                    for &t in &bp.table_off {
                        format::put_u32(out, t);
                    }
                    for &s in &bp.src_off {
                        format::put_u32(out, s);
                    }
                }
            }
        }
    }

    /// Parse a plan image for `nl`, validating every offset against
    /// the arenas and the structure against the netlist before any of
    /// it can be executed: the key must be one `nl` could have
    /// produced, each gather conn block must equal the netlist wiring,
    /// all table offsets must be in-arena, plane arities must respect
    /// [`MAX_PLANE_SUPPORT`] and plane sources must index real
    /// producer planes.  Finally the gather tables are compared
    /// entry-by-entry ([`ExecPlan::matches`]), so a stale or spliced
    /// image is rejected rather than served.
    /// When `src` is given (the reader's bytes live `base` bytes into a
    /// memory-mapped file), the word/conn arenas are *borrowed* from
    /// the mapping instead of copied, provided the zero-copy
    /// preconditions hold ([`Arena::try_map`]: little-endian host,
    /// in-bounds, 8-byte-aligned offsets — which the v2 writers pad to
    /// guarantee); otherwise each arena independently falls back to an
    /// owned copy.  Validation is identical on both paths.
    pub(super) fn read_image(r: &mut ByteReader<'_>, nl: &Netlist,
                             src: Option<(&Arc<MappedFile>, usize)>)
                             -> Result<ExecPlan> {
        let key = r.u64("plan key")?;
        let bp_opts = if key == plan_key(nl, PlanOptions { bitplane: true }) {
            true
        } else if key == plan_key(nl, PlanOptions { bitplane: false }) {
            false
        } else {
            bail!("plan key {key:016x} does not match the netlist \
                   (content hash {:016x})", nl.content_hash());
        };
        let tables_unique = r.u64("tables_unique")? as usize;
        let n_words = r.u64("word arena length")? as usize;
        let words = arena_u64(r, n_words, src, "word arena")?;
        let n_conn = r.u64("conn arena length")? as usize;
        let conn = arena_u32(r, n_conn, src, "conn arena")?;
        let n_layers = r.u32("plan layer count")? as usize;
        if n_layers != nl.layers.len() {
            bail!("plan has {n_layers} layers, netlist has {}",
                  nl.layers.len());
        }
        let mut layers = Vec::with_capacity(nl.layers.len());
        let mut tables_total = 0usize;
        let mut prev_w = nl.n_in;
        for (l, layer) in nl.layers.iter().enumerate() {
            let conn_off = r.u64("gather conn offset")? as usize;
            let table_off = r.u32s(layer.w, "gather table offsets")?;
            let conn_end = conn_off
                .checked_add(layer.w * layer.fan_in)
                .filter(|&e| e <= conn.len())
                .with_context(|| format!(
                    "layer {l}: conn block out of arena bounds"))?;
            if conn[conn_off..conn_end] != layer.conn[..] {
                bail!("layer {l}: gather wiring differs from the \
                       netlist");
            }
            let twords = layer.entries_per_unit().div_ceil(4);
            for (u, &toff) in table_off.iter().enumerate() {
                if (toff as usize).checked_add(twords)
                    .map(|e| e > words.len())
                    .unwrap_or(true)
                {
                    bail!("layer {l} unit {u}: gather table offset \
                           {toff} out of arena bounds");
                }
            }
            tables_total += layer.w;
            let bitplane = match r.u8("bit-plane flag")? {
                0 => None,
                1 => {
                    if !bp_opts {
                        bail!("layer {l}: bit-plane step in a \
                               gather-only plan image");
                    }
                    let planes = layer.w * layer.out_bits;
                    let arity = r.u8s(planes, "plane arities")?;
                    let bp_table_off =
                        r.u32s(planes, "plane table offsets")?;
                    let src_off = r.u32s(planes, "plane src offsets")?;
                    let in_planes = prev_w * layer.in_bits;
                    for p in 0..planes {
                        let a = arity[p] as usize;
                        if a > MAX_PLANE_SUPPORT {
                            bail!("layer {l} plane {p}: arity {a} \
                                   exceeds {MAX_PLANE_SUPPORT}");
                        }
                        if (bp_table_off[p] as usize) >= words.len() {
                            bail!("layer {l} plane {p}: table offset \
                                   out of arena bounds");
                        }
                        let s0 = src_off[p] as usize;
                        let s1 = s0.checked_add(a)
                            .filter(|&e| e <= conn.len())
                            .with_context(|| format!(
                                "layer {l} plane {p}: source run out \
                                 of arena bounds"))?;
                        if conn[s0..s1].iter()
                            .any(|&s| s as usize >= in_planes)
                        {
                            bail!("layer {l} plane {p}: source plane \
                                   index out of range ({in_planes} \
                                   producer planes)");
                        }
                    }
                    tables_total += planes;
                    Some(BitPlaneStep {
                        w: layer.w,
                        out_bits: layer.out_bits,
                        arity,
                        table_off: bp_table_off,
                        src_off,
                    })
                }
                f => bail!("layer {l}: bad bit-plane flag {f}"),
            };
            let shifts: Vec<u32> = (0..layer.fan_in)
                .map(|f| (layer.in_bits * f) as u32)
                .collect();
            layers.push(PlanLayer {
                gather: GatherStep {
                    w: layer.w,
                    fan_in: layer.fan_in,
                    in_bits: layer.in_bits,
                    out_bits: layer.out_bits,
                    prev_w,
                    conn_off,
                    table_off,
                    shifts,
                },
                bitplane,
            });
            prev_w = layer.w;
        }
        if tables_unique > tables_total || tables_unique > words.len() {
            bail!("implausible dedup stats: {tables_unique} unique of \
                   {tables_total} tables in {} words", words.len());
        }
        let max_w = layers
            .iter()
            .map(|l| l.gather.w)
            .max()
            .unwrap_or(0)
            .max(nl.n_in);
        let max_planes = layers
            .iter()
            .map(|l| l.gather.w * l.gather.out_bits)
            .max()
            .unwrap_or(0)
            .max(nl.n_in * nl.in_bits);
        let plan = ExecPlan {
            name: nl.name.clone(),
            n_in: nl.n_in,
            in_bits: nl.in_bits,
            out_width: nl.out_width(),
            out_bits: nl.out_bits(),
            key,
            words,
            conn,
            layers,
            max_w,
            max_planes,
            tables_total,
            tables_unique,
        };
        if !plan.matches(nl) {
            bail!("plan gather tables differ from the netlist");
        }
        Ok(plan)
    }
}

/// Read `count` u64s as an [`Arena`]: borrowed from the mapped source
/// when the zero-copy preconditions hold, else decoded into an owned
/// copy.  Both paths advance the reader past the same bytes and apply
/// the same bounds check, so the surrounding parse is oblivious.
fn arena_u64(r: &mut ByteReader<'_>, count: usize,
             src: Option<(&Arc<MappedFile>, usize)>, what: &str)
             -> Result<Arena<u64>> {
    let Some((map, base)) = src else {
        return Ok(r.u64s(count, what)?.into());
    };
    let abs = base.checked_add(r.pos());
    let n = count.checked_mul(8)
        .with_context(|| format!("{what}: count overflow"))?;
    let bytes = r.take(n, what)?;
    match abs.and_then(|a| Arena::try_map(map, a, count)) {
        Some(a) => Ok(a),
        None => Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect::<Vec<u64>>()
            .into()),
    }
}

/// u32 twin of [`arena_u64`].
fn arena_u32(r: &mut ByteReader<'_>, count: usize,
             src: Option<(&Arc<MappedFile>, usize)>, what: &str)
             -> Result<Arena<u32>> {
    let Some((map, base)) = src else {
        return Ok(r.u32s(count, what)?.into());
    };
    let abs = base.checked_add(r.pos());
    let n = count.checked_mul(4)
        .with_context(|| format!("{what}: count overflow"))?;
    let bytes = r.take(n, what)?;
    match abs.and_then(|a| Arena::try_map(map, a, count)) {
        Some(a) => Ok(a),
        None => Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect::<Vec<u32>>()
            .into()),
    }
}

/// Append `packed` to the arena unless identical content is already
/// interned; returns the word offset either way.
fn intern(words: &mut Vec<u64>, dedup: &mut HashMap<Vec<u64>, u32>,
          packed: Vec<u64>) -> u32 {
    if let Some(&off) = dedup.get(&packed) {
        return off;
    }
    let off = words.len() as u32;
    words.extend_from_slice(&packed);
    dedup.insert(packed, off);
    off
}

/// Support-reduce `layer` into plane form, or `None` if any plane's true
/// support exceeds [`MAX_PLANE_SUPPORT`] (same qualification rule as the
/// interpreted `BitPlaneLayer::try_build`).  Returned `srcs` runs are
/// plane-major with `arity[p]` entries each.
fn reduce_planes(layer: &LayerSpec)
                 -> Option<(Vec<u8>, Vec<u64>, Vec<u32>)> {
    if layer.in_bits * layer.fan_in > MAX_BUILD_ADDR_BITS {
        return None;
    }
    let planes = layer.w * layer.out_bits;
    let mut arity = Vec::with_capacity(planes);
    let mut tables = Vec::with_capacity(planes);
    let mut srcs = Vec::new();
    for u in 0..layer.w {
        let tt = layer.truth_table(u);
        let conn = layer.unit_conn(u);
        for b in 0..layer.out_bits {
            let support = tt.bit_support(b);
            if support.len() > MAX_PLANE_SUPPORT {
                return None;
            }
            arity.push(support.len() as u8);
            tables.push(tt.reduced_bit_table(b, &support));
            for &v in &support {
                let f = v / layer.in_bits;
                let k = v % layer.in_bits;
                srcs.push(conn[f] * layer.in_bits as u32 + k as u32);
            }
        }
    }
    Some((arity, tables, srcs))
}

/// Lower `nl` into an [`ExecPlan`].  Pure function of the netlist and
/// the options — compiling the same content twice yields plans with
/// identical arenas, which is what makes [`PlanCache`] sound.
pub fn compile(nl: &Netlist, opts: PlanOptions) -> ExecPlan {
    let mut words: Vec<u64> = Vec::new();
    let mut conn: Vec<u32> = Vec::new();
    let mut dedup: HashMap<Vec<u64>, u32> = HashMap::new();
    let mut tables_total = 0usize;
    let mut layers = Vec::with_capacity(nl.layers.len());
    let mut prev_w = nl.n_in;
    for layer in &nl.layers {
        let entries = layer.entries_per_unit();
        let twords = entries.div_ceil(4);
        let conn_off = conn.len();
        conn.extend_from_slice(&layer.conn);
        let mut table_off = Vec::with_capacity(layer.w);
        for u in 0..layer.w {
            let mut packed = vec![0u64; twords];
            for (i, &c) in layer.unit_table(u).iter().enumerate() {
                packed[i >> 2] |= (c as u64) << ((i & 3) << 4);
            }
            tables_total += 1;
            table_off.push(intern(&mut words, &mut dedup, packed));
        }
        let shifts: Vec<u32> =
            (0..layer.fan_in).map(|f| (layer.in_bits * f) as u32).collect();
        let gather = GatherStep {
            w: layer.w,
            fan_in: layer.fan_in,
            in_bits: layer.in_bits,
            out_bits: layer.out_bits,
            prev_w,
            conn_off,
            table_off,
            shifts,
        };
        let bitplane = if opts.bitplane {
            reduce_planes(layer).map(|(arity, tables, srcs)| {
                let mut table_off = Vec::with_capacity(tables.len());
                for &t in &tables {
                    tables_total += 1;
                    table_off.push(intern(&mut words, &mut dedup, vec![t]));
                }
                let mut src_off = Vec::with_capacity(arity.len());
                let mut run = 0usize;
                for &a in &arity {
                    src_off.push((conn.len() + run) as u32);
                    run += a as usize;
                }
                conn.extend_from_slice(&srcs);
                BitPlaneStep { w: layer.w, out_bits: layer.out_bits,
                               arity, table_off, src_off }
            })
        } else {
            None
        };
        layers.push(PlanLayer { gather, bitplane });
        prev_w = layer.w;
    }
    let max_w = layers
        .iter()
        .map(|l| l.gather.w)
        .max()
        .unwrap_or(0)
        .max(nl.n_in);
    let max_planes = layers
        .iter()
        .map(|l| l.gather.w * l.gather.out_bits)
        .max()
        .unwrap_or(0)
        .max(nl.n_in * nl.in_bits);
    let tables_unique = dedup.len();
    ExecPlan {
        name: nl.name.clone(),
        n_in: nl.n_in,
        in_bits: nl.in_bits,
        out_width: nl.out_width(),
        out_bits: nl.out_bits(),
        key: plan_key(nl, opts),
        words: words.into(),
        conn: conn.into(),
        layers,
        max_w,
        max_planes,
        tables_total,
        tables_unique,
    }
}

/// Read one code out of a four-codes-per-word packed gather table.
#[inline(always)]
fn table_read(words: &[u64], toff: usize, addr: usize) -> u16 {
    ((words[toff + (addr >> 2)] >> ((addr & 3) << 4)) & 0xFFFF) as u16
}

/// Gather-kernel evaluation of units `[u0, u1)` from signal-major
/// producer codes; `dst` covers exactly that unit range.
fn gather_units(plan: &ExecPlan, g: &GatherStep, prev: &[u16],
                batch: usize, u0: usize, u1: usize, dst: &mut [u16]) {
    debug_assert_eq!(dst.len(), (u1 - u0) * batch);
    // hoist the arenas to plain slices once — the storage may be
    // mapped, and the Arena deref must stay out of the inner loops
    let words: &[u64] = &plan.words;
    let conn_arena: &[u32] = &plan.conn;
    for u in u0..u1 {
        let c0 = g.conn_off + u * g.fan_in;
        let conn = &conn_arena[c0..c0 + g.fan_in];
        let toff = g.table_off[u] as usize;
        let row = &mut dst[(u - u0) * batch..(u - u0 + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let mut addr = 0usize;
            for (f, &src) in conn.iter().enumerate() {
                addr |= (prev[src as usize * batch + b] as usize)
                    << g.shifts[f];
            }
            *slot = table_read(words, toff, addr);
        }
    }
}

/// Layer-0 gather fused with the input boundary: reads the request's
/// row-major codes directly (`x[b * n_in + src]`), skipping the
/// signal-major transpose the interpreted path pays.
fn gather_units_rowmajor(plan: &ExecPlan, g: &GatherStep, x: &[i32],
                         batch: usize, u0: usize, u1: usize,
                         dst: &mut [u16]) {
    debug_assert_eq!(dst.len(), (u1 - u0) * batch);
    let n_in = g.prev_w;
    let words: &[u64] = &plan.words;
    let conn_arena: &[u32] = &plan.conn;
    for u in u0..u1 {
        let c0 = g.conn_off + u * g.fan_in;
        let conn = &conn_arena[c0..c0 + g.fan_in];
        let toff = g.table_off[u] as usize;
        let row = &mut dst[(u - u0) * batch..(u - u0 + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let mut addr = 0usize;
            for (f, &src) in conn.iter().enumerate() {
                addr |= (x[b * n_in + src as usize] as usize)
                    << g.shifts[f];
            }
            *slot = table_read(words, toff, addr);
        }
    }
}

/// A wide word: `W` consecutive `u64`s of one packed bit-plane, so
/// `W * 64` samples per operation.  The ops are plain fixed-size array
/// loops — no intrinsics, no unsafe — which LLVM auto-vectorizes to
/// whatever the target offers (SSE2/AVX2/AVX-512/NEON); `W = 1`
/// compiles to exactly the scalar code the pre-wide kernel emitted.
#[derive(Clone, Copy)]
pub(crate) struct Lane<const W: usize>([u64; W]);

impl<const W: usize> Lane<W> {
    #[inline(always)]
    fn splat(v: u64) -> Lane<W> {
        Lane([v; W])
    }

    /// The first `W` words of `words`.
    #[inline(always)]
    fn load(words: &[u64]) -> Lane<W> {
        let mut a = [0u64; W];
        a.copy_from_slice(&words[..W]);
        Lane(a)
    }

    /// Write into the first `W` words of `out`.
    #[inline(always)]
    fn store(self, out: &mut [u64]) {
        out[..W].copy_from_slice(&self.0);
    }
}

impl<const W: usize> std::ops::Not for Lane<W> {
    type Output = Lane<W>;

    #[inline(always)]
    fn not(mut self) -> Lane<W> {
        for x in self.0.iter_mut() {
            *x = !*x;
        }
        self
    }
}

impl<const W: usize> std::ops::BitAnd for Lane<W> {
    type Output = Lane<W>;

    #[inline(always)]
    fn bitand(mut self, rhs: Lane<W>) -> Lane<W> {
        for (x, &y) in self.0.iter_mut().zip(rhs.0.iter()) {
            *x &= y;
        }
        self
    }
}

impl<const W: usize> std::ops::BitOr for Lane<W> {
    type Output = Lane<W>;

    #[inline(always)]
    fn bitor(mut self, rhs: Lane<W>) -> Lane<W> {
        for (x, &y) in self.0.iter_mut().zip(rhs.0.iter()) {
            *x |= y;
        }
        self
    }
}

/// Lane-wide twin of `eval_packed_rec`: the same Shannon expansion,
/// with every mux step `(!x & lo) | (x & hi)` running elementwise over
/// `W` words.  Identical cofactor order and identical per-word bit
/// operations make it bit-exact with the scalar kernel by construction.
#[inline(always)]
fn eval_packed_lanes<const W: usize>(table: u64, inputs: &[Lane<W>])
                                     -> Lane<W> {
    match inputs.len() {
        0 => Lane::splat(if table & 1 == 1 { !0u64 } else { 0u64 }),
        n => {
            let x = inputs[n - 1];
            let half = 1usize << (n - 1);
            let mask = (1u64 << half) - 1;
            let lo = eval_packed_lanes(table & mask, &inputs[..n - 1]);
            let hi =
                eval_packed_lanes((table >> half) & mask, &inputs[..n - 1]);
            (!x & lo) | (x & hi)
        }
    }
}

/// Bit-plane evaluation of units `[u0, u1)`; `out` covers exactly that
/// unit range (plane-major, `nwords` words per plane).  Width-generic:
/// each plane runs `nwords / W` full-lane evaluations over [`Lane<W>`]
/// registers, then the ragged tail — the trailing `nwords % W` words,
/// i.e. a batch that is not a multiple of `64 * W` — falls through to
/// the scalar Shannon kernel word by word.  `W = 1` *is* the scalar
/// path (every word is a full lane, the tail is empty).
fn bitplane_units<const W: usize>(plan: &ExecPlan, s: &BitPlaneStep,
                                  prev: &[u64], nwords: usize, u0: usize,
                                  u1: usize, out: &mut [u64]) {
    debug_assert_eq!(out.len(), (u1 - u0) * s.out_bits * nwords);
    let blocks = nwords / W;
    let mut lanes = [Lane::<W>::splat(0); MAX_PLANE_SUPPORT];
    let mut ins = [0u64; MAX_PLANE_SUPPORT];
    let words: &[u64] = &plan.words;
    let conn_arena: &[u32] = &plan.conn;
    let p0 = u0 * s.out_bits;
    for p in p0..u1 * s.out_bits {
        let a = s.arity[p] as usize;
        let off = s.src_off[p] as usize;
        let srcs = &conn_arena[off..off + a];
        let table = words[s.table_off[p] as usize];
        let dst = &mut out[(p - p0) * nwords..(p - p0 + 1) * nwords];
        for blk in 0..blocks {
            let wd = blk * W;
            for (i, &src) in srcs.iter().enumerate() {
                lanes[i] = Lane::load(&prev[src as usize * nwords + wd..]);
            }
            eval_packed_lanes(table, &lanes[..a]).store(&mut dst[wd..]);
        }
        for wd in blocks * W..nwords {
            for (i, &src) in srcs.iter().enumerate() {
                ins[i] = prev[src as usize * nwords + wd];
            }
            dst[wd] = eval_packed_rec(table, &ins[..a]);
        }
    }
}

/// Pack signal-major codes into bit-planes (64 samples/word).  The
/// target region must be pre-zeroed.
fn pack_codes(cur: &[u16], w: usize, bits: usize, batch: usize,
              nwords: usize, out: &mut [u64]) {
    for s in 0..w {
        let row = &cur[s * batch..(s + 1) * batch];
        for (b, &c) in row.iter().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            for k in 0..bits {
                out[(s * bits + k) * nwords + wd] |=
                    (((c >> k) & 1) as u64) << sh;
            }
        }
    }
}

/// Pack the request's row-major codes straight into bit-planes, fusing
/// the input transpose with the packing pass.  The target region must
/// be pre-zeroed.
fn pack_rowmajor(x: &[i32], w: usize, bits: usize, batch: usize,
                 nwords: usize, out: &mut [u64]) {
    for b in 0..batch {
        let (wd, sh) = (b / 64, b % 64);
        let row = &x[b * w..(b + 1) * w];
        for (s, &c) in row.iter().enumerate() {
            let c = c as u64;
            for k in 0..bits {
                out[(s * bits + k) * nwords + wd] |= ((c >> k) & 1) << sh;
            }
        }
    }
}

/// Inverse of [`pack_codes`]: reassemble signal-major codes.
fn unpack_codes(planes: &[u64], w: usize, bits: usize, batch: usize,
                nwords: usize, cur: &mut [u16]) {
    for s in 0..w {
        let row = &mut cur[s * batch..(s + 1) * batch];
        for (b, slot) in row.iter_mut().enumerate() {
            let (wd, sh) = (b / 64, b % 64);
            let mut c = 0u16;
            for k in 0..bits {
                c |= (((planes[(s * bits + k) * nwords + wd] >> sh) & 1)
                    as u16) << k;
            }
            *slot = c;
        }
    }
}

/// Executes an [`ExecPlan`] with private, reusable scratch, processing
/// `W` packed words — `W * 64` samples — per bit-plane table
/// evaluation.  One executor per thread; the plan itself is shared and
/// immutable.  [`PlanExecutor`] is the `W = 1` alias and the scalar
/// reference; all widths are bit-exact with it because they run the
/// same width-generic kernel (see the module doc).
///
/// Threading mirrors the interpreted simulator exactly — same chunk
/// math, same profitability floors, scoped or pooled per
/// [`SimOptions::mode`] — so every mode is bit-exact with every other.
pub struct WidePlanExecutor<const W: usize> {
    plan: Arc<ExecPlan>,
    opts: SimOptions,
    pool: Option<WorkerPool>,
    /// scratch: signal-major u16 codes (double-buffered)
    cur: Vec<u16>,
    nxt: Vec<u16>,
    /// scratch: packed bit-plane words (double-buffered)
    bits_cur: Vec<u64>,
    bits_nxt: Vec<u64>,
    /// scratch for the single-sample path
    one_a: Vec<u16>,
    one_b: Vec<u16>,
    /// times any scratch buffer had to grow (steady-state eval keeps
    /// this flat — the observable form of the zero-allocation contract)
    grows: usize,
}

/// The scalar (`W = 1`) executor — the bit-exactness reference every
/// wider lane is checked against, and the default small-batch backend.
pub type PlanExecutor = WidePlanExecutor<1>;

impl<const W: usize> WidePlanExecutor<W> {
    pub fn new(plan: Arc<ExecPlan>) -> WidePlanExecutor<W> {
        Self::with_options(plan, SimOptions::default())
    }

    pub fn with_options(plan: Arc<ExecPlan>, opts: SimOptions)
                        -> WidePlanExecutor<W> {
        WidePlanExecutor {
            plan,
            opts,
            pool: None,
            cur: Vec::new(),
            nxt: Vec::new(),
            bits_cur: Vec::new(),
            bits_nxt: Vec::new(),
            one_a: Vec::new(),
            one_b: Vec::new(),
            grows: 0,
        }
    }

    /// The plan this executor runs.
    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    /// This executor's lane width: packed words per bit-plane table
    /// evaluation (`W * 64` samples per op).
    pub const fn lane_width(&self) -> usize {
        W
    }

    /// The options this executor was built with.
    pub fn options(&self) -> SimOptions {
        self.opts
    }

    /// How many times a scratch buffer had to (re)allocate.  Flat across
    /// steady-state same-shape calls.
    pub fn buffer_grows(&self) -> usize {
        self.grows
    }

    fn wanted_pool_workers(&self) -> usize {
        match self.opts.mode {
            ThreadMode::Pooled if self.opts.threads > 1 => {
                self.opts.threads - 1
            }
            _ => 0,
        }
    }

    fn ensure_pool(&mut self) {
        if self.pool.is_none() {
            let want = self.wanted_pool_workers();
            if want > 0 {
                self.pool = Some(WorkerPool::new(want));
            }
        }
    }

    /// Change the worker-thread count; a resident pool of the wrong size
    /// is dropped and lazily recreated.
    pub fn set_threads(&mut self, threads: usize) {
        self.opts.threads = threads.max(1);
        let want = self.wanted_pool_workers();
        let have = self.pool.as_ref().map(|p| p.workers()).unwrap_or(0);
        if self.pool.is_some() && want != have {
            self.pool = None;
        }
    }

    /// Lend a pool in (or take the resident one out) — the same sharing
    /// protocol as `Simulator::set_pool`, used by server workers to run
    /// several models' executors on one set of parked threads.
    pub fn set_pool(&mut self, pool: Option<WorkerPool>)
                    -> Option<WorkerPool> {
        std::mem::replace(&mut self.pool, pool)
    }

    /// Row-major input codes -> row-major output codes (allocating
    /// convenience wrapper around [`Self::eval_batch_into`]).
    pub fn eval_batch(&mut self, x: &[i32], batch: usize) -> Vec<i32> {
        let mut out = Vec::new();
        self.eval_batch_into(x, batch, &mut out);
        out
    }

    /// Row-major input codes -> row-major output codes, written into
    /// `out` (cleared first).  With a capacity-retaining `out` and a
    /// stable batch shape this performs no heap allocation.
    pub fn eval_batch_into(&mut self, x: &[i32], batch: usize,
                           out: &mut Vec<i32>) {
        let plan = self.plan.clone();
        assert_eq!(x.len(), batch * plan.n_in,
                   "input len {} != batch {batch} * n_in {}", x.len(),
                   plan.n_in);
        out.clear();
        // empty batch: nothing to pack, no pool to wake
        if batch == 0 {
            return;
        }
        if plan.layers.is_empty() {
            out.extend_from_slice(x);
            return;
        }
        if batch == 1 {
            // transpose-free single-sample path
            self.eval_one_into(x, out);
            return;
        }
        self.ensure_pool();
        let nwords = batch.div_ceil(64);
        let use_bits = batch >= self.opts.min_bitplane_batch
            && plan.layers.iter().any(|l| l.bitplane.is_some());
        let cap_before = self.scratch_capacity();
        let mut cur = std::mem::take(&mut self.cur);
        let mut nxt = std::mem::take(&mut self.nxt);
        let mut bits_cur = std::mem::take(&mut self.bits_cur);
        let mut bits_nxt = std::mem::take(&mut self.bits_nxt);
        cur.resize(plan.max_w * batch, 0);
        nxt.resize(plan.max_w * batch, 0);
        if use_bits {
            bits_cur.resize(plan.max_planes * nwords, 0);
            bits_nxt.resize(plan.max_planes * nwords, 0);
        }
        let mut packed = false;
        for (l, pl) in plan.layers.iter().enumerate() {
            let g = &pl.gather;
            match &pl.bitplane {
                Some(bp) if use_bits => {
                    if !packed {
                        let n = g.prev_w * g.in_bits * nwords;
                        bits_cur[..n].fill(0);
                        if l == 0 {
                            pack_rowmajor(x, g.prev_w, g.in_bits, batch,
                                          nwords, &mut bits_cur[..n]);
                        } else {
                            pack_codes(&cur, g.prev_w, g.in_bits, batch,
                                       nwords, &mut bits_cur[..n]);
                        }
                        packed = true;
                    }
                    let planes = bp.w * bp.out_bits;
                    let floor = if self.pool.is_some() {
                        PAR_MIN_WORK_POOLED
                    } else {
                        PAR_MIN_WORK
                    };
                    let t = par_threads(self.opts.threads, bp.w,
                                        planes * nwords, floor);
                    let prev: &[u64] = &bits_cur;
                    let p: &ExecPlan = &plan;
                    chunked_units(
                        &mut bits_nxt[..planes * nwords], bp.w,
                        bp.out_bits * nwords, t, self.pool.as_mut(),
                        |u0, u1, dst| {
                            bitplane_units::<W>(p, bp, prev, nwords, u0,
                                                u1, dst)
                        },
                    );
                    std::mem::swap(&mut bits_cur, &mut bits_nxt);
                }
                _ => {
                    if packed {
                        unpack_codes(&bits_cur, g.prev_w, g.in_bits, batch,
                                     nwords, &mut cur[..g.prev_w * batch]);
                        packed = false;
                    }
                    let floor = if self.pool.is_some() {
                        PAR_MIN_WORK_POOLED_GATHER
                    } else {
                        PAR_MIN_WORK
                    };
                    let t = par_threads(self.opts.threads, g.w,
                                        g.w * batch, floor);
                    let p: &ExecPlan = &plan;
                    if l == 0 {
                        chunked_units(
                            &mut nxt[..g.w * batch], g.w, batch, t,
                            self.pool.as_mut(),
                            |u0, u1, dst| {
                                gather_units_rowmajor(p, g, x, batch, u0,
                                                      u1, dst)
                            },
                        );
                    } else {
                        let prev: &[u16] = &cur;
                        chunked_units(
                            &mut nxt[..g.w * batch], g.w, batch, t,
                            self.pool.as_mut(),
                            |u0, u1, dst| {
                                gather_units(p, g, prev, batch, u0, u1, dst)
                            },
                        );
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                }
            }
        }
        let ow = plan.out_width;
        if packed {
            unpack_codes(&bits_cur, ow, plan.out_bits, batch, nwords,
                         &mut cur[..ow * batch]);
        }
        out.resize(batch * ow, 0);
        for u in 0..ow {
            let row = &cur[u * batch..(u + 1) * batch];
            for (b, &c) in row.iter().enumerate() {
                out[b * ow + u] = c as i32;
            }
        }
        self.cur = cur;
        self.nxt = nxt;
        self.bits_cur = bits_cur;
        self.bits_nxt = bits_nxt;
        if self.scratch_capacity() > cap_before {
            self.grows += 1;
        }
    }

    /// Single-sample evaluation through the compiled gather program —
    /// no transpose, no packing, scratch reused across calls.
    pub fn eval_one_into(&mut self, x: &[i32], out: &mut Vec<i32>) {
        let plan = self.plan.clone();
        assert_eq!(x.len(), plan.n_in, "input len {} != n_in {}", x.len(),
                   plan.n_in);
        let cap_before =
            self.one_a.capacity() + self.one_b.capacity();
        let mut cur = std::mem::take(&mut self.one_a);
        let mut nxt = std::mem::take(&mut self.one_b);
        cur.clear();
        cur.extend(x.iter().map(|&c| c as u16));
        let words: &[u64] = &plan.words;
        let conn_arena: &[u32] = &plan.conn;
        for pl in &plan.layers {
            let g = &pl.gather;
            nxt.clear();
            nxt.resize(g.w, 0);
            for (u, slot) in nxt.iter_mut().enumerate() {
                let c0 = g.conn_off + u * g.fan_in;
                let conn = &conn_arena[c0..c0 + g.fan_in];
                let mut addr = 0usize;
                for (f, &src) in conn.iter().enumerate() {
                    addr |= (cur[src as usize] as usize) << g.shifts[f];
                }
                *slot = table_read(words, g.table_off[u] as usize, addr);
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        out.clear();
        out.extend(cur.iter().map(|&c| c as i32));
        self.one_a = cur;
        self.one_b = nxt;
        if self.one_a.capacity() + self.one_b.capacity() > cap_before {
            self.grows += 1;
        }
    }

    /// Allocating convenience wrapper around
    /// [`Self::eval_one_into`].
    pub fn eval_one(&mut self, x: &[i32]) -> Vec<i32> {
        let mut out = Vec::new();
        self.eval_one_into(x, &mut out);
        out
    }

    fn scratch_capacity(&self) -> usize {
        self.cur.capacity() + self.nxt.capacity()
            + self.bits_cur.capacity() + self.bits_nxt.capacity()
    }
}

/// The widest lane worth running on this CPU.  4-word (256-bit) lanes
/// are the portable default — they auto-vectorize well even on 128-bit
/// SIMD (two ops per step) and cost nothing scalar thanks to reduced
/// loop overhead; 8-word lanes only pay for themselves where 512-bit
/// registers exist.
fn widest_supported_lane() -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") {
            return 8;
        }
        4
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        4
    }
}

/// Resolve a [`LaneSelect`] to a concrete executor width.
///
/// An explicit request pins that width.  `Auto` consults the batch-size
/// hint first — below 256 samples a plane holds at most 4 packed words,
/// so wider lanes would run tail-only and the scalar reference is the
/// right backend — and otherwise probes the CPU
/// (`is_x86_feature_detected!`-style where available) for the widest
/// profitable lane.  A hint of 0 means "unknown / unbounded" and trusts
/// the probe.
pub fn select_backend(lanes: LaneSelect, batch_hint: usize) -> usize {
    if let Some(w) = lanes.fixed_width() {
        return w;
    }
    if batch_hint != 0 && batch_hint < 256 {
        return 1;
    }
    widest_supported_lane()
}

/// A width-erased [`WidePlanExecutor`]: the lane width is a const
/// generic (so each kernel is monomorphized and auto-vectorized), but
/// servers and CLIs choose the width at runtime ([`select_backend`]) —
/// this enum carries one executor of the chosen width behind a uniform
/// API.  Every variant runs the same plan bit-exactly; only throughput
/// differs.
pub enum LaneExecutor {
    W1(WidePlanExecutor<1>),
    W4(WidePlanExecutor<4>),
    W8(WidePlanExecutor<8>),
}

macro_rules! each_lane {
    ($self:expr, $ex:ident => $body:expr) => {
        match $self {
            LaneExecutor::W1($ex) => $body,
            LaneExecutor::W4($ex) => $body,
            LaneExecutor::W8($ex) => $body,
        }
    };
}

impl LaneExecutor {
    /// An executor of exactly `width` lanes.  Panics on widths outside
    /// {1, 4, 8} — widths are produced by [`select_backend`] or the
    /// validated `--lanes` flag, never free-form.
    pub fn for_width(width: usize, plan: Arc<ExecPlan>, opts: SimOptions)
                     -> LaneExecutor {
        match width {
            1 => LaneExecutor::W1(WidePlanExecutor::with_options(plan, opts)),
            4 => LaneExecutor::W4(WidePlanExecutor::with_options(plan, opts)),
            8 => LaneExecutor::W8(WidePlanExecutor::with_options(plan, opts)),
            w => panic!("unsupported lane width {w} (supported: 1, 4, 8)"),
        }
    }

    /// An executor at the width `opts.lanes` resolves to for
    /// `batch_hint` (see [`select_backend`]).
    pub fn select(plan: Arc<ExecPlan>, opts: SimOptions, batch_hint: usize)
                  -> LaneExecutor {
        Self::for_width(select_backend(opts.lanes, batch_hint), plan, opts)
    }

    /// The lane width this executor runs at.
    pub fn width(&self) -> usize {
        each_lane!(self, ex => ex.lane_width())
    }

    /// The plan this executor runs.
    pub fn plan(&self) -> &Arc<ExecPlan> {
        each_lane!(self, ex => ex.plan())
    }

    /// The options the executor was built with.
    pub fn options(&self) -> SimOptions {
        each_lane!(self, ex => ex.options())
    }

    /// See [`WidePlanExecutor::buffer_grows`].
    pub fn buffer_grows(&self) -> usize {
        each_lane!(self, ex => ex.buffer_grows())
    }

    /// See [`WidePlanExecutor::set_threads`].
    pub fn set_threads(&mut self, threads: usize) {
        each_lane!(self, ex => ex.set_threads(threads))
    }

    /// See [`WidePlanExecutor::set_pool`].
    pub fn set_pool(&mut self, pool: Option<WorkerPool>)
                    -> Option<WorkerPool> {
        each_lane!(self, ex => ex.set_pool(pool))
    }

    pub fn eval_batch(&mut self, x: &[i32], batch: usize) -> Vec<i32> {
        each_lane!(self, ex => ex.eval_batch(x, batch))
    }

    pub fn eval_batch_into(&mut self, x: &[i32], batch: usize,
                           out: &mut Vec<i32>) {
        each_lane!(self, ex => ex.eval_batch_into(x, batch, out))
    }

    pub fn eval_one(&mut self, x: &[i32]) -> Vec<i32> {
        each_lane!(self, ex => ex.eval_one(x))
    }

    pub fn eval_one_into(&mut self, x: &[i32], out: &mut Vec<i32>) {
        each_lane!(self, ex => ex.eval_one_into(x, out))
    }
}

/// Cache key: structural content hash mixed with the compile options.
/// Public because persistent cache files and artifact tooling name
/// plans by this key (`{key:016x}.plan` in a cache directory).
pub fn plan_key(nl: &Netlist, opts: PlanOptions) -> u64 {
    let h = nl.content_hash();
    if opts.bitplane {
        h
    } else {
        h ^ 0x9E37_79B9_7F4A_7C15
    }
}

/// Magic for a persistent plan-cache file: a checksummed container
/// around one plan image (see [`ExecPlan::write_image`]).  Distinct
/// from the `.nlb` magic so the two cannot be confused — a cache file
/// carries no netlist section and is only readable next to one.
pub const PLAN_FILE_MAGIC: [u8; 4] = *b"NLBP";
const PLAN_FILE_VERSION: u16 = 1;

fn plan_file_bytes(plan: &ExecPlan) -> Vec<u8> {
    let mut payload = Vec::new();
    plan.write_image(&mut payload);
    let mut out = Vec::with_capacity(24 + payload.len());
    out.extend_from_slice(&PLAN_FILE_MAGIC);
    format::put_u16(&mut out, PLAN_FILE_VERSION);
    format::put_u16(&mut out, 0); // reserved
    format::put_u64(&mut out, payload.len() as u64);
    format::put_u64(&mut out, format::fnv1a(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Parse a plan-cache file.  `src` carries the mapping when `bytes`
/// come from one — the 24-byte header plus the image's own 24-byte
/// prefix put both arenas at 8-byte file offsets, so the v1 cache
/// layout zero-copy-loads as is (no version bump needed; unlike `.nlb`
/// there is no variable-length field ahead of the image).
fn read_plan_file(bytes: &[u8], nl: &Netlist,
                  src: Option<&Arc<MappedFile>>) -> Result<ExecPlan> {
    if bytes.len() < 24 {
        bail!("truncated header: {} bytes, need 24", bytes.len());
    }
    if bytes[..4] != PLAN_FILE_MAGIC {
        bail!("bad magic (not a plan cache file)");
    }
    let mut h = ByteReader::new(&bytes[4..24]);
    let version = h.u16("version")?;
    if version != PLAN_FILE_VERSION {
        bail!("unsupported plan file version {version} (this build \
               reads version {PLAN_FILE_VERSION})");
    }
    let _reserved = h.u16("reserved")?;
    let payload_len = h.u64("payload length")?;
    let payload_hash = h.u64("payload checksum")?;
    let payload = &bytes[24..];
    if payload.len() as u64 != payload_len {
        bail!("payload is {} bytes but the header declares \
               {payload_len}", payload.len());
    }
    if format::fnv1a(payload) != payload_hash {
        bail!("payload checksum mismatch (file corrupt)");
    }
    let mut r = ByteReader::new(payload);
    let plan = ExecPlan::read_image(&mut r, nl, src.map(|m| (m, 24)))
        .context("plan image")?;
    if r.remaining() != 0 {
        bail!("{} trailing bytes after the plan image", r.remaining());
    }
    Ok(plan)
}

/// Content-addressed cache of compiled plans, shared across threads —
/// optionally backed by a directory of plan-image files so the cache
/// survives process restarts.
///
/// Keyed by [`Netlist::content_hash`] (structure only — the name is
/// excluded, so two identically-structured models share one plan) mixed
/// with [`PlanOptions`].  The server holds one per process: model
/// registration compiles once and every router worker executes the same
/// immutable `Arc<ExecPlan>`.  Compilation runs outside the map lock;
/// concurrent racers may both compile, the last insert wins (plans for
/// equal content are identical, so either result is correct).
///
/// With a cache directory ([`PlanCache::persistent`]) each compiled
/// plan is also written to `{key:016x}.plan` (atomically: temp file +
/// rename), and a cold lookup tries the file before compiling — that is
/// the cold-start path: a server restarting with N registered models
/// loads N plan images instead of recompiling N netlists
/// (`benches/coldstart` measures the ratio).  Disk is strictly a
/// fallback layer: every loaded image is re-validated against the
/// netlist (see [`ExecPlan::read_image`]), and any unreadable, corrupt
/// or stale file is logged, ignored and overwritten by a fresh
/// compile — a poisoned cache directory can cost time, never
/// correctness.
#[derive(Default)]
pub struct PlanCache {
    inner: Mutex<HashMap<u64, Arc<ExecPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    disk_hits: AtomicU64,
    dir: Option<PathBuf>,
    /// disable the zero-copy disk-hit path (`--no-mmap`); the default
    /// `false` means disk hits memory-map their `.plan` file and
    /// borrow the arenas
    no_mmap: bool,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache backed by `dir` (created if missing; creation failure
    /// is logged and each file operation then fails soft).
    pub fn persistent(dir: impl Into<PathBuf>) -> PlanCache {
        let dir = dir.into();
        if let Err(e) = std::fs::create_dir_all(&dir) {
            log::warn!("plan cache dir {}: {e}", dir.display());
        }
        PlanCache { dir: Some(dir), ..Default::default() }
    }

    /// Enable/disable memory-mapped disk hits (enabled by default).
    /// With mapping off — or on targets without mapping support — disk
    /// hits fall back to read-and-copy; results are identical either
    /// way, only load cost differs.
    pub fn set_mmap(&mut self, enabled: bool) {
        self.no_mmap = !enabled;
    }

    /// The backing directory, if this cache is persistent.
    pub fn cache_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    fn plan_path(&self, key: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("{key:016x}.plan")))
    }

    fn load_from_disk(&self, key: u64, nl: &Netlist)
                      -> Option<Arc<ExecPlan>> {
        let path = self.plan_path(key)?;
        // a missing file is the expected cold-cache case — stay quiet
        if std::fs::metadata(&path).is_err() {
            return None;
        }
        let parsed = if self.no_mmap {
            let bytes = std::fs::read(&path).ok()?;
            read_plan_file(&bytes, nl, None)
        } else {
            match MappedFile::open(&path) {
                Ok(map) => read_plan_file(map.bytes(), nl, Some(&map)),
                // unsupported target or a racing delete: copy instead
                Err(_) => {
                    let bytes = std::fs::read(&path).ok()?;
                    read_plan_file(&bytes, nl, None)
                }
            }
        };
        match parsed {
            Ok(p) if p.key() == key => Some(Arc::new(p)),
            Ok(p) => {
                log::warn!("plan cache {}: image key {:016x} does not \
                            match the file name (recompiling)",
                           path.display(), p.key());
                None
            }
            Err(e) => {
                log::warn!("plan cache {}: {e:#} (recompiling)",
                           path.display());
                None
            }
        }
    }

    fn store_to_disk(&self, key: u64, plan: &ExecPlan) {
        let Some(path) = self.plan_path(key) else { return };
        if let Some(d) = &self.dir {
            let _ = std::fs::create_dir_all(d);
        }
        if let Err(e) = format::write_atomic(&path, &plan_file_bytes(plan))
        {
            log::warn!("plan cache write {}: {e}", path.display());
        }
    }

    /// The plan for `nl`: from memory, else from the cache directory,
    /// else compiled (and then persisted).
    pub fn get_or_compile(&self, nl: &Netlist, opts: PlanOptions)
                          -> Arc<ExecPlan> {
        let key = plan_key(nl, opts);
        let hit = self.inner.lock().unwrap().get(&key).cloned();
        if let Some(p) = hit {
            // 64-bit keys can collide in principle; the hit is reused
            // only after a full content comparison (dims, wiring, every
            // table entry), so a collision degrades to a fresh compile,
            // never a wrong plan.  The cached entry is left alone.
            if p.matches(nl) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return p;
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Arc::new(compile(nl, opts));
        }
        if let Some(p) = self.load_from_disk(key, nl) {
            self.disk_hits.fetch_add(1, Ordering::Relaxed);
            self.inner.lock().unwrap().insert(key, p.clone());
            return p;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(compile(nl, opts));
        self.store_to_disk(key, &plan);
        self.inner.lock().unwrap().insert(key, plan.clone());
        plan
    }

    /// Insert a plan that arrived with an artifact (an `.nlb` plan
    /// image) instead of being compiled here.  Returns the resident
    /// plan for its key — the already-cached one if equivalent content
    /// is resident (so identical artifacts share one plan), else the
    /// admitted plan.  Re-verified against `nl` first: a mismatched
    /// pair is an error, never a poisoned cache.
    pub fn admit(&self, nl: &Netlist, plan: Arc<ExecPlan>)
                 -> Result<Arc<ExecPlan>> {
        if !plan.matches(nl) {
            bail!("plan does not match the netlist it was admitted \
                   for");
        }
        let key = plan.key();
        let resident = {
            let mut map = self.inner.lock().unwrap();
            match map.get(&key) {
                Some(p) if p.matches(nl) => p.clone(),
                _ => {
                    map.insert(key, plan.clone());
                    plan
                }
            }
        };
        // seed the directory so a restart cold-loads artifact plans too
        if let Some(path) = self.plan_path(key) {
            if std::fs::metadata(&path).is_err() {
                self.store_to_disk(key, &resident);
            }
        }
        Ok(resident)
    }

    /// Distinct plans resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from memory.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that compiled.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Lookups answered by loading a plan image from the cache
    /// directory (always 0 for a non-persistent cache).
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    fn assert_plan_matches_eval_one<const W: usize>(
        nl: &Netlist, ex: &mut WidePlanExecutor<W>, seed: u64,
        batch: usize) {
        let x = random_inputs(seed, nl, batch);
        let got = ex.eval_batch(&x, batch);
        let ow = nl.out_width();
        assert_eq!(got.len(), batch * ow);
        for b in 0..batch {
            let one =
                nl.eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..], "row {b}");
        }
    }

    #[test]
    fn compiled_plan_matches_reference_walk() {
        let nl = random_netlist(7, 16, 2, &[(12, 3, 2), (6, 2, 1), (3, 2, 4)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        assert_eq!(plan.n_in(), 16);
        assert_eq!(plan.out_width(), 3);
        let mut ex = PlanExecutor::new(plan);
        // batch 1 (single-sample path), a gather-regime batch, a packed
        // batch that is not a multiple of 64
        for (seed, batch) in [(1u64, 1usize), (2, 9), (3, 130)] {
            assert_plan_matches_eval_one(&nl, &mut ex, seed, batch);
        }
    }

    #[test]
    fn compiled_plan_matches_on_reducible_netlists() {
        // wide raw address, reduced support: the bit-plane steps engage
        let nl = random_reducible_netlist(
            19, 12, 2, &[(8, 4, 2), (4, 4, 2), (2, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        assert_eq!(plan.bitplane_layers(), 3);
        assert_eq!(plan.layer_kernels(),
                   vec![KernelChoice::BitPlane; 3]);
        let mut ex = PlanExecutor::new(plan);
        for (seed, batch) in [(4u64, 1usize), (5, 31), (6, 64), (7, 200)] {
            assert_plan_matches_eval_one(&nl, &mut ex, seed, batch);
        }
    }

    #[test]
    fn wide_executors_match_scalar_on_ragged_batches() {
        let nl = random_reducible_netlist(
            19, 12, 2, &[(8, 4, 2), (4, 4, 2), (2, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut w1: WidePlanExecutor<1> = WidePlanExecutor::new(plan.clone());
        let mut w4: WidePlanExecutor<4> = WidePlanExecutor::new(plan.clone());
        let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan);
        assert_eq!((w1.lane_width(), w4.lane_width(), w8.lane_width()),
                   (1, 4, 8));
        // single sample, sub-word, one word, lane-misaligned word
        // counts, exact lane multiples, word + sub-word tails
        for (seed, batch) in [(1u64, 1usize), (2, 33), (3, 64), (4, 65),
                              (5, 256), (6, 300), (7, 511), (8, 64 * 8),
                              (9, 64 * 8 + 1), (10, 64 * 12 + 17)] {
            let x = random_inputs(seed, &nl, batch);
            let want = w1.eval_batch(&x, batch);
            assert_eq!(w4.eval_batch(&x, batch), want, "W4 batch {batch}");
            assert_eq!(w8.eval_batch(&x, batch), want, "W8 batch {batch}");
        }
        assert_plan_matches_eval_one(&nl, &mut w4, 11, 300);
        assert_plan_matches_eval_one(&nl, &mut w8, 12, 300);
    }

    #[test]
    fn wide_threaded_executors_are_bit_exact() {
        let nl = random_reducible_netlist(
            37, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut serial: WidePlanExecutor<4> =
            WidePlanExecutor::new(plan.clone());
        let mut pooled: WidePlanExecutor<4> = WidePlanExecutor::with_options(
            plan, SimOptions { threads: 4, ..Default::default() });
        for (seed, batch) in [(1u64, 600usize), (2, 2100)] {
            let x = random_inputs(seed, &nl, batch);
            assert_eq!(pooled.eval_batch(&x, batch),
                       serial.eval_batch(&x, batch), "batch {batch}");
        }
        assert_plan_matches_eval_one(&nl, &mut pooled, 9, 2100);
    }

    #[test]
    fn select_backend_resolves_widths() {
        assert_eq!(select_backend(LaneSelect::W1, 0), 1);
        assert_eq!(select_backend(LaneSelect::W4, 0), 4);
        assert_eq!(select_backend(LaneSelect::W8, 0), 8);
        // explicit widths ignore the batch hint
        assert_eq!(select_backend(LaneSelect::W8, 1), 8);
        // small batch hints pin scalar under Auto
        assert_eq!(select_backend(LaneSelect::Auto, 1), 1);
        assert_eq!(select_backend(LaneSelect::Auto, 255), 1);
        // large or unknown batches probe the CPU for a wide lane
        for hint in [0usize, 256, 4096] {
            let w = select_backend(LaneSelect::Auto, hint);
            assert!(w == 4 || w == 8, "auto resolved to {w}");
        }
    }

    #[test]
    fn lane_executor_is_bit_exact_across_widths() {
        let nl = random_reducible_netlist(
            61, 16, 2, &[(24, 3, 2), (12, 2, 2), (4, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut w1 =
            LaneExecutor::for_width(1, plan.clone(), SimOptions::default());
        assert_eq!(w1.width(), 1);
        for width in [4usize, 8] {
            let mut ex = LaneExecutor::for_width(
                width, plan.clone(), SimOptions::default());
            assert_eq!(ex.width(), width);
            assert!(Arc::ptr_eq(ex.plan(), &plan));
            for (seed, batch) in [(1u64, 1usize), (2, 130), (3, 1000)] {
                let x = random_inputs(seed, &nl, batch);
                assert_eq!(ex.eval_batch(&x, batch),
                           w1.eval_batch(&x, batch),
                           "width {width} batch {batch}");
            }
            let x = random_inputs(9, &nl, 1);
            assert_eq!(ex.eval_one(&x), w1.eval_one(&x));
        }
        // select() honors pinned widths and the small-batch hint
        let pinned = LaneExecutor::select(
            plan.clone(),
            SimOptions { lanes: LaneSelect::W4, ..Default::default() }, 0);
        assert_eq!(pinned.width(), 4);
        let small =
            LaneExecutor::select(plan.clone(), SimOptions::default(), 64);
        assert_eq!(small.width(), 1);
        let auto = LaneExecutor::select(plan, SimOptions::default(), 0);
        assert!(auto.width() >= 4);
    }

    #[test]
    #[should_panic(expected = "unsupported lane width")]
    fn lane_executor_rejects_unknown_widths() {
        let nl = random_netlist(31, 6, 2, &[(4, 2, 2)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let _ = LaneExecutor::for_width(2, plan, SimOptions::default());
    }

    #[test]
    fn wide_steady_state_eval_does_not_grow_buffers() {
        let nl = random_reducible_netlist(
            41, 16, 2, &[(24, 3, 2), (12, 2, 2), (4, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut ex: WidePlanExecutor<4> = WidePlanExecutor::new(plan);
        let mut out = Vec::new();
        let x = random_inputs(3, &nl, 1030);
        ex.eval_batch_into(&x, 1030, &mut out);
        let after_first = ex.buffer_grows();
        for rep in 0..5 {
            ex.eval_batch_into(&x, 1030, &mut out);
            assert_eq!(ex.buffer_grows(), after_first,
                       "rep {rep} reallocated scratch");
        }
    }

    #[test]
    fn gather_only_plan_matches() {
        let nl = random_reducible_netlist(
            23, 10, 2, &[(8, 3, 2), (4, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions { bitplane: false }));
        assert_eq!(plan.bitplane_layers(), 0);
        let mut ex = PlanExecutor::new(plan);
        for (seed, batch) in [(8u64, 1usize), (9, 100)] {
            assert_plan_matches_eval_one(&nl, &mut ex, seed, batch);
        }
    }

    #[test]
    fn threaded_executors_are_bit_exact() {
        let nl = random_reducible_netlist(
            37, 24, 2, &[(64, 3, 2), (48, 2, 3), (16, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut pooled = PlanExecutor::with_options(
            plan.clone(),
            SimOptions { threads: 4, mode: ThreadMode::Pooled,
                         ..Default::default() },
        );
        let mut scoped = PlanExecutor::with_options(
            plan,
            SimOptions { threads: 4, mode: ThreadMode::Scoped,
                         ..Default::default() },
        );
        for (seed, batch) in [(1u64, 33usize), (2, 600), (3, 2100)] {
            let x = random_inputs(seed, &nl, batch);
            assert_eq!(pooled.eval_batch(&x, batch),
                       scoped.eval_batch(&x, batch), "batch {batch}");
        }
        assert_plan_matches_eval_one(&nl, &mut pooled, 9, 2100);
    }

    #[test]
    fn table_arena_dedup_shares_identical_tables() {
        // four units, all the same XOR table, two distinct wirings; one
        // second-layer unit reusing XOR again.  Gather tables pack into
        // one arena word, every plane table reduces to the same word —
        // so the arena holds exactly two distinct entries.
        let xor = vec![0u16, 1, 1, 0];
        let l0 = LayerSpec {
            w: 4, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 1, 2, 3, 0, 2, 1, 3],
            tables: [xor.clone(), xor.clone(), xor.clone(), xor.clone()]
                .concat(),
        };
        let l1 = LayerSpec {
            w: 1, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 3],
            tables: xor,
        };
        let nl = Netlist { name: "sharing".into(), n_in: 4, in_bits: 1,
                           layers: vec![l0, l1] };
        nl.validate().unwrap();
        let plan = compile(&nl, PlanOptions::default());
        let st = plan.stats();
        // 5 gather tables + 5 plane tables compiled...
        assert_eq!(st.tables_total, 10);
        // ...but only one distinct gather word and one distinct plane
        // word survive dedup
        assert_eq!(st.tables_unique, 2, "stats: {}", st.summary());
        assert_eq!(st.table_words, 2);
        assert_eq!(st.planes, 5);
        // and the shared-table plan still evaluates correctly
        let mut ex = PlanExecutor::new(Arc::new(plan));
        assert_plan_matches_eval_one(&nl, &mut ex, 11, 70);
    }

    #[test]
    fn dedup_keeps_distinct_tables_distinct() {
        let nl = random_netlist(29, 8, 1, &[(4, 2, 2), (2, 2, 2)]);
        let plan = compile(&nl, PlanOptions::default());
        let st = plan.stats();
        assert!(st.tables_unique <= st.tables_total);
        assert!(st.tables_unique >= 1);
        // unique count is bounded below by the number of distinct
        // gather-table contents
        let mut distinct = std::collections::HashSet::new();
        for layer in &nl.layers {
            for u in 0..layer.w {
                distinct.insert(layer.unit_table(u).to_vec());
            }
        }
        assert!(st.tables_unique >= distinct.len());
    }

    #[test]
    fn empty_batch_returns_empty_without_work() {
        let nl = random_netlist(31, 6, 2, &[(4, 2, 2)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        // threads > 1: the early return must fire before any pool is
        // created or woken
        let mut ex = PlanExecutor::with_options(
            plan, SimOptions { threads: 4, ..Default::default() });
        assert!(ex.eval_batch(&[], 0).is_empty());
        let mut out = vec![1, 2, 3];
        ex.eval_batch_into(&[], 0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn layerless_netlist_is_identity() {
        let nl = Netlist { name: "empty".into(), n_in: 3, in_bits: 2,
                           layers: vec![] };
        nl.validate().unwrap();
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        assert_eq!(plan.out_width(), 3);
        let mut ex = PlanExecutor::new(plan);
        let x = vec![1, 2, 3, 0, 1, 2];
        assert_eq!(ex.eval_batch(&x, 2), x);
        assert_eq!(ex.eval_one(&[3, 1, 0]), vec![3, 1, 0]);
    }

    #[test]
    fn steady_state_eval_does_not_grow_buffers() {
        let nl = random_reducible_netlist(
            41, 16, 2, &[(24, 3, 2), (12, 2, 2), (4, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut ex = PlanExecutor::new(plan);
        let mut out = Vec::new();
        for batch in [1usize, 64, 200] {
            let x = random_inputs(batch as u64, &nl, batch);
            ex.eval_batch_into(&x, batch, &mut out);
            let after_first = ex.buffer_grows();
            for rep in 0..5 {
                ex.eval_batch_into(&x, batch, &mut out);
                assert_eq!(ex.buffer_grows(), after_first,
                           "batch {batch} rep {rep} reallocated scratch");
            }
        }
        // smaller batches after the largest: capacity already covers
        // them, so no growth at all
        let before = ex.buffer_grows();
        for batch in [1usize, 64, 200] {
            let x = random_inputs(batch as u64, &nl, batch);
            ex.eval_batch_into(&x, batch, &mut out);
        }
        assert_eq!(ex.buffer_grows(), before);
    }

    #[test]
    fn plan_cache_shares_and_counts() {
        let cache = PlanCache::new();
        let nl = random_netlist(43, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
        let a = cache.get_or_compile(&nl, PlanOptions::default());
        let b = cache.get_or_compile(&nl, PlanOptions::default());
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
        // same structure under a different name still hits (the name is
        // not part of the content hash)
        let mut renamed = nl.clone();
        renamed.name = "other".into();
        let c = cache.get_or_compile(&renamed, PlanOptions::default());
        assert!(Arc::ptr_eq(&a, &c));
        // different options compile a different plan
        let d = cache.get_or_compile(&nl, PlanOptions { bitplane: false });
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(cache.len(), 2);
        // different content compiles a different plan
        let nl2 = random_netlist(44, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
        let e = cache.get_or_compile(&nl2, PlanOptions::default());
        assert!(!Arc::ptr_eq(&a, &e));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn content_hash_tracks_structure_not_name() {
        let nl = random_netlist(47, 8, 1, &[(4, 2, 2)]);
        let mut renamed = nl.clone();
        renamed.name = "x".into();
        assert_eq!(nl.content_hash(), renamed.content_hash());
        let mut touched = nl.clone();
        touched.layers[0].tables[1] ^= 1;
        assert_ne!(nl.content_hash(), touched.content_hash());
        let mut rewired = nl.clone();
        rewired.layers[0].conn[0] ^= 1;
        assert_ne!(nl.content_hash(), rewired.content_hash());
    }

    #[test]
    fn set_threads_and_pool_lending() {
        let nl = random_reducible_netlist(
            53, 24, 2, &[(64, 3, 2), (32, 2, 2)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let mut ex = PlanExecutor::new(plan);
        assert_plan_matches_eval_one(&nl, &mut ex, 1, 64);
        ex.set_threads(4);
        assert_plan_matches_eval_one(&nl, &mut ex, 2, 2100);
        // lend an external pool, as server workers do
        let prev = ex.set_pool(Some(WorkerPool::new(2)));
        assert_plan_matches_eval_one(&nl, &mut ex, 3, 2100);
        let lent = ex.set_pool(prev);
        assert!(lent.is_some());
        ex.set_threads(1);
        assert_plan_matches_eval_one(&nl, &mut ex, 4, 100);
    }

    /// Fresh per-test directory under the system temp dir (tests run
    /// in-process-parallel, so the name carries a tag and the pid).
    fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("nid_plan_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn persistent_cache_reloads_across_instances() {
        let dir = temp_cache_dir("reload");
        let nl = random_reducible_netlist(
            61, 10, 2, &[(8, 3, 2), (4, 2, 2)], 6);
        {
            let cache = PlanCache::persistent(&dir);
            let p = cache.get_or_compile(&nl, PlanOptions::default());
            assert_eq!((cache.misses(), cache.disk_hits()), (1, 0));
            let q = cache.get_or_compile(&nl, PlanOptions::default());
            assert!(Arc::ptr_eq(&p, &q));
            assert_eq!(cache.hits(), 1);
        }
        // a fresh cache over the same directory models a process
        // restart: the lookup is answered from disk, not recompiled
        let cache = PlanCache::persistent(&dir);
        let p = cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!((cache.misses(), cache.disk_hits()), (0, 1));
        let mut ex = PlanExecutor::new(p);
        assert_plan_matches_eval_one(&nl, &mut ex, 5, 80);
        // second lookup hits memory, not disk
        cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!((cache.hits(), cache.disk_hits()), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_cache_separates_options() {
        let dir = temp_cache_dir("opts");
        let nl = random_reducible_netlist(63, 8, 2, &[(6, 3, 2)], 6);
        {
            let cache = PlanCache::persistent(&dir);
            cache.get_or_compile(&nl, PlanOptions::default());
            cache.get_or_compile(&nl, PlanOptions { bitplane: false });
            assert_eq!(cache.misses(), 2);
        }
        let cache = PlanCache::persistent(&dir);
        let a = cache.get_or_compile(&nl, PlanOptions::default());
        let b = cache.get_or_compile(&nl, PlanOptions { bitplane: false });
        assert_eq!((cache.misses(), cache.disk_hits()), (0, 2));
        assert!(a.bitplane_layers() > 0);
        assert_eq!(b.bitplane_layers(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_cache_tolerates_corrupt_files() {
        let dir = temp_cache_dir("corrupt");
        let nl = random_netlist(67, 8, 1, &[(6, 3, 2)]);
        let key = plan_key(&nl, PlanOptions::default());
        {
            let cache = PlanCache::persistent(&dir);
            cache.get_or_compile(&nl, PlanOptions::default());
        }
        let path = dir.join(format!("{key:016x}.plan"));
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        // the corrupt file is detected, ignored and overwritten by the
        // recompile — never served
        let cache = PlanCache::persistent(&dir);
        let p = cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!((cache.misses(), cache.disk_hits()), (1, 0));
        assert!(p.matches(&nl));
        let cache2 = PlanCache::persistent(&dir);
        cache2.get_or_compile(&nl, PlanOptions::default());
        assert_eq!(cache2.disk_hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn admit_shares_validates_and_persists() {
        let dir = temp_cache_dir("admit");
        let nl = random_netlist(71, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        {
            let cache = PlanCache::persistent(&dir);
            let a = cache.admit(&nl, plan.clone()).unwrap();
            assert!(Arc::ptr_eq(&a, &plan));
            // a second identical artifact shares the resident plan
            let b = cache
                .admit(&nl, Arc::new(compile(&nl, PlanOptions::default())))
                .unwrap();
            assert!(Arc::ptr_eq(&b, &plan));
            // a mismatched pair is rejected
            let other = random_netlist(72, 8, 1, &[(6, 3, 2), (3, 2, 2)]);
            assert!(cache.admit(&other, plan.clone()).is_err());
            // get_or_compile now hits memory
            let c = cache.get_or_compile(&nl, PlanOptions::default());
            assert!(Arc::ptr_eq(&c, &plan));
            assert_eq!((cache.hits(), cache.misses()), (1, 0));
        }
        // admit seeded the directory: a restart cold-loads from disk
        let cache = PlanCache::persistent(&dir);
        cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!((cache.misses(), cache.disk_hits()), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistent_cache_disk_hits_are_mapped() {
        let dir = temp_cache_dir("mmap");
        let nl = random_reducible_netlist(
            77, 10, 2, &[(8, 3, 2), (4, 2, 2)], 6);
        {
            let cache = PlanCache::persistent(&dir);
            let p = cache.get_or_compile(&nl, PlanOptions::default());
            assert!(!p.is_mapped(),
                    "freshly compiled plans own their arenas");
        }
        let cache = PlanCache::persistent(&dir);
        let p = cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!(cache.disk_hits(), 1);
        if cfg!(all(unix, target_pointer_width = "64",
                    target_endian = "little"))
        {
            assert!(p.is_mapped(),
                    "disk hit should borrow the mapped .plan file");
        }
        let mut ex = PlanExecutor::new(p);
        assert_plan_matches_eval_one(&nl, &mut ex, 13, 90);
        // the escape hatch copies instead; identical results
        let mut copying = PlanCache::persistent(&dir);
        copying.set_mmap(false);
        let q = copying.get_or_compile(&nl, PlanOptions::default());
        assert_eq!(copying.disk_hits(), 1);
        assert!(!q.is_mapped());
        let mut exq = PlanExecutor::new(q);
        assert_plan_matches_eval_one(&nl, &mut exq, 14, 90);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mapped_plans_are_bit_exact_at_all_lane_widths() {
        let dir = temp_cache_dir("mmap_lanes");
        let nl = random_reducible_netlist(
            79, 12, 2, &[(8, 4, 2), (4, 4, 2), (2, 2, 2)], 6);
        {
            let cache = PlanCache::persistent(&dir);
            cache.get_or_compile(&nl, PlanOptions::default());
        }
        let cache = PlanCache::persistent(&dir);
        let p = cache.get_or_compile(&nl, PlanOptions::default());
        assert_eq!(cache.disk_hits(), 1);
        let mut w1: WidePlanExecutor<1> = WidePlanExecutor::new(p.clone());
        let mut w4: WidePlanExecutor<4> = WidePlanExecutor::new(p.clone());
        let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(p);
        // single-sample path, gather regime, packed regime with a
        // ragged lane tail — all against the interpreted reference
        for (seed, batch) in [(1u64, 1usize), (2, 130), (3, 64 * 8 + 9)] {
            let x = random_inputs(seed, &nl, batch);
            let want = w1.eval_batch(&x, batch);
            let ow = nl.out_width();
            for b in 0..batch {
                let one = nl
                    .eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])
                    .unwrap();
                assert_eq!(&want[b * ow..(b + 1) * ow], &one[..],
                           "scalar-on-mapped row {b}");
            }
            assert_eq!(w4.eval_batch(&x, batch), want, "W4 batch {batch}");
            assert_eq!(w8.eval_batch(&x, batch), want, "W8 batch {batch}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn admit_works_without_a_directory() {
        let cache = PlanCache::new();
        let nl = random_netlist(73, 6, 1, &[(4, 2, 1)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let a = cache.admit(&nl, plan.clone()).unwrap();
        assert!(Arc::ptr_eq(&a, &plan));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.disk_hits(), 0);
    }
}
