//! L-LUT netlist: the hardware-level view of a trained NeuraLUT-Assemble
//! model, and its bit-exact simulator.
//!
//! A netlist is a feed-forward sequence of L-LUT layers; layer `l` has
//! `w` units, each reading `fan_in` producer signals (by index into the
//! previous layer's outputs, or the primary inputs for `l = 0`) and
//! emitting an `out_bits`-bit code.  This mirrors exactly what the RTL
//! emitter writes and what the Vivado flow would synthesize, so simulating
//! it *is* simulating the FPGA design at the value level.
//!
//! The simulator is the L3 serving hot path (see `benches/netlist_hotpath`
//! and EXPERIMENTS.md §Hot path): `eval_batch` uses precomputed address
//! strides, and a bit-plane kernel evaluates every layer whose per-output-
//! bit support fits a physical LUT — boolean *and* multi-bit — 64 samples
//! per word, optionally chunked across worker threads.  Because the
//! structure is static, the default execution model goes one step
//! further: [`compile`] flattens a netlist into an arena-backed
//! [`ExecPlan`] (shared tables deduplicated, CSR connections, static
//! schedule) that [`PlanExecutor`]s run with zero steady-state
//! allocation, cached across consumers by content hash ([`PlanCache`]).
//! The executor core is width-polymorphic ([`WidePlanExecutor`]):
//! wide lanes evaluate 4 or 8 packed words — up to 512 samples — per
//! table operation, selected at runtime ([`select_backend`],
//! [`LaneSelect`]) and bit-exact with the scalar reference by
//! construction.
//!
//! A netlist is also an *artifact*: [`format`](self) defines `.nlb`,
//! the versioned on-disk representation (header + layer sections +
//! optional compiled-plan image), written identically by the python
//! exporter — so "get me a runnable model" means mapping a file, and
//! config-driven synthesis is just one producer of such files.

mod format;
mod mapped;
mod opt;
mod plan;
mod sim;

pub use format::{load_nlb, load_nlb_mapped, read_nlb, read_nlb_mapped,
                 save_nlb, write_nlb, NlbModel, NLB_MAGIC, NLB_VERSION};
pub(crate) use format::fnv1a;
pub use mapped::{Arena, MappedFile};
pub use opt::{optimize, ConstantFold, Cse, DeadLogic, OptLevel,
              OptReport, Pass, PassDelta, PassManager};
pub use plan::{compile, plan_key, select_backend, ExecPlan, LaneExecutor,
               PlanCache, PlanExecutor, PlanOptions, PlanStats,
               WidePlanExecutor, PLAN_FILE_MAGIC};
pub use sim::{eval_packed, BitPlaneLayer, KernelChoice, LaneSelect,
              SimOptions, Simulator, ThreadMode, WorkerPool,
              MAX_PLANE_SUPPORT};

use anyhow::{bail, Context, Result};

use crate::luts::TruthTable;

/// Upper bound on a unit's address width (`in_bits * fan_in`).  Real
/// designs stay far below it (a 2^24-entry table is already 32 MiB);
/// the cap exists so corrupt or adversarial inputs fail validation with
/// a clear error instead of overflowing the `1 << addr_bits` shift in
/// [`LayerSpec::entries_per_unit`].
pub const MAX_ADDR_BITS: usize = 24;

/// One layer of the netlist.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub w: usize,
    pub fan_in: usize,
    pub in_bits: usize,
    pub out_bits: usize,
    /// `w * fan_in` producer indices, unit-major.
    pub conn: Vec<u32>,
    /// `w * 2^(in_bits*fan_in)` table entries, unit-major.
    pub tables: Vec<u16>,
}

impl LayerSpec {
    pub fn entries_per_unit(&self) -> usize {
        1usize << (self.in_bits * self.fan_in)
    }

    pub fn unit_table(&self, u: usize) -> &[u16] {
        let t = self.entries_per_unit();
        &self.tables[u * t..(u + 1) * t]
    }

    pub fn unit_conn(&self, u: usize) -> &[u32] {
        &self.conn[u * self.fan_in..(u + 1) * self.fan_in]
    }

    /// View unit `u` as a `TruthTable` (for mapping / RTL / analysis).
    pub fn truth_table(&self, u: usize) -> TruthTable {
        TruthTable::new(self.fan_in, self.in_bits, self.out_bits,
                        self.unit_table(u).to_vec())
            .expect("layer invariants guarantee a valid table")
    }
}

/// A complete LUT netlist.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub name: String,
    pub n_in: usize,
    pub in_bits: usize,
    pub layers: Vec<LayerSpec>,
}

impl Netlist {
    pub fn validate(&self) -> Result<()> {
        let mut prev_w = self.n_in;
        let mut prev_bits = self.in_bits;
        for (l, layer) in self.layers.iter().enumerate() {
            // bound the address width *before* anything shifts by it:
            // entries_per_unit computes 1 << (in_bits * fan_in), which
            // overflows usize on adversarial/corrupt inputs
            let addr_bits = layer.in_bits.saturating_mul(layer.fan_in);
            if addr_bits > MAX_ADDR_BITS {
                bail!("layer {l}: address width {addr_bits} bits \
                       (in_bits {} * fan_in {}) exceeds the \
                       {MAX_ADDR_BITS}-bit cap",
                      layer.in_bits, layer.fan_in);
            }
            if layer.out_bits == 0 || layer.out_bits > 16 {
                bail!("layer {l}: out_bits {} outside 1..=16 \
                       (tables store u16 codes)", layer.out_bits);
            }
            if layer.conn.len() != layer.w * layer.fan_in {
                bail!("layer {l}: conn len mismatch");
            }
            if layer.tables.len() != layer.w * layer.entries_per_unit() {
                bail!("layer {l}: tables len mismatch");
            }
            if layer.in_bits != prev_bits {
                bail!("layer {l}: in_bits {} != producer bits {prev_bits}",
                      layer.in_bits);
            }
            if let Some(&c) = layer.conn.iter().find(|&&c| c as usize >= prev_w) {
                bail!("layer {l}: conn index {c} out of range (prev width {prev_w})");
            }
            let max = ((1u32 << layer.out_bits) - 1) as u16;
            if layer.tables.iter().any(|&e| e > max) {
                bail!("layer {l}: table entry exceeds out_bits");
            }
            prev_w = layer.w;
            prev_bits = layer.out_bits;
        }
        Ok(())
    }

    pub fn out_width(&self) -> usize {
        self.layers.last().map(|l| l.w).unwrap_or(self.n_in)
    }

    pub fn out_bits(&self) -> usize {
        self.layers.last().map(|l| l.out_bits).unwrap_or(self.in_bits)
    }

    /// Total number of L-LUTs.
    pub fn total_units(&self) -> usize {
        self.layers.iter().map(|l| l.w).sum()
    }

    /// Structural content hash (FNV-1a over widths, wiring and tables;
    /// the `name` is deliberately excluded so identically-structured
    /// models hash alike).  This is the [`PlanCache`] key: equal content
    /// means the compiled [`ExecPlan`] is identical, so a cached plan
    /// can be shared.
    pub fn content_hash(&self) -> u64 {
        // FNV-1a, 64-bit
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
        }
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        h = mix(h, self.n_in as u64);
        h = mix(h, self.in_bits as u64);
        h = mix(h, self.layers.len() as u64);
        for layer in &self.layers {
            h = mix(h, layer.w as u64);
            h = mix(h, layer.fan_in as u64);
            h = mix(h, layer.in_bits as u64);
            h = mix(h, layer.out_bits as u64);
            for &c in &layer.conn {
                h = mix(h, c as u64);
            }
            // separate the streams so conn/table boundaries cannot alias
            h = mix(h, 0xC0DE_5EA1);
            for &t in &layer.tables {
                h = mix(h, t as u64);
            }
            h = mix(h, 0x7AB1_E5E9);
        }
        h
    }

    /// Lower this netlist into a compiled execution plan (see
    /// [`compile`] / `netlist::plan`).
    pub fn compile_plan(&self, opts: PlanOptions) -> ExecPlan {
        plan::compile(self, opts)
    }

    /// Evaluate one sample (codes) -> output codes. Reference-simple path.
    pub fn eval_one(&self, x: &[i32]) -> Result<Vec<i32>> {
        if x.len() != self.n_in {
            bail!("input width {} != {}", x.len(), self.n_in);
        }
        let mut prev: Vec<u16> = x.iter().map(|&c| c as u16).collect();
        for layer in &self.layers {
            let mut next = vec![0u16; layer.w];
            let t = layer.entries_per_unit();
            for u in 0..layer.w {
                let mut addr = 0usize;
                for (f, &src) in layer.unit_conn(u).iter().enumerate() {
                    addr |= (prev[src as usize] as usize) << (layer.in_bits * f);
                }
                next[u] = layer.tables[u * t + addr];
            }
            prev = next;
        }
        Ok(prev.into_iter().map(|c| c as i32).collect())
    }

    /// Evaluate a batch (row-major codes) -> row-major output codes.
    /// This is the optimized request-path entry point.
    pub fn eval_batch(&self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        if x.len() != batch * self.n_in {
            bail!("batch input len mismatch");
        }
        // empty batch: skip simulator construction (which compiles an
        // execution plan) entirely
        if batch == 0 {
            return Ok(Vec::new());
        }
        let mut sim = sim::Simulator::new(self);
        Ok(sim.eval_batch(x, batch))
    }

    /// Persistent simulator with reusable scratch buffers (hot path).
    pub fn simulator(&self) -> sim::Simulator<'_> {
        sim::Simulator::new(self)
    }

    /// Persistent simulator with explicit kernel/threading options.
    pub fn simulator_with(&self, opts: sim::SimOptions) -> sim::Simulator<'_> {
        sim::Simulator::with_options(self, opts)
    }

    /// Build a netlist from per-layer (conn, tables) data plus widths —
    /// the bridge from the enumeration artifacts.
    pub fn from_parts(
        name: &str,
        n_in: usize,
        in_bits: usize,
        specs: Vec<LayerSpec>,
    ) -> Result<Netlist> {
        let nl = Netlist { name: name.to_string(), n_in, in_bits, layers: specs };
        nl.validate().context("netlist validation")?;
        Ok(nl)
    }
}

/// Random-netlist generators shared by unit tests, integration tests and
/// the hot-path benches (hence not `#[cfg(test)]`).
pub mod testutil {
    use super::*;
    use crate::util::Rng;

    /// Random valid netlist for property tests.
    pub fn random_netlist(seed: u64, n_in: usize, in_bits: usize,
                          layer_shapes: &[(usize, usize, usize)]) -> Netlist {
        // layer_shapes: (w, fan_in, out_bits)
        let mut rng = Rng::new(seed);
        let mut prev_w = n_in;
        let mut prev_bits = in_bits;
        let mut layers = Vec::new();
        for &(w, fan_in, out_bits) in layer_shapes {
            let entries = 1usize << (prev_bits * fan_in);
            let conn: Vec<u32> = (0..w * fan_in)
                .map(|_| rng.below(prev_w) as u32)
                .collect();
            let tables: Vec<u16> = (0..w * entries)
                .map(|_| rng.below(1 << out_bits) as u16)
                .collect();
            layers.push(LayerSpec {
                w,
                fan_in,
                in_bits: prev_bits,
                out_bits,
                conn,
                tables,
            });
            prev_w = w;
            prev_bits = out_bits;
        }
        let nl = Netlist {
            name: format!("rand{seed}"),
            n_in,
            in_bits,
            layers,
        };
        nl.validate().unwrap();
        nl
    }

    /// Random netlist whose truth tables have *bounded true support*:
    /// each output bit depends on at most `max_support` of the unit's raw
    /// address bits, and is constant with probability 1/8 (zero-support
    /// planes).  Trained NeuraLUT-Assemble tables look like this after
    /// pruning — it is exactly the structure that lets the bit-plane
    /// kernel cover layers whose raw address width exceeds a physical
    /// LUT.  Used by the sim tests, the property suite and the
    /// `netlist_hotpath` bench.
    pub fn random_reducible_netlist(seed: u64, n_in: usize, in_bits: usize,
                                    layer_shapes: &[(usize, usize, usize)],
                                    max_support: usize) -> Netlist {
        assert!(max_support <= 6);
        let mut rng = Rng::new(seed);
        let mut prev_w = n_in;
        let mut prev_bits = in_bits;
        let mut layers = Vec::new();
        for &(w, fan_in, out_bits) in layer_shapes {
            let addr_bits = prev_bits * fan_in;
            let entries = 1usize << addr_bits;
            let conn: Vec<u32> = (0..w * fan_in)
                .map(|_| rng.below(prev_w) as u32)
                .collect();
            let mut tables = vec![0u16; w * entries];
            for u in 0..w {
                for b in 0..out_bits {
                    let cap = max_support.min(addr_bits);
                    let s = if rng.below(8) == 0 || cap == 0 {
                        0
                    } else {
                        1 + rng.below(cap)
                    };
                    let support = rng.sample_distinct(addr_bits.max(1), s);
                    let f = if (1usize << s) >= 64 {
                        rng.next_u64()
                    } else {
                        rng.next_u64() & ((1u64 << (1usize << s)) - 1)
                    };
                    for addr in 0..entries {
                        let mut m = 0usize;
                        for (i, &v) in support.iter().enumerate() {
                            m |= ((addr >> v) & 1) << i;
                        }
                        if (f >> m) & 1 == 1 {
                            tables[u * entries + addr] |= 1 << b;
                        }
                    }
                }
            }
            layers.push(LayerSpec {
                w,
                fan_in,
                in_bits: prev_bits,
                out_bits,
                conn,
                tables,
            });
            prev_w = w;
            prev_bits = out_bits;
        }
        let nl = Netlist {
            name: format!("reducible{seed}"),
            n_in,
            in_bits,
            layers,
        };
        nl.validate().unwrap();
        nl
    }

    pub fn random_inputs(seed: u64, nl: &Netlist, batch: usize) -> Vec<i32> {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        (0..batch * nl.n_in)
            .map(|_| rng.below(1 << nl.in_bits) as i32)
            .collect()
    }

    /// Serialize in the legacy v1 payload layout (no alignment padding
    /// before the plan image) — fixture generator for the back-compat
    /// tests; current tooling always writes [`NLB_VERSION`].
    pub fn write_nlb_v1(nl: &Netlist, plan: Option<&ExecPlan>)
                        -> Result<Vec<u8>> {
        super::format::write_nlb_versioned(nl, plan, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn validate_catches_errors() {
        let mut nl = random_netlist(1, 8, 1, &[(4, 2, 2), (2, 2, 3)]);
        nl.validate().unwrap();
        nl.layers[1].conn[0] = 99;
        assert!(nl.validate().is_err());
        let mut nl2 = random_netlist(2, 8, 1, &[(4, 2, 2)]);
        nl2.layers[0].tables[3] = 7; // > 2 bits
        assert!(nl2.validate().is_err());
    }

    #[test]
    fn validate_rejects_overflowing_address_width() {
        // in_bits * fan_in = 64 would overflow `1usize << addr_bits`
        // inside entries_per_unit; validation must bail first
        let layer = LayerSpec {
            w: 1,
            fan_in: 16,
            in_bits: 4,
            out_bits: 1,
            conn: vec![0; 16],
            tables: vec![],
        };
        let nl = Netlist { name: "bad".into(), n_in: 1, in_bits: 4,
                           layers: vec![layer] };
        let err = nl.validate().unwrap_err().to_string();
        assert!(err.contains("address width"), "unexpected error: {err}");
        // just over the cap fails; at the cap the shift itself is fine
        let mut nl2 = Netlist { name: "edge".into(), n_in: 1, in_bits: 1,
                                layers: vec![LayerSpec {
                                    w: 0,
                                    fan_in: MAX_ADDR_BITS + 1,
                                    in_bits: 1,
                                    out_bits: 1,
                                    conn: vec![],
                                    tables: vec![],
                                }] };
        assert!(nl2.validate().is_err());
        nl2.layers[0].fan_in = MAX_ADDR_BITS;
        nl2.validate().unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_out_bits() {
        let layer = LayerSpec {
            w: 1,
            fan_in: 1,
            in_bits: 1,
            out_bits: 17,
            conn: vec![0],
            tables: vec![0, 0],
        };
        let nl = Netlist { name: "bad".into(), n_in: 1, in_bits: 1,
                           layers: vec![layer] };
        let err = nl.validate().unwrap_err().to_string();
        assert!(err.contains("out_bits"), "unexpected error: {err}");
    }

    #[test]
    fn eval_one_identity_chain() {
        // one unit copying its single input through an identity table
        let ident = LayerSpec {
            w: 1,
            fan_in: 1,
            in_bits: 2,
            out_bits: 2,
            conn: vec![0],
            tables: vec![0, 1, 2, 3],
        };
        let nl = Netlist {
            name: "id".into(),
            n_in: 1,
            in_bits: 2,
            layers: vec![ident.clone(), ident],
        };
        nl.validate().unwrap();
        for c in 0..4 {
            assert_eq!(nl.eval_one(&[c]).unwrap(), vec![c]);
        }
    }

    #[test]
    fn eval_batch_matches_eval_one() {
        let nl = random_netlist(7, 16, 2, &[(12, 3, 2), (6, 2, 1), (3, 2, 4)]);
        let batch = 33;
        let x = random_inputs(7, &nl, batch);
        let got = nl.eval_batch(&x, batch).unwrap();
        let ow = nl.out_width();
        for b in 0..batch {
            let one = nl.eval_one(&x[b * 16..(b + 1) * 16]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..], "row {b}");
        }
    }

    #[test]
    fn xor_tree_semantics() {
        // 4 one-bit inputs -> 2 XOR LUTs -> 1 XOR LUT == parity
        let xor = vec![0u16, 1, 1, 0];
        let l0 = LayerSpec {
            w: 2, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 1, 2, 3],
            tables: [xor.clone(), xor.clone()].concat(),
        };
        let l1 = LayerSpec {
            w: 1, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 1],
            tables: xor,
        };
        let nl = Netlist { name: "par".into(), n_in: 4, in_bits: 1,
                           layers: vec![l0, l1] };
        nl.validate().unwrap();
        for v in 0..16u32 {
            let x: Vec<i32> = (0..4).map(|i| ((v >> i) & 1) as i32).collect();
            let parity = (v.count_ones() & 1) as i32;
            assert_eq!(nl.eval_one(&x).unwrap(), vec![parity], "v={v}");
        }
    }

    #[test]
    fn total_units() {
        let nl = random_netlist(3, 8, 1, &[(4, 2, 1), (2, 2, 1)]);
        assert_eq!(nl.total_units(), 6);
    }
}
