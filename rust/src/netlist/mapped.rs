//! Memory-mapped artifact regions and the arena storage behind
//! [`ExecPlan`](super::ExecPlan) — one of the crate's two audited
//! `unsafe` islands (the other is the worker-pool plumbing in
//! `netlist/sim.rs`; the crate root carries `#![deny(unsafe_code)]`
//! and CI greps that the keyword appears nowhere else).
//!
//! ## Why
//!
//! A compiled plan is two flat little-endian buffers — a `u64` table
//! arena and a `u32` conn arena — plus a thin layer schedule.  The
//! copying loader pays O(bytes) per model to move those buffers into
//! owned `Vec`s; at hundreds of registered models that is the dominant
//! cold-start cost.  [`MappedFile`] + [`Arena`] let the loader *borrow*
//! the arenas straight out of a memory-mapped `.nlb` / `.plan` file, so
//! a load costs O(validation): headers, checksums and structural
//! cross-checks are still performed on every byte, but the bulk data is
//! never copied and pages fault in lazily on first execution.
//!
//! ## Safety argument
//!
//! The module exposes no raw pointers and no lifetimes tied to a file:
//!
//! * [`MappedFile`] owns a `PROT_READ`/`MAP_PRIVATE` mapping for its
//!   whole lifetime and is only handed out as `Arc<MappedFile>`; every
//!   [`Arena`] that borrows from it holds a clone of the `Arc`, so the
//!   mapping outlives every view into it by construction.
//! * [`Arena::try_map`] is the *only* way to build a borrowed arena,
//!   and it re-checks every precondition `Deref`'s
//!   `slice::from_raw_parts` needs: the host is little-endian (else the
//!   raw bytes are not valid `T`s — foreign-endian hosts always copy),
//!   the byte range lies inside the mapping, and the absolute address
//!   is aligned for `T` (writers pad so this holds; an unaligned file
//!   yields `None` and the caller falls back to the copying decoder).
//! * Element types are sealed ([`ArenaElem`]: `u32`/`u64` only) — plain
//!   old data with no invalid bit patterns, so arbitrary file bytes are
//!   always valid values.  Validation happens *after* the borrow, on
//!   the same checked-slice view execution uses.
//! * The kernels index arenas exclusively through bounds-checked slice
//!   ops, so even if the underlying file were truncated or rewritten
//!   after validation, the worst outcomes are a panic or wrong outputs
//!   — never out-of-bounds access through this module.  (Artifact and
//!   cache writers are temp-file + rename, so a file is never truncated
//!   in place under a reader; `MAP_PRIVATE` additionally decouples the
//!   mapping from later writes on most systems.)
//!
//! `mmap`/`munmap` are declared by hand (the crate deliberately has no
//! libc dependency) with the constants `PROT_READ = 1` /
//! `MAP_PRIVATE = 2`, which hold on every 64-bit unix this crate
//! targets (Linux, macOS, the BSDs).  Non-unix or 32-bit targets get
//! [`io::ErrorKind::Unsupported`] from [`MappedFile::open`] and every
//! caller falls back to the copying loader.
#![allow(unsafe_code)]

use std::io;
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(addr: *mut c_void, len: usize, prot: i32,
                    flags: i32, fd: i32, offset: i64) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A read-only memory mapping of a whole file, alive for as long as any
/// `Arc` clone (and therefore any [`Arena`] borrowed from it) exists.
pub struct MappedFile {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) for the
// struct's whole lifetime, so shared access from any thread is sound.
unsafe impl Send for MappedFile {}
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map `path` read-only.  A zero-length file maps to an empty view
    /// without calling `mmap` (which rejects length 0).  On targets
    /// without the mapping syscalls this returns
    /// [`io::ErrorKind::Unsupported`] and callers copy instead.
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn open(path: &Path) -> io::Result<Arc<MappedFile>> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            return Ok(Arc::new(MappedFile { ptr: std::ptr::null(),
                                            len: 0 }));
        }
        // SAFETY: plain read-only private mapping of an open fd; the
        // result is checked against MAP_FAILED before use.  The fd may
        // be closed afterwards — the mapping keeps the pages alive.
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ,
                      sys::MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(Arc::new(MappedFile { ptr: ptr as *const u8, len }))
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn open(_path: &Path) -> io::Result<Arc<MappedFile>> {
        Err(io::Error::new(io::ErrorKind::Unsupported,
                           "memory mapping is unsupported on this \
                            target; use the copying loader"))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The whole mapping as a byte slice — the view every header and
    /// checksum validation runs over.
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len describe a live PROT_READ mapping owned by
        // self; u8 has no alignment or validity requirements.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len != 0 {
            // SAFETY: exactly the region mmap returned; after the last
            // Arc drops no view into it can exist.
            unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for u64 {}
}

/// Element types an [`Arena`] may hold: sealed to the two plain-old-data
/// integers the plan arenas use, so any file bytes are valid values.
pub trait ArenaElem: sealed::Sealed + Copy + 'static {}

impl ArenaElem for u32 {}
impl ArenaElem for u64 {}

enum Repr<T: ArenaElem> {
    Owned(Vec<T>),
    /// `len` elements of `T` starting `off` bytes into `map`.  Invariant
    /// (established by [`Arena::try_map`], the only constructor): the
    /// host is little-endian, the byte range is inside the mapping, and
    /// the absolute address is aligned for `T`.
    Mapped {
        map: Arc<MappedFile>,
        off: usize,
        len: usize,
    },
}

/// Storage for one plan arena: an owned `Vec` (the compiler and the
/// copying loader) or a borrowed slice of a memory-mapped file (the
/// zero-copy loader).  Derefs to `[T]` either way, so the kernels are
/// oblivious — they hoist `&plan.words` / `&plan.conn` to plain slices
/// once per call and index those.
pub struct Arena<T: ArenaElem>(Repr<T>);

impl<T: ArenaElem> Arena<T> {
    /// Borrow `count` elements starting at `byte_off` of `map`, or
    /// `None` when the zero-copy preconditions fail (foreign-endian
    /// host, out-of-bounds range, unaligned address) — the caller then
    /// decodes a copy instead.  Infallibly safe: every precondition of
    /// the `Deref` slice construction is established here, against the
    /// immutable mapping the arena will keep alive.
    pub fn try_map(map: &Arc<MappedFile>, byte_off: usize, count: usize)
                   -> Option<Arena<T>> {
        if !cfg!(target_endian = "little") {
            return None;
        }
        let bytes = count.checked_mul(std::mem::size_of::<T>())?;
        let end = byte_off.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        let addr = map.bytes().as_ptr() as usize + byte_off;
        if addr % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(Arena(Repr::Mapped { map: map.clone(), off: byte_off,
                                  len: count }))
    }

    /// Does this arena borrow from a mapping (vs own its storage)?
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }
}

impl<T: ArenaElem> From<Vec<T>> for Arena<T> {
    fn from(v: Vec<T>) -> Arena<T> {
        Arena(Repr::Owned(v))
    }
}

impl<T: ArenaElem> Deref for Arena<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        match &self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { map, off, len } => {
                // SAFETY: try_map checked bounds and alignment against
                // this mapping, which `map` keeps alive and immutable;
                // T is sealed POD, so the bytes are valid values.
                unsafe {
                    std::slice::from_raw_parts(
                        map.bytes().as_ptr().add(*off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("nid_mapped_{tag}_{}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_bytes_verbatim() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096 + 13).collect();
        let p = temp_file("verbatim", &data);
        let map = MappedFile::open(&p).unwrap();
        assert_eq!(map.len(), data.len());
        assert_eq!(map.bytes(), &data[..]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn zero_length_file_maps_empty() {
        let p = temp_file("empty", &[]);
        let map = MappedFile::open(&p).unwrap();
        assert!(map.is_empty());
        assert_eq!(map.bytes(), &[] as &[u8]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_file_is_an_error() {
        let p = std::env::temp_dir().join("nid_mapped_nonexistent");
        assert!(MappedFile::open(&p).is_err());
    }

    #[test]
    fn try_map_reads_little_endian_elements() {
        let vals: Vec<u64> = (0..32).map(|i| i * 0x0101_0101_0101).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = temp_file("u64s", &bytes);
        let map = MappedFile::open(&p).unwrap();
        let arena: Arena<u64> =
            Arena::try_map(&map, 0, vals.len()).unwrap();
        assert!(arena.is_mapped());
        assert_eq!(&arena[..], &vals[..]);
        // a mid-buffer aligned view works too
        let tail: Arena<u64> =
            Arena::try_map(&map, 8, vals.len() - 1).unwrap();
        assert_eq!(&tail[..], &vals[1..]);
        // the arena keeps the mapping alive past the last Arc
        drop(map);
        assert_eq!(arena[3], vals[3]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn try_map_rejects_misaligned_and_out_of_bounds() {
        let p = temp_file("bounds", &[0u8; 64]);
        let map = MappedFile::open(&p).unwrap();
        // unaligned offsets for the element size
        assert!(Arena::<u64>::try_map(&map, 4, 1).is_none());
        assert!(Arena::<u64>::try_map(&map, 1, 1).is_none());
        assert!(Arena::<u32>::try_map(&map, 2, 1).is_none());
        // out of bounds: length, offset, and overflowing combinations
        assert!(Arena::<u64>::try_map(&map, 0, 9).is_none());
        assert!(Arena::<u64>::try_map(&map, 64, 1).is_none());
        assert!(Arena::<u64>::try_map(&map, 0, usize::MAX).is_none());
        assert!(Arena::<u32>::try_map(&map, usize::MAX - 3, 1).is_none());
        // in-bounds aligned views at both element sizes are fine
        assert!(Arena::<u64>::try_map(&map, 0, 8).is_some());
        assert!(Arena::<u32>::try_map(&map, 60, 1).is_some());
        // empty views are fine too
        let empty: Arena<u32> = Arena::try_map(&map, 64, 0).unwrap();
        assert_eq!(empty.len(), 0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn owned_arena_derefs_to_its_vec() {
        let arena: Arena<u32> = vec![7u32, 8, 9].into();
        assert!(!arena.is_mapped());
        assert_eq!(&arena[..], &[7, 8, 9]);
    }
}
