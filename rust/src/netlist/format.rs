//! `.nlb` — the versioned on-disk artifact format for trained netlists.
//!
//! The paper's deliverable is a *trained LUT network*: a concrete
//! artifact, not a config.  Before this module every consumer
//! re-synthesized netlists from config and recompiled plans on every
//! process start; `.nlb` inverts that dependency, making config-driven
//! synthesis one *producer* of artifacts rather than the only entry
//! point.  The python training side writes the identical byte layout
//! (`python/compile/nlb.py`), proven bit-exact by the golden-file
//! integration test, so a session trained under JAX loads into the rust
//! server unchanged.
//!
//! ## Wire layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic "NLBF"
//! 4       2     version (currently 2)
//! 6       2     flags (bit 0: compiled-plan image section present)
//! 8       8     content hash (Netlist::content_hash of the payload)
//! 16      8     payload length (must equal file length - 32)
//! 24      8     payload checksum (FNV-1a over the payload bytes)
//! 32      ..    payload:
//!   name            u32 length + UTF-8 bytes
//!   n_in            u32
//!   in_bits         u32
//!   n_layers        u32
//!   per layer:
//!     w, fan_in, in_bits, out_bits          4 x u32
//!     conn     w * fan_in            x u32  (unit-major)
//!     tables   w * 2^(in_bits*fan_in) x u16 (unit-major)
//!   padding     (v2+, iff flags bit 0: 0-7 zero bytes so the plan
//!                image starts at a file offset that is a multiple of
//!                8 — readers recompute the count and reject nonzero
//!                bytes, keeping the encoding canonical)
//!   plan image  (iff flags bit 0 — the ExecPlan arenas verbatim;
//!                layout documented at `ExecPlan::write_image`)
//! ```
//!
//! ## Versioning policy
//!
//! The version bumps on any layout change; readers accept exactly the
//! versions they know and reject the rest with a descriptive error —
//! an old binary must never misparse a new file.  New optional
//! sections get a flag bit, and readers reject unknown flag bits for
//! the same reason.  Currently readable: **v2** (the written version;
//! adds the alignment padding before the plan image, which is what
//! makes the zero-copy mapped load possible) and **v1** (the
//! unpadded layout, accepted via a back-compat copying read —
//! [`read_nlb_mapped`] never borrows arenas from a v1 file).
//!
//! ## Validation & threat model
//!
//! [`read_nlb`] is total: any byte string either parses into a
//! validated model or returns an error — it never panics and never
//! allocates more than the input length can justify.  The checks, in
//! order: header shape (magic, version, known flags, exact length),
//! payload checksum, structural netlist validation
//! ([`Netlist::validate`]), content-hash integrity, and — when a plan
//! image is present — full arena bounds validation plus a structural
//! cross-check of the plan against the netlist it claims to accelerate.
//! This authenticates *integrity* (truncation, bit rot, a mismatched
//! netlist/plan pair), not *malice*: a hand-crafted file with a
//! self-consistent checksum could still carry a bit-plane table that
//! disagrees with its own netlist section.  For untrusted artifacts,
//! run `check_conformance` after loading (the cold-start CI job does)
//! or ignore the plan image and recompile.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::mapped::MappedFile;
use super::plan::{compile, plan_key, ExecPlan, PlanOptions};
use super::{LayerSpec, Netlist, MAX_ADDR_BITS};

pub const NLB_MAGIC: [u8; 4] = *b"NLBF";
pub const NLB_VERSION: u16 = 2;

/// Oldest version the reader still accepts (copying read only).
const NLB_MIN_VERSION: u16 = 1;

/// Flag bit 0: a compiled-plan image section follows the netlist.
const FLAG_PLAN: u16 = 1;

/// FNV-1a over raw bytes — the payload checksum.  (The *content* hash
/// is [`Netlist::content_hash`], an FNV-1a over the decoded structure;
/// this one detects corruption anywhere in the encoded payload,
/// including the plan image, before any of it is parsed.)  Also the
/// frame checksum of the TCP wire protocol (`net::wire` truncates it
/// to 32 bits), re-exported crate-wide from `netlist`.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

pub(super) fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub(super) fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(super) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(super) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor.  Every take verifies the
/// remaining length first, so array reads are bounded by the input
/// size — an adversarial count fails fast instead of allocating.
pub(super) struct ByteReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub(super) fn new(b: &'a [u8]) -> ByteReader<'a> {
        ByteReader { b, pos: 0 }
    }

    pub(super) fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Bytes consumed so far — the cursor's offset from the start of
    /// the buffer it was constructed over (used to translate reader
    /// positions into absolute file offsets for the mapped load path).
    pub(super) fn pos(&self) -> usize {
        self.pos
    }

    pub(super) fn take(&mut self, n: usize, what: &str)
                       -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated: {what} needs {n} bytes at offset {}, only \
                   {} left", self.pos, self.remaining());
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(super) fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(super) fn u16(&mut self, what: &str) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    pub(super) fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(super) fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub(super) fn usize32(&mut self, what: &str) -> Result<usize> {
        Ok(self.u32(what)? as usize)
    }

    pub(super) fn u8s(&mut self, count: usize, what: &str)
                      -> Result<Vec<u8>> {
        Ok(self.take(count, what)?.to_vec())
    }

    pub(super) fn u16s(&mut self, count: usize, what: &str)
                       -> Result<Vec<u16>> {
        let n = count.checked_mul(2)
            .with_context(|| format!("{what}: count overflow"))?;
        Ok(self.take(n, what)?
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(super) fn u32s(&mut self, count: usize, what: &str)
                       -> Result<Vec<u32>> {
        let n = count.checked_mul(4)
            .with_context(|| format!("{what}: count overflow"))?;
        Ok(self.take(n, what)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(super) fn u64s(&mut self, count: usize, what: &str)
                       -> Result<Vec<u64>> {
        let n = count.checked_mul(8)
            .with_context(|| format!("{what}: count overflow"))?;
        Ok(self.take(n, what)?
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// A loaded `.nlb` artifact: the validated netlist plus, if the file
/// carried one, its compiled plan (already cross-checked against the
/// netlist at load time).
pub struct NlbModel {
    pub netlist: Netlist,
    pub plan: Option<Arc<ExecPlan>>,
}

impl NlbModel {
    /// The artifact's plan if it was exported compiled under `opts`,
    /// otherwise a fresh compile of the netlist.
    pub fn plan_or_compile(&self, opts: PlanOptions) -> Arc<ExecPlan> {
        match &self.plan {
            Some(p) if p.key() == plan_key(&self.netlist, opts) => {
                p.clone()
            }
            _ => Arc::new(compile(&self.netlist, opts)),
        }
    }
}

/// Serialize `nl` (and optionally a plan compiled from it) to `.nlb`
/// bytes.  Refuses invalid netlists and plans that were not compiled
/// from this exact content — a file we write always loads.
pub fn write_nlb(nl: &Netlist, plan: Option<&ExecPlan>)
                 -> Result<Vec<u8>> {
    write_nlb_versioned(nl, plan, NLB_VERSION)
}

/// [`write_nlb`] with an explicit version — v1 (no alignment padding)
/// exists only so back-compat tests can generate legacy fixtures.
pub(crate) fn write_nlb_versioned(nl: &Netlist, plan: Option<&ExecPlan>,
                                  version: u16) -> Result<Vec<u8>> {
    if !(NLB_MIN_VERSION..=NLB_VERSION).contains(&version) {
        bail!("cannot write .nlb version {version}");
    }
    nl.validate().context("refusing to serialize an invalid netlist")?;
    if let Some(p) = plan {
        let ok = [true, false].iter().any(|&b| {
            p.key() == plan_key(nl, PlanOptions { bitplane: b })
        });
        if !ok {
            bail!("plan (key {:016x}) was not compiled from this \
                   netlist (content hash {:016x})",
                  p.key(), nl.content_hash());
        }
    }
    let mut payload = Vec::new();
    put_u32(&mut payload, nl.name.len() as u32);
    payload.extend_from_slice(nl.name.as_bytes());
    put_u32(&mut payload, nl.n_in as u32);
    put_u32(&mut payload, nl.in_bits as u32);
    put_u32(&mut payload, nl.layers.len() as u32);
    for layer in &nl.layers {
        put_u32(&mut payload, layer.w as u32);
        put_u32(&mut payload, layer.fan_in as u32);
        put_u32(&mut payload, layer.in_bits as u32);
        put_u32(&mut payload, layer.out_bits as u32);
        for &c in &layer.conn {
            put_u32(&mut payload, c);
        }
        for &t in &layer.tables {
            put_u16(&mut payload, t);
        }
    }
    let mut flags = 0u16;
    if let Some(p) = plan {
        flags |= FLAG_PLAN;
        if version >= 2 {
            // pad the image to a file offset that is a multiple of 8:
            // the payload starts at 32 (≡ 0 mod 8), so padding the
            // payload length to 8 aligns the image — and with it the
            // word/conn arenas — for the zero-copy mapped load
            let pad = (8 - payload.len() % 8) % 8;
            payload.resize(payload.len() + pad, 0);
        }
        p.write_image(&mut payload);
    }
    let mut out = Vec::with_capacity(32 + payload.len());
    out.extend_from_slice(&NLB_MAGIC);
    put_u16(&mut out, version);
    put_u16(&mut out, flags);
    put_u64(&mut out, nl.content_hash());
    put_u64(&mut out, payload.len() as u64);
    put_u64(&mut out, fnv1a(&payload));
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Parse and validate `.nlb` bytes.  Total: returns a descriptive
/// error on any malformed input, never panics (see the module doc for
/// the check order).
pub fn read_nlb(bytes: &[u8]) -> Result<NlbModel> {
    read_nlb_impl(bytes, None)
}

/// Zero-copy variant of [`read_nlb`]: parse a memory-mapped `.nlb`
/// whole-file view, borrowing the plan arenas from the mapping when
/// the file is v2 and the preconditions hold (little-endian host,
/// aligned offsets — see `netlist::mapped`).  Validation is identical
/// to the copying read; only the arena storage differs, observable via
/// [`ExecPlan::is_mapped`].  v1 files and failed preconditions fall
/// back to copying the arenas — never to weaker checking.
pub fn read_nlb_mapped(map: &Arc<MappedFile>) -> Result<NlbModel> {
    read_nlb_impl(map.bytes(), Some(map))
}

fn read_nlb_impl(bytes: &[u8], map: Option<&Arc<MappedFile>>)
                 -> Result<NlbModel> {
    if bytes.len() < 32 {
        bail!("truncated header: {} bytes, need 32", bytes.len());
    }
    let mut h = ByteReader::new(&bytes[..32]);
    let magic = h.take(4, "magic")?;
    if magic != NLB_MAGIC {
        bail!("bad magic {magic:02x?} (expected \"NLBF\" — not an .nlb \
               file)");
    }
    let version = h.u16("version")?;
    if !(NLB_MIN_VERSION..=NLB_VERSION).contains(&version) {
        bail!("unsupported format version {version} (this build reads \
               versions {NLB_MIN_VERSION}..={NLB_VERSION})");
    }
    let flags = h.u16("flags")?;
    if flags & !FLAG_PLAN != 0 {
        bail!("unknown flag bits {:#06x} (written by a newer tool?)",
              flags & !FLAG_PLAN);
    }
    let content_hash = h.u64("content hash")?;
    let payload_len = h.u64("payload length")?;
    let payload_hash = h.u64("payload checksum")?;
    let payload = &bytes[32..];
    if payload.len() as u64 != payload_len {
        bail!("payload is {} bytes but the header declares {} \
               (truncated file or trailing garbage)",
              payload.len(), payload_len);
    }
    if fnv1a(payload) != payload_hash {
        bail!("payload checksum mismatch (file corrupt)");
    }
    let mut r = ByteReader::new(payload);
    let name_len = r.usize32("name length")?;
    let name = String::from_utf8(r.take(name_len, "name")?.to_vec())
        .context("model name is not UTF-8")?;
    let n_in = r.usize32("n_in")?;
    let in_bits = r.usize32("in_bits")?;
    let n_layers = r.usize32("layer count")?;
    let mut layers = Vec::new();
    for l in 0..n_layers {
        let w = r.usize32("layer w")?;
        let fan_in = r.usize32("layer fan_in")?;
        let l_in_bits = r.usize32("layer in_bits")?;
        let out_bits = r.usize32("layer out_bits")?;
        // bound the address width before `1 << addr_bits` (the same
        // first check Netlist::validate makes, needed here because the
        // shift happens while sizing the table read)
        let addr_bits = l_in_bits.saturating_mul(fan_in);
        if addr_bits > MAX_ADDR_BITS {
            bail!("layer {l}: address width {addr_bits} bits exceeds \
                   the {MAX_ADDR_BITS}-bit cap");
        }
        let conn_len = w.checked_mul(fan_in)
            .with_context(|| format!("layer {l}: conn size overflow"))?;
        let conn = r.u32s(conn_len, "layer conn")?;
        let table_len = w.checked_mul(1usize << addr_bits)
            .with_context(|| format!("layer {l}: table size overflow"))?;
        let tables = r.u16s(table_len, "layer tables")?;
        layers.push(LayerSpec {
            w,
            fan_in,
            in_bits: l_in_bits,
            out_bits,
            conn,
            tables,
        });
    }
    let nl = Netlist { name, n_in, in_bits, layers };
    nl.validate().context("netlist section failed validation")?;
    if nl.content_hash() != content_hash {
        bail!("content hash mismatch: header says {content_hash:016x}, \
               payload hashes to {:016x}", nl.content_hash());
    }
    let plan = if flags & FLAG_PLAN != 0 {
        if version >= 2 {
            // consume the writer's alignment padding (recomputed, not
            // stored — and required to be zero, so the encoding stays
            // canonical)
            let pad = (8 - r.pos() % 8) % 8;
            if r.take(pad, "alignment padding")?.iter().any(|&b| b != 0) {
                bail!("nonzero alignment padding before the plan image");
            }
        }
        // v1 files predate the alignment guarantee: always copy them
        let src = match map {
            Some(m) if version >= 2 => Some((m, 32usize)),
            _ => None,
        };
        let p = ExecPlan::read_image(&mut r, &nl, src)
            .context("plan image section")?;
        Some(Arc::new(p))
    } else {
        None
    };
    if r.remaining() != 0 {
        bail!("{} trailing bytes after the last section", r.remaining());
    }
    Ok(NlbModel { netlist: nl, plan })
}

/// Write an `.nlb` artifact atomically (temp file + rename, so a
/// crashed export never leaves a half-written model behind).
pub fn save_nlb(path: impl AsRef<Path>, nl: &Netlist,
                plan: Option<&ExecPlan>) -> Result<()> {
    let path = path.as_ref();
    let bytes = write_nlb(nl, plan)?;
    write_atomic(path, &bytes)
        .with_context(|| format!("writing {}", path.display()))
}

/// Load and validate an `.nlb` artifact from disk.
pub fn load_nlb(path: impl AsRef<Path>) -> Result<NlbModel> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    read_nlb(&bytes)
        .with_context(|| format!("loading {}", path.display()))
}

/// Load an `.nlb` artifact by memory-mapping it: same validation as
/// [`load_nlb`], but a v2 plan image's arenas are borrowed from the
/// mapping instead of copied, making the load O(validation) rather
/// than O(bytes).  On targets without mapping support this degrades to
/// the copying load; a malformed file is an error on both paths.
pub fn load_nlb_mapped(path: impl AsRef<Path>) -> Result<NlbModel> {
    let path = path.as_ref();
    match MappedFile::open(path) {
        Ok(map) => read_nlb_mapped(&map)
            .with_context(|| format!("loading {}", path.display())),
        // Unsupported target — or any open error the copying path can
        // diagnose better (missing file, permissions)
        Err(_) => load_nlb(path),
    }
}

/// Temp-file-then-rename write; the temp name carries the pid so
/// concurrent writers (e.g. two servers sharing a plan-cache dir)
/// cannot clobber each other's in-flight file.
pub(super) fn write_atomic(path: &Path, bytes: &[u8])
                           -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(".{}.tmp", std::process::id()));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{PlanExecutor, PlanOptions};
    use super::*;

    fn assert_same_netlist(a: &Netlist, b: &Netlist) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.n_in, b.n_in);
        assert_eq!(a.in_bits, b.in_bits);
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!((la.w, la.fan_in, la.in_bits, la.out_bits),
                       (lb.w, lb.fan_in, lb.in_bits, lb.out_bits));
            assert_eq!(la.conn, lb.conn);
            assert_eq!(la.tables, lb.tables);
        }
    }

    #[test]
    fn roundtrip_without_plan() {
        let nl = random_netlist(3, 10, 2, &[(8, 3, 2), (4, 2, 1)]);
        let bytes = write_nlb(&nl, None).unwrap();
        let m = read_nlb(&bytes).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        assert!(m.plan.is_none());
        // writing the loaded netlist again is byte-identical (the
        // encoding is canonical)
        assert_eq!(write_nlb(&m.netlist, None).unwrap(), bytes);
    }

    #[test]
    fn roundtrip_with_plan_is_bit_exact() {
        let nl = random_reducible_netlist(
            11, 12, 2, &[(10, 3, 2), (6, 2, 2), (3, 2, 1)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        let m = read_nlb(&bytes).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        let loaded = m.plan.expect("plan image should load");
        assert_eq!(loaded.key(), plan.key());
        assert_eq!(loaded.bitplane_layers(), plan.bitplane_layers());
        let mut ex = PlanExecutor::new(loaded);
        for (seed, batch) in [(1u64, 1usize), (2, 9), (3, 130)] {
            let x = random_inputs(seed, &nl, batch);
            let got = ex.eval_batch(&x, batch);
            let ow = nl.out_width();
            for b in 0..batch {
                let one = nl
                    .eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])
                    .unwrap();
                assert_eq!(&got[b * ow..(b + 1) * ow], &one[..]);
            }
        }
    }

    #[test]
    fn gather_only_plan_roundtrips() {
        let nl = random_reducible_netlist(13, 8, 2, &[(6, 3, 2)], 6);
        let plan =
            Arc::new(compile(&nl, PlanOptions { bitplane: false }));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        let m = read_nlb(&bytes).unwrap();
        let loaded = m.plan.unwrap();
        assert_eq!(loaded.key(), plan.key());
        assert_eq!(loaded.bitplane_layers(), 0);
    }

    #[test]
    fn plan_or_compile_reuses_matching_image() {
        let nl = random_netlist(17, 8, 1, &[(4, 2, 2)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        let m = read_nlb(&bytes).unwrap();
        let d = m.plan_or_compile(PlanOptions::default());
        assert!(Arc::ptr_eq(&d, m.plan.as_ref().unwrap()));
        // different options: the image does not apply, compile fresh
        let g = m.plan_or_compile(PlanOptions { bitplane: false });
        assert!(!Arc::ptr_eq(&g, m.plan.as_ref().unwrap()));
        assert_eq!(g.key(), plan_key(&nl, PlanOptions { bitplane: false }));
    }

    #[test]
    fn zero_layer_netlist_roundtrips() {
        let nl = Netlist { name: "empty".into(), n_in: 3, in_bits: 2,
                           layers: vec![] };
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        let m = read_nlb(&bytes).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        let mut ex = PlanExecutor::new(m.plan.unwrap());
        assert_eq!(ex.eval_one(&[1, 2, 3]), vec![1, 2, 3]);
    }

    #[test]
    fn zero_unit_layer_roundtrips() {
        // a w=0 layer is valid (validate passes) and must survive the
        // trip — or be rejected cleanly — never panic
        let nl = Netlist {
            name: "hollow".into(),
            n_in: 2,
            in_bits: 1,
            layers: vec![LayerSpec {
                w: 0,
                fan_in: 2,
                in_bits: 1,
                out_bits: 1,
                conn: vec![],
                tables: vec![],
            }],
        };
        nl.validate().unwrap();
        let bytes = write_nlb(&nl, None).unwrap();
        let m = read_nlb(&bytes).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        assert_eq!(m.netlist.out_width(), 0);
    }

    #[test]
    fn rejects_truncation_at_every_length() {
        let nl = random_netlist(19, 6, 1, &[(4, 2, 1)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        // every proper prefix must fail cleanly (no panic, no accept)
        for n in 0..bytes.len() {
            assert!(read_nlb(&bytes[..n]).is_err(), "prefix {n} accepted");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let nl = random_netlist(23, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        bytes[0] = b'X';
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("magic"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_version_mismatch() {
        let nl = random_netlist(29, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        bytes[4] = NLB_VERSION as u8 + 1;
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_unknown_flags() {
        let nl = random_netlist(31, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        bytes[6] |= 0x80;
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("flag"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_flipped_content_hash_byte() {
        let nl = random_netlist(37, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        bytes[8] ^= 0x01; // first content-hash byte
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("content hash"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_corrupt_payload() {
        let nl = random_netlist(41, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        let nl = random_netlist(43, 6, 1, &[(4, 2, 1)]);
        let mut bytes = write_nlb(&nl, None).unwrap();
        bytes.push(0);
        assert!(read_nlb(&bytes).is_err());
    }

    #[test]
    fn rejects_foreign_plan() {
        let nl = random_netlist(47, 6, 1, &[(4, 2, 1)]);
        let other = random_netlist(48, 6, 1, &[(4, 2, 1)]);
        let plan = Arc::new(compile(&other, PlanOptions::default()));
        let err = write_nlb(&nl, Some(&plan)).unwrap_err().to_string();
        assert!(err.contains("not compiled"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_invalid_netlist_section() {
        // corrupt a table entry beyond out_bits *and* fix up both
        // hashes so only structural validation can catch it
        let nl = random_netlist(53, 4, 1, &[(2, 2, 1)]);
        let mut evil = nl.clone();
        evil.layers[0].tables[0] = 3; // > 1-bit out
        // bypass write_nlb's own validation by patching bytes directly
        let good = write_nlb(&nl, None).unwrap();
        let mut bytes = good.clone();
        // payload layout: name(4+len) n_in(4) in_bits(4) n_layers(4)
        // w,fan_in,in_bits,out_bits(16) conn(2*2*4) tables...
        let name_len = nl.name.len();
        let table0 = 32 + 4 + name_len + 12 + 16 + 16;
        bytes[table0] = 3;
        // recompute both hashes so the file is "self-consistent"
        let ch = evil.content_hash().to_le_bytes();
        bytes[8..16].copy_from_slice(&ch);
        let ph = fnv1a(&bytes[32..]).to_le_bytes();
        bytes[24..32].copy_from_slice(&ph);
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("validation"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_mismatched_plan_image() {
        // plan image from a different netlist spliced after a valid
        // netlist section: the image's key check must reject it
        let nl = random_netlist(59, 6, 1, &[(4, 2, 1)]);
        let other = random_netlist(60, 6, 1, &[(4, 2, 1)]);
        let plan_other = Arc::new(compile(&other, PlanOptions::default()));
        let with_plan = write_nlb(&other, Some(&plan_other)).unwrap();
        let plain_other = write_nlb(&other, None).unwrap();
        let image = &with_plan[plain_other.len()..];
        let plain = write_nlb(&nl, None).unwrap();
        let mut bytes = plain.clone();
        bytes.extend_from_slice(image);
        bytes[6] |= FLAG_PLAN;
        let new_len = (bytes.len() - 32) as u64;
        bytes[16..24].copy_from_slice(&new_len.to_le_bytes());
        let ph = fnv1a(&bytes[32..]).to_le_bytes();
        bytes[24..32].copy_from_slice(&ph);
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("plan image"), "unexpected error: {err}");
    }

    #[test]
    fn empty_input_is_rejected() {
        assert!(read_nlb(&[]).is_err());
    }

    fn temp_artifact(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("nid_nlb_{tag}_{}.nlb", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    /// Do the zero-copy preconditions hold on this host?
    fn host_maps() -> bool {
        cfg!(all(unix, target_pointer_width = "64",
                 target_endian = "little"))
    }

    #[test]
    fn v2_plan_image_is_8_byte_aligned_for_any_name_length() {
        // name lengths 5..=8 cover every padding residue the header
        // fields leave reachable
        for seed in [7u64, 77, 777, 7777] {
            let nl = random_reducible_netlist(
                seed, 8, 2, &[(6, 3, 2), (3, 2, 1)], 6);
            let plan = Arc::new(compile(&nl, PlanOptions::default()));
            let plain = write_nlb(&nl, None).unwrap();
            let pad = (8 - (plain.len() - 32) % 8) % 8;
            assert_eq!((plain.len() + pad) % 8, 0, "seed {seed}");
            let bytes = write_nlb(&nl, Some(&plan)).unwrap();
            assert_eq!(&bytes[plain.len()..plain.len() + pad],
                       vec![0u8; pad].as_slice(), "seed {seed} padding");
            let m = read_nlb(&bytes).unwrap();
            assert_eq!(m.plan.unwrap().key(), plan.key(), "seed {seed}");
        }
    }

    #[test]
    fn mapped_load_is_zero_copy_and_bit_exact() {
        let nl = random_reducible_netlist(
            81, 12, 2, &[(10, 3, 2), (6, 2, 2), (3, 2, 1)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let bytes = write_nlb(&nl, Some(&plan)).unwrap();
        let path = temp_artifact("mapped", &bytes);
        let m = load_nlb_mapped(&path).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        let loaded = m.plan.expect("plan image should load");
        assert_eq!(loaded.key(), plan.key());
        if host_maps() {
            assert!(loaded.is_mapped(),
                    "v2 artifact plan should borrow the mapping");
        }
        // the copying load of the same file owns its arenas and the
        // two agree with the interpreted reference bit-for-bit
        let copied = load_nlb(&path).unwrap().plan.unwrap();
        assert!(!copied.is_mapped());
        let mut ex = PlanExecutor::new(loaded);
        let mut exc = PlanExecutor::new(copied);
        for (seed, batch) in [(1u64, 1usize), (2, 9), (3, 130)] {
            let x = random_inputs(seed, &nl, batch);
            let got = ex.eval_batch(&x, batch);
            assert_eq!(exc.eval_batch(&x, batch), got);
            let ow = nl.out_width();
            for b in 0..batch {
                let one = nl
                    .eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in])
                    .unwrap();
                assert_eq!(&got[b * ow..(b + 1) * ow], &one[..]);
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_free_artifacts_load_mapped_too() {
        let nl = random_netlist(83, 8, 1, &[(4, 2, 2)]);
        let bytes = write_nlb(&nl, None).unwrap();
        let path = temp_artifact("noplan", &bytes);
        let m = load_nlb_mapped(&path).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        assert!(m.plan.is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v1_files_load_via_the_copying_read() {
        let nl = random_reducible_netlist(
            85, 10, 2, &[(8, 3, 2), (4, 2, 1)], 6);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let v1 = write_nlb_v1(&nl, Some(&plan)).unwrap();
        assert_eq!(u16::from_le_bytes([v1[4], v1[5]]), 1);
        // in-memory read accepts the legacy layout
        let m = read_nlb(&v1).unwrap();
        assert_same_netlist(&nl, &m.netlist);
        assert_eq!(m.plan.as_ref().unwrap().key(), plan.key());
        // the mapped loader accepts it too but never borrows from it
        let path = temp_artifact("v1", &v1);
        let mm = load_nlb_mapped(&path).unwrap();
        let loaded = mm.plan.unwrap();
        assert!(!loaded.is_mapped(), "v1 must take the copying read");
        let mut ex = PlanExecutor::new(loaded);
        let x = random_inputs(5, &nl, 40);
        let got = ex.eval_batch(&x, 40);
        let ow = nl.out_width();
        for b in 0..40 {
            let one =
                nl.eval_one(&x[b * nl.n_in..(b + 1) * nl.n_in]).unwrap();
            assert_eq!(&got[b * ow..(b + 1) * ow], &one[..]);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v1_and_v2_encodings_differ_only_as_documented() {
        // without a plan there is nothing to align: v1 and v2 bytes
        // match except the version field
        let nl = random_netlist(87, 6, 1, &[(4, 2, 1)]);
        let v1 = write_nlb_v1(&nl, None).unwrap();
        let v2 = write_nlb(&nl, None).unwrap();
        assert_eq!(v1.len(), v2.len());
        assert_eq!(&v1[..4], &v2[..4]);
        assert_eq!(&v1[6..], &v2[6..]);
        assert_ne!(v1[4], v2[4]);
    }

    #[test]
    fn rejects_nonzero_alignment_padding() {
        let nl = random_netlist(19, 6, 1, &[(4, 2, 1)]);
        let plan = Arc::new(compile(&nl, PlanOptions::default()));
        let plain = write_nlb(&nl, None).unwrap();
        let pad = (8 - (plain.len() - 32) % 8) % 8;
        assert!(pad > 0, "pick a netlist whose section forces padding");
        let mut bytes = write_nlb(&nl, Some(&plan)).unwrap();
        bytes[plain.len()] = 1;
        let ph = fnv1a(&bytes[32..]).to_le_bytes();
        bytes[24..32].copy_from_slice(&ph);
        let err = read_nlb(&bytes).unwrap_err().to_string();
        assert!(err.contains("padding"), "unexpected error: {err}");
    }
}
