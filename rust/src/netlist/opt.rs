//! Netlist optimizer: a shared, semantics-preserving pass pipeline.
//!
//! Trained L-LUT tables are heavily structured — pruned supports,
//! constant bits, duplicated sub-functions — and before this module
//! every consumer rediscovered that structure independently (the
//! bit-plane kernel through support reduction, the mapper through its
//! own constant/duplicate analysis) while the netlist itself stayed raw
//! everywhere else: the RTL emitter wrote dead units, timing priced
//! them, the server simulated them on every request.  The optimizer
//! turns that observation into an IR transform performed **once**:
//! `optimize(&netlist, level)` returns a smaller netlist whose
//! *observable outputs are bit-exact* with the input for every possible
//! input vector, plus an [`OptReport`] of what each pass removed.
//! Mapping, timing, RTL emission and serving all consume the optimized
//! artifact (the raw netlist is kept around only as the worst-case /
//! ablation reference).
//!
//! Pass set (applied in pipeline order by [`PassManager::for_level`]):
//!
//! * **constant folding** ([`ConstantFold`]) — a forward sweep pins
//!   every consumer address bit that is fed by a constant producer bit
//!   (projecting the consumer table so the bit becomes a don't-care),
//!   then deletes units whose outputs are entirely constant: their
//!   consumers no longer read them.
//! * **dead-logic elimination** ([`DeadLogic`]) — duplicate-producer
//!   slots within a unit are merged (two slots wired to the same
//!   producer always carry equal fields, so the higher slot can mirror
//!   the lower and fall out of the support), unused slots are
//!   canonically repointed at producer 0, backward liveness from the
//!   primary outputs drops every unit no live consumer truly reads,
//!   and address slots dead across a whole layer are pruned with table
//!   projection (shrinking `fan_in` and the table size `2^(in_bits *
//!   fan_in)`).
//! * **common-subexpression elimination** ([`Cse`]) — units within a
//!   layer are hash-consed on `(conn, table)`; consumers of duplicates
//!   are rewired to the representative.  The canonical wiring produced
//!   by `DeadLogic` feeds this, which is why the full pipeline runs
//!   `DeadLogic` both before and after `Cse`.
//!
//! Soundness notes live on each helper: every rewrite is a table
//! projection that is the identity on all *reachable* addresses, a
//! deletion of units no consumer can observe, or an index remap.  The
//! output layer is never restructured (its width and unit order are the
//! observable interface), layers are never emptied (an anchor unit is
//! kept so the `LayerSpec` chain stays valid), and `fan_in` never
//! reaches zero (downstream emitters index address vectors).  The
//! property suite (`rust/tests/properties.rs`) proves bit-exactness
//! against `eval_one`/`eval_batch` on random reducible netlists across
//! seeds, levels and batch sizes.

use std::collections::HashMap;

use anyhow::bail;

use super::{LayerSpec, Netlist};

/// How aggressively to optimize.  Levels are cumulative.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// no passes; the netlist is returned unchanged (ablation baseline)
    None,
    /// constant folding + dead-logic elimination
    Basic,
    /// `Basic` + CSE (with a second dead-logic sweep after rewiring)
    #[default]
    Full,
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OptLevel::None => "O0",
            OptLevel::Basic => "O1",
            OptLevel::Full => "O2",
        })
    }
}

impl std::str::FromStr for OptLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<OptLevel> {
        match s {
            "0" | "none" | "O0" | "o0" => Ok(OptLevel::None),
            "1" | "basic" | "O1" | "o1" => Ok(OptLevel::Basic),
            "2" | "full" | "O2" | "o2" => Ok(OptLevel::Full),
            other => bail!("unknown opt level '{other}' (use 0|1|2)"),
        }
    }
}

/// One netlist-to-netlist rewrite whose contract is bit-exact
/// observable outputs for every input vector.
pub trait Pass {
    fn name(&self) -> &'static str;

    /// Rewrite the netlist.  The result must validate and must evaluate
    /// identically to `nl` on every input.
    fn run(&self, nl: &Netlist) -> Netlist;
}

/// What one pass changed, in netlist-size terms (units are L-LUTs;
/// table entries are the stored `u16` codes — the memory the simulator
/// walks and the ROM bits the RTL emits).  Mapped P-LUT deltas are the
/// mapper's to report: consumers compare `map_netlist` on the raw and
/// optimized netlists (the flow and CLI print both).
#[derive(Clone, Debug)]
pub struct PassDelta {
    pub pass: &'static str,
    pub units_before: usize,
    pub units_after: usize,
    pub table_entries_before: usize,
    pub table_entries_after: usize,
}

/// Aggregate record of one [`optimize`] run.
#[derive(Clone, Debug)]
pub struct OptReport {
    pub level: OptLevel,
    pub passes: Vec<PassDelta>,
    pub units_before: usize,
    pub units_after: usize,
    pub table_entries_before: usize,
    pub table_entries_after: usize,
}

impl OptReport {
    pub fn units_removed(&self) -> usize {
        self.units_before.saturating_sub(self.units_after)
    }

    pub fn table_entries_removed(&self) -> usize {
        self.table_entries_before
            .saturating_sub(self.table_entries_after)
    }

    /// One-line human summary for logs and CLI tables.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{}: {} -> {} L-LUTs, {} -> {} table entries",
            self.level, self.units_before, self.units_after,
            self.table_entries_before, self.table_entries_after
        );
        if !self.passes.is_empty() {
            let parts: Vec<String> = self
                .passes
                .iter()
                .map(|d| {
                    format!(
                        "{} -{}u/-{}e",
                        d.pass,
                        d.units_before.saturating_sub(d.units_after),
                        d.table_entries_before
                            .saturating_sub(d.table_entries_after)
                    )
                })
                .collect();
            s.push_str(&format!(" ({})", parts.join(", ")));
        }
        s
    }
}

/// An ordered pass pipeline.  [`PassManager::for_level`] builds the
/// standard pipelines; custom pipelines can be assembled from the
/// exported passes.
pub struct PassManager {
    level: OptLevel,
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    /// The standard pipeline for an optimization level.
    pub fn for_level(level: OptLevel) -> PassManager {
        let mut passes: Vec<Box<dyn Pass>> = Vec::new();
        if level >= OptLevel::Basic {
            passes.push(Box::new(ConstantFold));
            passes.push(Box::new(DeadLogic));
        }
        if level >= OptLevel::Full {
            passes.push(Box::new(Cse));
            passes.push(Box::new(DeadLogic));
        }
        PassManager { level, passes }
    }

    /// A custom pipeline (reported under the given level label).
    pub fn new(level: OptLevel, passes: Vec<Box<dyn Pass>>) -> PassManager {
        PassManager { level, passes }
    }

    pub fn pass_names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    /// Run the pipeline, recording per-pass size deltas.
    pub fn run(&self, nl: &Netlist) -> (Netlist, OptReport) {
        let mut cur = nl.clone();
        let mut passes = Vec::with_capacity(self.passes.len());
        if !nl.layers.is_empty() {
            for p in &self.passes {
                let units_before = cur.total_units();
                let table_entries_before = table_entries(&cur);
                cur = p.run(&cur);
                debug_assert!(
                    cur.validate().is_ok(),
                    "pass '{}' broke netlist invariants",
                    p.name()
                );
                passes.push(PassDelta {
                    pass: p.name(),
                    units_before,
                    units_after: cur.total_units(),
                    table_entries_before,
                    table_entries_after: table_entries(&cur),
                });
            }
        }
        let report = OptReport {
            level: self.level,
            passes,
            units_before: nl.total_units(),
            units_after: cur.total_units(),
            table_entries_before: table_entries(nl),
            table_entries_after: table_entries(&cur),
        };
        (cur, report)
    }
}

/// Optimize a netlist at the given level.  The returned netlist is
/// bit-exact with `nl` on every input; the report records what each
/// pass removed.
pub fn optimize(nl: &Netlist, level: OptLevel) -> (Netlist, OptReport) {
    PassManager::for_level(level).run(nl)
}

fn table_entries(nl: &Netlist) -> usize {
    nl.layers.iter().map(|l| l.tables.len()).sum()
}

fn rebuilt(nl: &Netlist, layers: Vec<LayerSpec>) -> Netlist {
    Netlist {
        name: nl.name.clone(),
        n_in: nl.n_in,
        in_bits: nl.in_bits,
        layers,
    }
}

/// Make address bit `a` of one unit's table a don't-care by copying the
/// cofactor where bit `a` equals `v` over the other cofactor.  Sound
/// when bit `a` can only ever carry `v` at run time (its producer bit
/// is constant): every reachable address keeps its old value.
fn fix_addr_bit(table: &mut [u16], a: usize, v: bool) {
    let stride = 1usize << a;
    for base in 0..table.len() {
        if base & stride == 0 {
            let keep = if v { table[base | stride] } else { table[base] };
            table[base] = keep;
            table[base | stride] = keep;
        }
    }
}

/// Project a unit's table so address slot `f2` becomes a don't-care by
/// reading the value at the address where slot `f2`'s field is replaced
/// with slot `f1`'s.  Sound when both slots are wired to the same
/// producer: their fields are always equal at run time, so reachable
/// addresses (field(f1) == field(f2)) keep their old value.
fn merge_dup_slot(table: &mut [u16], in_bits: usize, f1: usize, f2: usize) {
    let mask = (1usize << in_bits) - 1;
    let old = table.to_vec();
    for (addr, slot) in table.iter_mut().enumerate() {
        let v1 = (addr >> (in_bits * f1)) & mask;
        let src = (addr & !(mask << (in_bits * f2))) | (v1 << (in_bits * f2));
        *slot = old[src];
    }
}

/// Which address slots unit `u`'s table actually depends on (union of
/// the per-output-bit true supports, folded onto slots).
fn used_slots(layer: &LayerSpec, u: usize) -> Vec<bool> {
    let tt = layer.truth_table(u);
    let mut used = vec![false; layer.fan_in];
    for b in 0..layer.out_bits {
        for v in tt.bit_support(b) {
            used[v / layer.in_bits] = true;
        }
    }
    used
}

/// Drop the units of layer `l` whose `keep` flag is false and rewire
/// the consumer layer.  Callers guarantee that every consumer reference
/// to a dropped unit is either a don't-care slot (the consumer's table
/// ignores the slot's address bits) or has a kept replacement in
/// `redirect` (CSE: a representative computing the identical function).
/// A layer is never emptied: if nothing survives, unit 0 is kept as an
/// anchor so the `LayerSpec` chain stays structurally valid.
fn retain_units(layers: &mut [LayerSpec], l: usize, keep: &[bool],
                redirect: &HashMap<u32, u32>) {
    if keep.is_empty() {
        return;
    }
    let mut keep = keep.to_vec();
    if !keep.iter().any(|&k| k) {
        keep[0] = true;
    }
    if keep.iter().all(|&k| k) {
        return;
    }
    let mut new_idx = vec![u32::MAX; keep.len()];
    let mut n = 0u32;
    for (u, &k) in keep.iter().enumerate() {
        if k {
            new_idx[u] = n;
            n += 1;
        }
    }
    let first_kept = keep.iter().position(|&k| k).unwrap();
    {
        let layer = &mut layers[l];
        let epu = layer.entries_per_unit();
        let fan_in = layer.fan_in;
        let mut conn = Vec::with_capacity(n as usize * fan_in);
        let mut tables = Vec::with_capacity(n as usize * epu);
        for u in 0..layer.w {
            if keep[u] {
                conn.extend_from_slice(
                    &layer.conn[u * fan_in..(u + 1) * fan_in]);
                tables.extend_from_slice(
                    &layer.tables[u * epu..(u + 1) * epu]);
            }
        }
        layer.w = n as usize;
        layer.conn = conn;
        layer.tables = tables;
    }
    if l + 1 < layers.len() {
        for c in layers[l + 1].conn.iter_mut() {
            let mut p = *c as usize;
            if !keep[p] {
                p = match redirect.get(&(p as u32)) {
                    Some(&r) if keep[r as usize] => r as usize,
                    _ => first_kept,
                };
            }
            *c = new_idx[p];
        }
    }
}

/// Drop address slots no unit in the layer depends on, projecting every
/// table onto the surviving slots (dropped fields fixed to 0 — they are
/// don't-cares for every unit, so any fixing is sound).  At least one
/// slot is kept so `fan_in` never reaches zero.
fn prune_dead_slots(layer: &mut LayerSpec) {
    if layer.fan_in <= 1 || layer.w == 0 {
        return;
    }
    let mut keep = vec![false; layer.fan_in];
    for u in 0..layer.w {
        for (f, used) in used_slots(layer, u).into_iter().enumerate() {
            if used {
                keep[f] = true;
            }
        }
        if keep.iter().all(|&k| k) {
            return;
        }
    }
    if !keep.iter().any(|&k| k) {
        keep[0] = true;
    }
    let in_bits = layer.in_bits;
    let old_fan = layer.fan_in;
    let new_fan = keep.iter().filter(|&&k| k).count();
    let old_epu = layer.entries_per_unit();
    let new_epu = 1usize << (in_bits * new_fan);
    let mask = (1usize << in_bits) - 1;
    let mut conn = Vec::with_capacity(layer.w * new_fan);
    let mut tables = Vec::with_capacity(layer.w * new_epu);
    for u in 0..layer.w {
        let old_t = &layer.tables[u * old_epu..(u + 1) * old_epu];
        for addr in 0..new_epu {
            let mut old_addr = 0usize;
            let mut g = 0usize;
            for f in 0..old_fan {
                if keep[f] {
                    old_addr |=
                        ((addr >> (in_bits * g)) & mask) << (in_bits * f);
                    g += 1;
                }
            }
            tables.push(old_t[old_addr]);
        }
        for f in 0..old_fan {
            if keep[f] {
                conn.push(layer.conn[u * old_fan + f]);
            }
        }
    }
    layer.fan_in = new_fan;
    layer.conn = conn;
    layer.tables = tables;
}

/// Constant folding: pin consumer address bits fed by constant producer
/// bits (zero-support output bits are thereby hardwired into every
/// consumer), then delete units whose outputs are entirely constant —
/// after the pinning sweep no consumer table reads any of their bits.
pub struct ConstantFold;

impl Pass for ConstantFold {
    fn name(&self) -> &'static str {
        "const-fold"
    }

    fn run(&self, nl: &Netlist) -> Netlist {
        let mut layers = nl.layers.to_vec();
        let n = layers.len();
        // forward sweep: prev_const[s * in_bits + k] records whether bit
        // k of producer signal s is constant (inputs never are)
        let mut prev_const: Vec<Option<bool>> =
            vec![None; nl.n_in * nl.in_bits];
        let mut unit_const: Vec<Vec<bool>> = Vec::with_capacity(n);
        for layer in layers.iter_mut() {
            let epu = layer.entries_per_unit();
            let fan_in = layer.fan_in;
            let in_bits = layer.in_bits;
            for u in 0..layer.w {
                for f in 0..fan_in {
                    let src = layer.conn[u * fan_in + f] as usize;
                    for k in 0..in_bits {
                        if let Some(v) = prev_const[src * in_bits + k] {
                            fix_addr_bit(
                                &mut layer.tables
                                    [u * epu..(u + 1) * epu],
                                f * in_bits + k,
                                v,
                            );
                        }
                    }
                }
            }
            let mut consts = vec![None; layer.w * layer.out_bits];
            let mut all_const = vec![true; layer.w];
            for u in 0..layer.w {
                let tt = layer.truth_table(u);
                for b in 0..layer.out_bits {
                    let c = tt.bit_constant(b);
                    if c.is_none() {
                        all_const[u] = false;
                    }
                    consts[u * layer.out_bits + b] = c;
                }
            }
            prev_const = consts;
            unit_const.push(all_const);
        }
        // deletion sweep: fully-constant units (never the output layer —
        // constant primary outputs are observable and stay)
        for l in 0..n.saturating_sub(1) {
            let keep: Vec<bool> =
                unit_const[l].iter().map(|&c| !c).collect();
            retain_units(&mut layers, l, &keep, &HashMap::new());
        }
        rebuilt(nl, layers)
    }
}

/// Dead-logic elimination: duplicate-producer slot merging, backward
/// liveness from the primary outputs, canonical rewiring of unused
/// slots, and layer-wide dead address-slot pruning.
pub struct DeadLogic;

impl Pass for DeadLogic {
    fn name(&self) -> &'static str {
        "dead-logic"
    }

    fn run(&self, nl: &Netlist) -> Netlist {
        let mut layers = nl.layers.to_vec();
        let n = layers.len();
        if n == 0 {
            return rebuilt(nl, layers);
        }
        // 1. merge duplicate-producer slots so the higher slot leaves
        //    the support
        for layer in layers.iter_mut() {
            let fan_in = layer.fan_in;
            let in_bits = layer.in_bits;
            let epu = layer.entries_per_unit();
            for u in 0..layer.w {
                for f2 in 1..fan_in {
                    let src2 = layer.conn[u * fan_in + f2];
                    if let Some(f1) = (0..f2)
                        .find(|&f1| layer.conn[u * fan_in + f1] == src2)
                    {
                        merge_dup_slot(
                            &mut layer.tables[u * epu..(u + 1) * epu],
                            in_bits, f1, f2,
                        );
                    }
                }
            }
        }
        // 2. backward liveness; unused slots repointed at producer 0 on
        //    the way (their values cannot matter, and uniform wiring
        //    gives the CSE pass more hash-cons hits)
        let mut live: Vec<Vec<bool>> =
            layers.iter().map(|l| vec![false; l.w]).collect();
        for x in live[n - 1].iter_mut() {
            *x = true;
        }
        for l in (0..n).rev() {
            let layer = &mut layers[l];
            let fan_in = layer.fan_in;
            let mut used: Vec<Vec<bool>> = Vec::with_capacity(layer.w);
            for u in 0..layer.w {
                used.push(used_slots(layer, u));
            }
            for u in 0..layer.w {
                for f in 0..fan_in {
                    if !used[u][f] {
                        layer.conn[u * fan_in + f] = 0;
                    }
                }
            }
            if l > 0 {
                for u in 0..layer.w {
                    if !live[l][u] {
                        continue;
                    }
                    for f in 0..fan_in {
                        if used[u][f] {
                            let src =
                                layer.conn[u * fan_in + f] as usize;
                            live[l - 1][src] = true;
                        }
                    }
                }
            }
        }
        // 3. drop dead units (consumer references to them are unused
        //    slots, so the fallback rewiring in retain_units is sound)
        for l in 0..n.saturating_sub(1) {
            let keep = live[l].clone();
            retain_units(&mut layers, l, &keep, &HashMap::new());
        }
        // 4. prune address slots dead across each whole layer
        for layer in layers.iter_mut() {
            prune_dead_slots(layer);
        }
        rebuilt(nl, layers)
    }
}

/// Common-subexpression elimination: hash-cons units within a layer on
/// `(conn, table)` and rewire consumers of duplicates onto the
/// representative.  The output layer is skipped — its units are the
/// observable interface even when two compute the same function.
pub struct Cse;

impl Pass for Cse {
    fn name(&self) -> &'static str {
        "cse"
    }

    fn run(&self, nl: &Netlist) -> Netlist {
        let mut layers = nl.layers.to_vec();
        let n = layers.len();
        for l in 0..n.saturating_sub(1) {
            let (keep, redirect) = {
                let layer = &layers[l];
                let mut seen: HashMap<(Vec<u32>, Vec<u16>), u32> =
                    HashMap::new();
                let mut keep = vec![true; layer.w];
                let mut redirect: HashMap<u32, u32> = HashMap::new();
                for u in 0..layer.w {
                    let key = (layer.unit_conn(u).to_vec(),
                               layer.unit_table(u).to_vec());
                    match seen.entry(key) {
                        std::collections::hash_map::Entry::Occupied(e) => {
                            keep[u] = false;
                            redirect.insert(u as u32, *e.get());
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(u as u32);
                        }
                    }
                }
                (keep, redirect)
            };
            if !keep.iter().all(|&k| k) {
                retain_units(&mut layers, l, &keep, &redirect);
            }
        }
        rebuilt(nl, layers)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{random_inputs,
                                 random_reducible_netlist};
    use super::*;

    fn assert_bit_exact(raw: &Netlist, opt: &Netlist, seed: u64,
                        batch: usize) {
        assert_eq!(opt.n_in, raw.n_in);
        assert_eq!(opt.out_width(), raw.out_width());
        opt.validate().unwrap();
        let x = random_inputs(seed, raw, batch);
        for b in 0..batch {
            let row = &x[b * raw.n_in..(b + 1) * raw.n_in];
            assert_eq!(opt.eval_one(row).unwrap(),
                       raw.eval_one(row).unwrap(), "row {b}");
        }
    }

    #[test]
    fn constant_producer_is_absorbed_and_deleted() {
        // layer 0: unit 0 constant-1, unit 1 identity; layer 1: AND
        let l0 = LayerSpec {
            w: 2, fan_in: 1, in_bits: 1, out_bits: 1,
            conn: vec![0, 1],
            tables: vec![1, 1, 0, 1],
        };
        let l1 = LayerSpec {
            w: 1, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 1],
            tables: vec![0, 0, 0, 1],
        };
        let nl = Netlist { name: "cf".into(), n_in: 2, in_bits: 1,
                           layers: vec![l0, l1] };
        nl.validate().unwrap();
        let (opt, report) = optimize(&nl, OptLevel::Full);
        assert_bit_exact(&nl, &opt, 1, 4);
        // the constant unit is gone; the AND collapsed to a wire whose
        // dead slot was pruned away
        assert_eq!(opt.total_units(), 2);
        assert_eq!(opt.layers[0].w, 1);
        assert_eq!(opt.layers[1].fan_in, 1);
        assert_eq!(report.units_removed(), 1);
        assert!(report.table_entries_removed() > 0);
    }

    #[test]
    fn dead_units_are_dropped_by_liveness() {
        // layer 0 has 3 units; only unit 2 is read by the output
        let l0 = LayerSpec {
            w: 3, fan_in: 1, in_bits: 1, out_bits: 1,
            conn: vec![0, 1, 0],
            tables: vec![0, 1, 1, 0, 0, 1],
        };
        let l1 = LayerSpec {
            w: 1, fan_in: 1, in_bits: 1, out_bits: 1,
            conn: vec![2],
            tables: vec![1, 0],
        };
        let nl = Netlist { name: "dce".into(), n_in: 2, in_bits: 1,
                           layers: vec![l0, l1] };
        nl.validate().unwrap();
        let (opt, report) = optimize(&nl, OptLevel::Basic);
        assert_bit_exact(&nl, &opt, 2, 4);
        assert_eq!(opt.layers[0].w, 1);
        assert_eq!(report.units_removed(), 2);
    }

    #[test]
    fn duplicate_units_are_hash_consed() {
        // two identical XOR units + one OR, all live: the consumer
        // computes a ^ (b & c) over units (0, 1, 2)
        let xor = vec![0u16, 1, 1, 0];
        let or = vec![0u16, 1, 1, 1];
        let l0 = LayerSpec {
            w: 3, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 1, 0, 1, 0, 1],
            tables: [xor.clone(), xor.clone(), or].concat(),
        };
        let l1 = LayerSpec {
            w: 1, fan_in: 3, in_bits: 1, out_bits: 1,
            conn: vec![0, 1, 2],
            tables: vec![0, 1, 0, 1, 0, 1, 1, 0],
        };
        let nl = Netlist { name: "cse".into(), n_in: 2, in_bits: 1,
                           layers: vec![l0, l1] };
        nl.validate().unwrap();
        let (opt, _) = optimize(&nl, OptLevel::Full);
        assert_bit_exact(&nl, &opt, 3, 4);
        assert_eq!(opt.layers[0].w, 2, "duplicate XOR must be shared");
        // the consumer's two XOR slots merged, so one was pruned away
        assert_eq!(opt.layers[1].fan_in, 2);
        // Basic has no CSE: all three units stay (all are live)
        let (basic, _) = optimize(&nl, OptLevel::Basic);
        assert_eq!(basic.layers[0].w, 3);
        assert_eq!(basic.layers[1].fan_in, 3);
    }

    #[test]
    fn duplicate_producer_slots_merge_and_prune() {
        // one unit reading input 0 twice: XOR(x, x) == 0, but the
        // rewrite must stay sound for any table — use f(a,b) = a
        let l0 = LayerSpec {
            w: 1, fan_in: 2, in_bits: 1, out_bits: 1,
            conn: vec![0, 0],
            tables: vec![0, 1, 0, 1],
        };
        let nl = Netlist { name: "dup".into(), n_in: 1, in_bits: 1,
                           layers: vec![l0] };
        nl.validate().unwrap();
        let (opt, _) = optimize(&nl, OptLevel::Basic);
        assert_bit_exact(&nl, &opt, 4, 2);
        assert_eq!(opt.layers[0].fan_in, 1, "dead slot must be pruned");
        assert_eq!(opt.layers[0].tables, vec![0, 1]);
    }

    #[test]
    fn all_constant_cascade_keeps_anchors() {
        // every unit in layers 0/1 collapses to a constant; anchors
        // keep the layer chain valid and the output is preserved
        let l0 = LayerSpec {
            w: 2, fan_in: 1, in_bits: 1, out_bits: 1,
            conn: vec![0, 1],
            tables: vec![1, 1, 0, 0],
        };
        let l1 = LayerSpec {
            w: 2, fan_in: 2, in_bits: 1, out_bits: 2,
            conn: vec![0, 1, 1, 0],
            tables: vec![3, 2, 1, 0, 3, 2, 1, 0],
        };
        let l2 = LayerSpec {
            w: 1, fan_in: 1, in_bits: 2, out_bits: 2,
            conn: vec![1],
            tables: vec![0, 1, 2, 3],
        };
        let nl = Netlist { name: "anchor".into(), n_in: 2, in_bits: 1,
                           layers: vec![l0, l1, l2] };
        nl.validate().unwrap();
        for level in [OptLevel::Basic, OptLevel::Full] {
            let (opt, _) = optimize(&nl, level);
            assert_bit_exact(&nl, &opt, 5, 4);
            assert!(opt.layers.iter().all(|l| l.w >= 1 && l.fan_in >= 1));
        }
    }

    #[test]
    fn level_none_is_identity() {
        let nl = random_reducible_netlist(
            71, 10, 2, &[(8, 2, 2), (4, 2, 2)], 6);
        let (opt, report) = optimize(&nl, OptLevel::None);
        assert!(report.passes.is_empty());
        assert_eq!(report.units_removed(), 0);
        assert_eq!(opt.layers.len(), nl.layers.len());
        for (a, b) in opt.layers.iter().zip(nl.layers.iter()) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.conn, b.conn);
            assert_eq!(a.tables, b.tables);
        }
    }

    #[test]
    fn reducible_netlist_shrinks_and_stays_exact() {
        let nl = random_reducible_netlist(
            73, 16, 2, &[(24, 3, 2), (12, 2, 2), (4, 2, 2)], 6);
        let (opt, report) = optimize(&nl, OptLevel::Full);
        assert_bit_exact(&nl, &opt, 6, 64);
        assert!(report.units_after <= report.units_before);
        assert!(report.table_entries_after
                <= report.table_entries_before);
        // per-pass accounting chains: each pass starts where the
        // previous ended, and the ends match the aggregate
        for w in report.passes.windows(2) {
            assert_eq!(w[0].units_after, w[1].units_before);
        }
        assert_eq!(report.passes.first().unwrap().units_before,
                   report.units_before);
        assert_eq!(report.passes.last().unwrap().units_after,
                   report.units_after);
    }

    #[test]
    fn pipeline_for_levels() {
        assert!(PassManager::for_level(OptLevel::None)
            .pass_names().is_empty());
        assert_eq!(PassManager::for_level(OptLevel::Basic).pass_names(),
                   vec!["const-fold", "dead-logic"]);
        assert_eq!(PassManager::for_level(OptLevel::Full).pass_names(),
                   vec!["const-fold", "dead-logic", "cse", "dead-logic"]);
    }

    #[test]
    fn opt_level_parse_and_display() {
        for (s, want) in [("0", OptLevel::None), ("none", OptLevel::None),
                          ("1", OptLevel::Basic), ("basic", OptLevel::Basic),
                          ("2", OptLevel::Full), ("full", OptLevel::Full),
                          ("O2", OptLevel::Full)] {
            assert_eq!(s.parse::<OptLevel>().unwrap(), want, "{s}");
        }
        assert!("3".parse::<OptLevel>().is_err());
        assert_eq!(OptLevel::Full.to_string(), "O2");
        assert_eq!(OptLevel::default(), OptLevel::Full);
        assert!(OptLevel::None < OptLevel::Basic);
        assert!(OptLevel::Basic < OptLevel::Full);
    }

    #[test]
    fn summary_mentions_level_and_passes() {
        let nl = random_reducible_netlist(
            77, 12, 1, &[(10, 3, 1), (4, 2, 1)], 4);
        let (_, report) = optimize(&nl, OptLevel::Full);
        let s = report.summary();
        assert!(s.starts_with("O2:"), "{s}");
        assert!(s.contains("const-fold") && s.contains("cse"), "{s}");
    }
}
