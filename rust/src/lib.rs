//! # NeuraLUT-Assemble
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *NeuraLUT-Assemble: Hardware-aware Assembling of Sub-Neural Networks
//! for Efficient LUT Inference* (Andronic & Constantinides, 2025).
//!
//! Layer map:
//! * **L1/L2** live in `python/compile/` and run only at build time
//!   (`make artifacts`), producing HLO-text executables.
//! * **L3** is this crate: the toolflow coordinator (train → prune →
//!   retrain → enumerate → map → time → RTL), every hardware substrate
//!   (netlist simulator, technology mapper, timing model, RTL emitter),
//!   datasets, baselines, a batching inference server, and the benchmark
//!   harnesses that regenerate the paper's tables and figures.
//!
//! See README.md for the quickstart and module map, DESIGN.md for the
//! system inventory and EXPERIMENTS.md for paper-vs-measured results
//! and the hot-path benchmark numbers.

// Unsafe is confined to two audited islands, each carrying an explicit
// item- or module-level `allow` with a SAFETY argument:
// `netlist::mapped` (mmap FFI + arena borrowing) and the lifetime-erased
// worker-pool plumbing in `netlist::sim`.  CI greps for exactly this
// confinement; everything else is denied here.
#![deny(unsafe_code)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod luts;
pub mod mapper;
pub mod metrics;
pub mod net;
pub mod netlist;
pub mod pruning;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod timing;
pub mod util;
