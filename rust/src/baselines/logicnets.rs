//! LogicNets-style baseline: extremely sparse *linear* neurons absorbed
//! into L-LUTs (Umuroglu et al., FPL'20).
//!
//! Each unit computes `quant(act(sum_i w_i * decode(c_i) + b))` over a
//! fixed random subset of `F` producers — a continuous piecewise-linear
//! function per L-LUT, versus NeuraLUT-Assemble's hidden MLPs.  Training
//! (STE fake-quant, SGD with momentum) runs in pure rust: this baseline
//! deliberately exercises none of the JAX path, demonstrating that the
//! downstream netlist/mapping/timing substrates are model-agnostic.

use anyhow::Result;

use crate::dataset::Dataset;
use crate::netlist::{LayerSpec, Netlist};
use crate::pruning;
use crate::util::Rng;

/// LogicNets-like architecture: widths/fan-ins/bits per layer.
#[derive(Clone, Debug)]
pub struct LogicNetsConfig {
    pub n_in: usize,
    pub beta_in: usize,
    pub w: Vec<usize>,
    pub f: Vec<usize>,
    pub beta: Vec<usize>,
    pub n_classes: usize,
    pub seed: u64,
}

impl LogicNetsConfig {
    /// The paper's NID-scale LogicNets point (scaled like our presets).
    pub fn nid() -> LogicNetsConfig {
        LogicNetsConfig {
            n_in: 593,
            beta_in: 1,
            w: vec![64, 32, 1],
            f: vec![6, 4, 4],
            beta: vec![2, 2, 2],
            n_classes: 1,
            seed: 11,
        }
    }

    /// JSC-scale configuration.
    pub fn jsc() -> LogicNetsConfig {
        LogicNetsConfig {
            n_in: 16,
            beta_in: 4,
            w: vec![64, 32, 5],
            f: vec![2, 2, 2],
            beta: vec![4, 4, 8],
            n_classes: 5,
            seed: 13,
        }
    }

    fn in_width(&self, l: usize) -> usize {
        if l == 0 { self.n_in } else { self.w[l - 1] }
    }

    fn in_bits(&self, l: usize) -> usize {
        if l == 0 { self.beta_in } else { self.beta[l - 1] }
    }
}

/// Midrise decode (mirrors `quant.decode`).
fn decode(c: i32, s: f32, bits: usize) -> f32 {
    let levels = (1usize << bits) as f32;
    s * ((2.0 * c as f32 + 1.0) / levels - 1.0)
}

/// Midrise encode with clipping (mirrors `quant.encode`).
fn encode(x: f32, s: f32, bits: usize) -> i32 {
    let half = (1i64 << (bits - 1)) as f32;
    let c = (x / s * half).floor() as i64 + half as i64;
    c.clamp(0, (1i64 << bits) - 1) as i32
}

struct Layer {
    conn: Vec<Vec<u32>>,
    /// per-unit weights [w][F] and bias
    w: Vec<Vec<f32>>,
    b: Vec<f32>,
    /// momentum buffers
    mw: Vec<Vec<f32>>,
    mb: Vec<f32>,
    /// output scale (fixed; LogicNets uses fixed scale factors)
    scale: f32,
    bits: usize,
    relu: bool,
}

/// A trained LogicNets-style model.
pub struct LogicNetsModel {
    cfg: LogicNetsConfig,
    layers: Vec<Layer>,
}

impl LogicNetsModel {
    /// Random-connectivity init (the defining LogicNets choice).
    pub fn new(cfg: &LogicNetsConfig) -> LogicNetsModel {
        let mut rng = Rng::new(cfg.seed);
        let mut layers = Vec::new();
        for l in 0..cfg.w.len() {
            let p = cfg.in_width(l);
            let conn = pruning::random_connections(cfg.w[l], p, cfg.f[l], &mut rng);
            let std = (2.0 / cfg.f[l] as f32).sqrt();
            let w: Vec<Vec<f32>> = (0..cfg.w[l])
                .map(|_| (0..cfg.f[l]).map(|_| rng.normal() * std).collect())
                .collect();
            layers.push(Layer {
                conn,
                mw: vec![vec![0.0; cfg.f[l]]; cfg.w[l]],
                mb: vec![0.0; cfg.w[l]],
                w,
                b: vec![0.0; cfg.w[l]],
                scale: 2.0,
                bits: cfg.beta[l],
                relu: l + 1 < cfg.w.len(),
            });
        }
        LogicNetsModel { cfg: cfg.clone(), layers }
    }

    /// Forward with straight-through quantization.  Returns per-layer
    /// pre-activation values and the final logits.
    fn forward(&self, x_codes: &[i32]) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.layers.len() + 1);
        let first: Vec<f32> = x_codes
            .iter()
            .map(|&c| decode(c, 1.0, self.cfg.beta_in))
            .collect();
        acts.push(first);
        for (l, layer) in self.layers.iter().enumerate() {
            let prev = &acts[l];
            let mut out = Vec::with_capacity(layer.w.len());
            for u in 0..layer.w.len() {
                let mut acc = layer.b[u];
                for (k, &src) in layer.conn[u].iter().enumerate() {
                    acc += layer.w[u][k] * prev[src as usize];
                }
                if layer.relu {
                    acc = acc.max(0.0);
                }
                out.push(acc);
            }
            let is_last = l + 1 == self.layers.len();
            let quantized: Vec<f32> = if is_last {
                out.clone() // logits stay continuous for the loss
            } else {
                out.iter()
                    .map(|&v| decode(encode(v, layer.scale, layer.bits),
                                     layer.scale, layer.bits))
                    .collect()
            };
            acts.push(quantized);
            if is_last {
                return (acts, out);
            }
        }
        unreachable!()
    }

    /// One SGD-with-momentum step on a single sample (STE backward).
    fn step(&mut self, x_codes: &[i32], y: i32, lr: f32) -> f32 {
        let (acts, logits) = self.forward(x_codes);
        // loss gradient on logits
        let k = self.layers.last().unwrap().w.len();
        let mut grad = vec![0.0f32; k];
        let loss;
        if self.cfg.n_classes > 1 {
            let max = logits.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            loss = -(exps[y as usize] / sum).ln();
            for i in 0..k {
                grad[i] = exps[i] / sum - if i == y as usize { 1.0 } else { 0.0 };
            }
        } else {
            let z = logits[0];
            let p = 1.0 / (1.0 + (-z).exp());
            loss = if y == 1 { -(p.max(1e-7)).ln() } else { -((1.0 - p).max(1e-7)).ln() };
            grad[0] = p - y as f32;
        }
        // backprop with STE through quantizers (identity in clip range)
        for l in (0..self.layers.len()).rev() {
            let prev = acts[l].clone();
            let mut prev_grad = vec![0.0f32; prev.len()];
            let layer = &mut self.layers[l];
            for u in 0..layer.w.len() {
                let mut g = grad[u];
                if layer.relu {
                    // recompute pre-act sign cheaply from stored activation
                    // (activation 0 means relu clipped)
                    let mut acc = layer.b[u];
                    for (k2, &src) in layer.conn[u].iter().enumerate() {
                        acc += layer.w[u][k2] * prev[src as usize];
                    }
                    if acc <= 0.0 {
                        g = 0.0;
                    }
                }
                for (k2, &src) in layer.conn[u].iter().enumerate() {
                    let gw = g * prev[src as usize];
                    layer.mw[u][k2] = 0.9 * layer.mw[u][k2] + gw;
                    prev_grad[src as usize] += g * layer.w[u][k2];
                }
                layer.mb[u] = 0.9 * layer.mb[u] + g;
            }
            for u in 0..layer.w.len() {
                for k2 in 0..layer.w[u].len() {
                    layer.w[u][k2] -= lr * layer.mw[u][k2];
                }
                layer.b[u] -= lr * layer.mb[u];
            }
            grad = prev_grad;
        }
        loss
    }

    /// Train with SGD over the dataset.
    pub fn train(&mut self, data: &Dataset, epochs: usize, lr: f32) -> f32 {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7141);
        let mut last = 0.0;
        for e in 0..epochs {
            let order = rng.permutation(data.n);
            let decayed = lr * 0.5f32.powi(e as i32 / 4);
            let mut sum = 0.0;
            for &i in &order {
                sum += self.step(data.row(i), data.y[i], decayed);
            }
            last = sum / data.n as f32;
        }
        last
    }

    /// Quantized-inference prediction for one sample.
    pub fn predict(&self, x_codes: &[i32]) -> i32 {
        let (_, logits) = self.forward(x_codes);
        if self.cfg.n_classes > 1 {
            let mut best = 0;
            for i in 1..logits.len() {
                if logits[i] > logits[best] {
                    best = i;
                }
            }
            best as i32
        } else {
            (logits[0] > 0.0) as i32
        }
    }

    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let hits = (0..data.n)
            .filter(|&i| self.predict(data.row(i)) == data.y[i])
            .count();
        hits as f64 / data.n as f64
    }

    /// Absorb every neuron into an L-LUT by enumeration (pure rust) and
    /// emit the netlist — same downstream pipeline as the main model.
    pub fn to_netlist(&self) -> Result<Netlist> {
        let cfg = &self.cfg;
        let mut specs = Vec::new();
        for (l, layer) in self.layers.iter().enumerate() {
            let in_bits = cfg.in_bits(l);
            let entries = 1usize << (in_bits * cfg.f[l]);
            let in_scale = if l == 0 { 1.0 } else { self.layers[l - 1].scale };
            let is_last = l + 1 == self.layers.len();
            let mut tables = Vec::with_capacity(cfg.w[l] * entries);
            for u in 0..cfg.w[l] {
                for addr in 0..entries {
                    let mut acc = layer.b[u];
                    for k in 0..cfg.f[l] {
                        let c = ((addr >> (in_bits * k)) & ((1 << in_bits) - 1)) as i32;
                        acc += layer.w[u][k] * decode(c, in_scale, in_bits);
                    }
                    if layer.relu {
                        acc = acc.max(0.0);
                    }
                    let _ = is_last;
                    tables.push(encode(acc, layer.scale, layer.bits) as u16);
                }
            }
            let conn: Vec<u32> = layer.conn.iter().flatten().copied().collect();
            specs.push(LayerSpec {
                w: cfg.w[l],
                fan_in: cfg.f[l],
                in_bits,
                out_bits: layer.bits,
                conn,
                tables,
            });
        }
        Netlist::from_parts("logicnets", cfg.n_in, cfg.beta_in, specs)
    }

    /// Netlist-level accuracy (prediction from quantized output codes).
    pub fn netlist_accuracy(&self, nl: &Netlist, data: &Dataset) -> Result<f64> {
        let out = nl.eval_batch(&data.x, data.n)?;
        let w = nl.out_width();
        let ob = nl.out_bits();
        let preds: Vec<i32> = (0..data.n)
            .map(|i| {
                let row = &out[i * w..(i + 1) * w];
                if self.cfg.n_classes > 1 {
                    let mut best = 0usize;
                    for j in 1..w {
                        if row[j] > row[best] {
                            best = j;
                        }
                    }
                    best as i32
                } else {
                    (row[0] >= (1 << (ob - 1))) as i32
                }
            })
            .collect();
        Ok(crate::metrics::accuracy(&preds, &data.y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{synthetic_blobs, GenOpts};

    #[test]
    fn trains_above_chance_on_blobs() {
        let opts = GenOpts { n_train: 600, n_test: 200, ..Default::default() };
        let splits = synthetic_blobs(12, 2, 2, &opts);
        let cfg = LogicNetsConfig {
            n_in: 12, beta_in: 2, w: vec![16, 1], f: vec![4, 4],
            beta: vec![2, 2], n_classes: 1, seed: 5,
        };
        let mut model = LogicNetsModel::new(&cfg);
        model.train(&splits.train, 6, 0.02);
        let acc = model.accuracy(&splits.test);
        assert!(acc > 0.65, "accuracy {acc}");
    }

    #[test]
    fn netlist_conversion_is_valid_and_close() {
        let opts = GenOpts { n_train: 400, n_test: 150, ..Default::default() };
        let splits = synthetic_blobs(12, 2, 2, &opts);
        let cfg = LogicNetsConfig {
            n_in: 12, beta_in: 2, w: vec![12, 1], f: vec![3, 4],
            beta: vec![2, 3], n_classes: 1, seed: 6,
        };
        let mut model = LogicNetsModel::new(&cfg);
        model.train(&splits.train, 5, 0.02);
        let nl = model.to_netlist().unwrap();
        nl.validate().unwrap();
        let float_acc = model.accuracy(&splits.test);
        let lut_acc = model.netlist_accuracy(&nl, &splits.test).unwrap();
        // final-layer logits are quantized in the netlist: small gap allowed
        assert!((float_acc - lut_acc).abs() < 0.15,
                "float {float_acc} vs lut {lut_acc}");
    }

    #[test]
    fn presets_construct() {
        LogicNetsModel::new(&LogicNetsConfig::nid());
        LogicNetsModel::new(&LogicNetsConfig::jsc());
    }
}
