//! TreeLUT-style baseline: gradient-boosted decision trees mapped onto
//! LUTs (Khataei & Bazargan, FPGA'25).
//!
//! Implements classic gradient boosting with depth-bounded regression
//! trees over the quantized input codes (one-vs-rest for multi-class,
//! logistic for binary), plus the hardware cost model TreeLUT's evaluation
//! relies on: every internal node is a `beta_in`-bit comparator against a
//! constant (<= 1 P-LUT for beta <= 6), leaf values are quantized to a
//! small fixed width and summed by a balanced adder tree whose cost is
//! counted per output bit, and the whole design is 1-2 pipeline stages.

use crate::dataset::Dataset;
use crate::mapper::{MappedLayer, MappedNetlist};
use crate::util::Rng;

/// Boosting hyper-parameters.
#[derive(Clone, Debug)]
pub struct TreeLutConfig {
    pub n_trees: usize,
    pub depth: usize,
    pub lr: f32,
    /// leaf-value quantization bits (TreeLUT quantizes leaves)
    pub leaf_bits: usize,
    pub seed: u64,
}

impl Default for TreeLutConfig {
    fn default() -> Self {
        TreeLutConfig { n_trees: 24, depth: 3, lr: 0.35, leaf_bits: 5, seed: 3 }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf(f32),
    Split { feat: usize, thr: i32, left: usize, right: usize },
}

#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn eval(&self, row: &[i32]) -> f32 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf(v) => return *v,
                Node::Split { feat, thr, left, right } => {
                    i = if row[*feat] <= *thr { *left } else { *right };
                }
            }
        }
    }

    fn internal_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Split { .. })).count()
    }
}

/// Fit one regression tree to residuals by greedy variance reduction.
fn fit_tree(data: &Dataset, idx: &[usize], resid: &[f32], depth: usize,
            rng: &mut Rng) -> Tree {
    let mut nodes = Vec::new();
    build(data, idx, resid, depth, &mut nodes, rng);
    Tree { nodes }
}

fn build(data: &Dataset, idx: &[usize], resid: &[f32], depth: usize,
         nodes: &mut Vec<Node>, rng: &mut Rng) -> usize {
    let mean = if idx.is_empty() {
        0.0
    } else {
        idx.iter().map(|&i| resid[i]).sum::<f32>() / idx.len() as f32
    };
    if depth == 0 || idx.len() < 8 {
        nodes.push(Node::Leaf(mean));
        return nodes.len() - 1;
    }
    // candidate features: random subset (stochastic GBM)
    let n_try = (data.n_in as f64).sqrt().ceil() as usize + 1;
    let feats = rng.sample_distinct(data.n_in, n_try.min(data.n_in));
    let base_score: f32 = idx.iter().map(|&i| (resid[i] - mean).powi(2)).sum();
    let mut best: Option<(usize, i32, f32)> = None;
    let max_code = (1 << data.beta_in) - 1;
    for &f in &feats {
        for thr in 0..max_code {
            let (mut sl, mut nl, mut sr, mut nr) = (0f32, 0usize, 0f32, 0usize);
            for &i in idx {
                if data.row(i)[f] <= thr {
                    sl += resid[i];
                    nl += 1;
                } else {
                    sr += resid[i];
                    nr += 1;
                }
            }
            if nl < 4 || nr < 4 {
                continue;
            }
            let ml = sl / nl as f32;
            let mr = sr / nr as f32;
            // variance reduction = n_l*m_l^2 + n_r*m_r^2 - n*m^2 (up to const)
            let gain = nl as f32 * ml * ml + nr as f32 * mr * mr
                - idx.len() as f32 * mean * mean;
            if gain > best.map(|b| b.2).unwrap_or(1e-6) {
                best = Some((f, thr, gain));
            }
        }
    }
    let _ = base_score;
    match best {
        None => {
            nodes.push(Node::Leaf(mean));
            nodes.len() - 1
        }
        Some((feat, thr, _)) => {
            let (li, ri): (Vec<usize>, Vec<usize>) =
                idx.iter().partition(|&&i| data.row(i)[feat] <= thr);
            let me = nodes.len();
            nodes.push(Node::Leaf(0.0)); // placeholder
            let left = build(data, &li, resid, depth - 1, nodes, rng);
            let right = build(data, &ri, resid, depth - 1, nodes, rng);
            nodes[me] = Node::Split { feat, thr, left, right };
            me
        }
    }
}

/// A trained TreeLUT-style ensemble (K one-vs-rest boosters, or 1 for
/// binary tasks).
pub struct TreeLutModel {
    cfg: TreeLutConfig,
    n_classes: usize,
    /// boosters[class][round]
    boosters: Vec<Vec<Tree>>,
    base: Vec<f32>,
}

impl TreeLutModel {
    pub fn train(data: &Dataset, cfg: &TreeLutConfig) -> TreeLutModel {
        let k = data.n_classes.max(2);
        let heads = if k == 2 { 1 } else { k };
        let mut rng = Rng::new(cfg.seed);
        let idx: Vec<usize> = (0..data.n).collect();
        let mut boosters = Vec::with_capacity(heads);
        let mut base = Vec::with_capacity(heads);
        for class in 0..heads {
            let targets: Vec<f32> = (0..data.n)
                .map(|i| {
                    let pos = if heads == 1 { data.y[i] == 1 } else { data.y[i] as usize == class };
                    if pos { 1.0 } else { 0.0 }
                })
                .collect();
            let prior = targets.iter().sum::<f32>() / data.n as f32;
            let b0 = (prior.max(1e-4) / (1.0 - prior).max(1e-4)).ln();
            let mut scores = vec![b0; data.n];
            let mut trees = Vec::with_capacity(cfg.n_trees);
            for _ in 0..cfg.n_trees {
                // logistic gradient
                let resid: Vec<f32> = (0..data.n)
                    .map(|i| targets[i] - 1.0 / (1.0 + (-scores[i]).exp()))
                    .collect();
                let tree = fit_tree(data, &idx, &resid, cfg.depth, &mut rng);
                for i in 0..data.n {
                    scores[i] += cfg.lr * tree.eval(data.row(i));
                }
                trees.push(tree);
            }
            boosters.push(trees);
            base.push(b0);
        }
        TreeLutModel { cfg: cfg.clone(), n_classes: k, boosters, base }
    }

    fn score(&self, row: &[i32], head: usize) -> f32 {
        self.base[head]
            + self.cfg.lr
                * self.boosters[head].iter().map(|t| t.eval(row)).sum::<f32>()
    }

    pub fn predict(&self, row: &[i32]) -> i32 {
        if self.boosters.len() == 1 {
            (self.score(row, 0) > 0.0) as i32
        } else {
            let mut best = 0usize;
            let mut bs = f32::MIN;
            for h in 0..self.boosters.len() {
                let s = self.score(row, h);
                if s > bs {
                    bs = s;
                    best = h;
                }
            }
            best as i32
        }
    }

    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let hits = (0..data.n)
            .filter(|&i| self.predict(data.row(i)) == data.y[i])
            .count();
        hits as f64 / data.n as f64
    }

    /// TreeLUT hardware cost model, expressed as a `MappedNetlist` so the
    /// shared timing model produces Fmax/latency/ADP for Table IV.
    ///
    /// * comparator layer: one P-LUT per internal node (beta_in <= 6 bit
    ///   compare-to-constant), depth 1;
    /// * per-tree leaf mux: path muxes fold into ~depth/2 LUT levels;
    /// * adder tree over quantized leaf values: (n_trees - 1) adders per
    ///   head, `leaf_bits + log2(n_trees)` LUTs each, log2(n_trees) levels.
    pub fn hardware_model(&self) -> MappedNetlist {
        let heads = self.boosters.len();
        let internal: usize = self
            .boosters
            .iter()
            .flat_map(|ts| ts.iter().map(|t| t.internal_nodes()))
            .sum();
        let trees_per_head = self.cfg.n_trees;
        let sum_bits = self.cfg.leaf_bits
            + (usize::BITS - (trees_per_head.max(1)).leading_zeros()) as usize;
        let mux_luts: usize = heads * trees_per_head * (1 << (self.cfg.depth - 1));
        let adders = heads * trees_per_head.saturating_sub(1) * sum_bits;
        let levels = (usize::BITS - (trees_per_head.max(1)).leading_zeros()) as f64;
        let layers = vec![
            // comparators + leaf muxes (combinational front)
            MappedLayer {
                luts: internal + mux_luts,
                depth: 1.0 + (self.cfg.depth as f64) / 2.0,
                out_bits_total: heads * trees_per_head * self.cfg.leaf_bits,
                luts_worst_case: internal + mux_luts,
            },
            // adder tree
            MappedLayer {
                luts: adders,
                depth: levels,
                out_bits_total: heads * sum_bits,
                luts_worst_case: adders,
            },
        ];
        MappedNetlist { layers, input_bits: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{synthetic_blobs, GenOpts};

    #[test]
    fn boosting_learns_blobs() {
        let opts = GenOpts { n_train: 600, n_test: 200, ..Default::default() };
        let s = synthetic_blobs(10, 2, 3, &opts);
        let model = TreeLutModel::train(
            &s.train,
            &TreeLutConfig { n_trees: 12, depth: 3, ..Default::default() },
        );
        let acc = model.accuracy(&s.test);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn multiclass_one_vs_rest() {
        let opts = GenOpts { n_train: 900, n_test: 300, ..Default::default() };
        let s = synthetic_blobs(10, 3, 3, &opts);
        let model = TreeLutModel::train(
            &s.train,
            &TreeLutConfig { n_trees: 10, depth: 3, ..Default::default() },
        );
        assert_eq!(model.boosters.len(), 3);
        let acc = model.accuracy(&s.test);
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn hardware_model_scales_with_trees() {
        let opts = GenOpts { n_train: 300, n_test: 100, ..Default::default() };
        let s = synthetic_blobs(8, 2, 2, &opts);
        let small = TreeLutModel::train(
            &s.train, &TreeLutConfig { n_trees: 4, ..Default::default() });
        let big = TreeLutModel::train(
            &s.train, &TreeLutConfig { n_trees: 16, ..Default::default() });
        assert!(big.hardware_model().total_luts()
                > small.hardware_model().total_luts());
    }
}
