//! Floating-point fully-connected MLP reference (the "FP FC" column of
//! the paper's Table II): same-order capacity, no quantization, no
//! sparsity — the accuracy ceiling the LUT models are compared against.
//! Pure-rust SGD with momentum; small datasets train in seconds.

use crate::dataset::Dataset;
use crate::util::Rng;

/// Fully-connected float MLP: n_in -> hidden... -> n_out.
pub struct Mlp {
    sizes: Vec<usize>,
    /// weights[l]: [out, in] row-major; biases[l]: [out]
    w: Vec<Vec<f32>>,
    b: Vec<Vec<f32>>,
    mw: Vec<Vec<f32>>,
    mb: Vec<Vec<f32>>,
    n_classes: usize,
}

impl Mlp {
    pub fn new(n_in: usize, hidden: &[usize], n_classes: usize, seed: u64) -> Mlp {
        let n_out = if n_classes > 1 { n_classes } else { 1 };
        let mut sizes = vec![n_in];
        sizes.extend_from_slice(hidden);
        sizes.push(n_out);
        let mut rng = Rng::new(seed);
        let mut w: Vec<Vec<f32>> = Vec::new();
        let mut b: Vec<Vec<f32>> = Vec::new();
        for l in 0..sizes.len() - 1 {
            let std = (2.0 / sizes[l] as f32).sqrt();
            w.push((0..sizes[l] * sizes[l + 1]).map(|_| rng.normal() * std).collect());
            b.push(vec![0.0; sizes[l + 1]]);
        }
        let mw: Vec<Vec<f32>> = w.iter().map(|x| vec![0.0; x.len()]).collect();
        let mb: Vec<Vec<f32>> = b.iter().map(|x| vec![0.0; x.len()]).collect();
        Mlp { sizes, w, b, mw, mb, n_classes }
    }

    fn decode_row(&self, row: &[i32], beta: usize) -> Vec<f32> {
        let levels = (1usize << beta) as f32;
        row.iter()
            .map(|&c| (2.0 * c as f32 + 1.0) / levels - 1.0)
            .collect()
    }

    fn forward(&self, x: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts = vec![x.to_vec()];
        for l in 0..self.w.len() {
            let (ni, no) = (self.sizes[l], self.sizes[l + 1]);
            let prev = &acts[l];
            let mut out = vec![0.0f32; no];
            for o in 0..no {
                let mut acc = self.b[l][o];
                let row = &self.w[l][o * ni..(o + 1) * ni];
                for i in 0..ni {
                    acc += row[i] * prev[i];
                }
                out[o] = if l + 1 < self.w.len() { acc.max(0.0) } else { acc };
            }
            acts.push(out);
        }
        let logits = acts.last().unwrap().clone();
        (acts, logits)
    }

    fn step(&mut self, x: &[f32], y: i32, lr: f32) -> f32 {
        let (acts, logits) = self.forward(x);
        let no = *self.sizes.last().unwrap();
        let mut grad = vec![0.0f32; no];
        let loss;
        if self.n_classes > 1 {
            let max = logits.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            loss = -(exps[y as usize] / sum).max(1e-9).ln();
            for o in 0..no {
                grad[o] = exps[o] / sum - if o == y as usize { 1.0 } else { 0.0 };
            }
        } else {
            let z = logits[0];
            let p = 1.0 / (1.0 + (-z).exp());
            loss = if y == 1 { -p.max(1e-7).ln() } else { -(1.0 - p).max(1e-7).ln() };
            grad[0] = p - y as f32;
        }
        for l in (0..self.w.len()).rev() {
            let (ni, no) = (self.sizes[l], self.sizes[l + 1]);
            let prev = &acts[l];
            let mut prev_grad = vec![0.0f32; ni];
            for o in 0..no {
                let mut g = grad[o];
                if l + 1 < self.w.len() && acts[l + 1][o] <= 0.0 {
                    g = 0.0;
                }
                let row = o * ni;
                for i in 0..ni {
                    self.mw[l][row + i] = 0.9 * self.mw[l][row + i] + g * prev[i];
                    prev_grad[i] += g * self.w[l][row + i];
                }
                self.mb[l][o] = 0.9 * self.mb[l][o] + g;
            }
            for o in 0..no {
                let row = o * ni;
                for i in 0..ni {
                    self.w[l][row + i] -= lr * self.mw[l][row + i];
                }
                self.b[l][o] -= lr * self.mb[l][o];
            }
            grad = prev_grad;
        }
        loss
    }

    /// Train on (quantized-code) data, decoding to floats first.  The
    /// step size is scaled by 1/sqrt(n_in) so wide inputs (e.g. 784-dim
    /// MNIST) stay stable under momentum SGD.
    pub fn train(&mut self, data: &Dataset, epochs: usize, lr: f32, seed: u64) {
        let mut rng = Rng::new(seed);
        let scale = (16.0 / self.sizes[0] as f32).sqrt().min(1.0);
        for e in 0..epochs {
            let order = rng.permutation(data.n);
            let decayed = lr * scale * 0.5f32.powi(e as i32 / 3);
            for &i in &order {
                let x = self.decode_row(data.row(i), data.beta_in);
                self.step(&x, data.y[i], decayed);
            }
        }
    }

    pub fn predict(&self, row: &[i32], beta: usize) -> i32 {
        let x = self.decode_row(row, beta);
        let (_, logits) = self.forward(&x);
        if self.n_classes > 1 {
            let mut best = 0usize;
            for i in 1..logits.len() {
                if logits[i] > logits[best] {
                    best = i;
                }
            }
            best as i32
        } else {
            (logits[0] > 0.0) as i32
        }
    }

    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let hits = (0..data.n)
            .filter(|&i| self.predict(data.row(i), data.beta_in) == data.y[i])
            .count();
        hits as f64 / data.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{synthetic_blobs, GenOpts};

    #[test]
    fn mlp_learns_blobs() {
        let opts = GenOpts { n_train: 800, n_test: 200, ..Default::default() };
        let s = synthetic_blobs(10, 3, 3, &opts);
        let mut mlp = Mlp::new(10, &[32, 32], 3, 1);
        mlp.train(&s.train, 6, 0.01, 2);
        let acc = mlp.accuracy(&s.test);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn mlp_binary_head() {
        let opts = GenOpts { n_train: 600, n_test: 200, ..Default::default() };
        let s = synthetic_blobs(8, 2, 2, &opts);
        let mut mlp = Mlp::new(8, &[16], 1, 3);
        mlp.train(&s.train, 5, 0.01, 4);
        assert!(mlp.accuracy(&s.test) > 0.75);
    }
}
