//! Baseline systems the paper compares against (Table IV).
//!
//! Fully implemented here:
//! * [`logicnets`] — LogicNets-style quantized *linear* sparse neurons
//!   with fixed random connectivity, trained in pure rust (no JAX) and
//!   converted to an L-LUT netlist through the same enumeration → mapping
//!   → timing pipeline as our model.
//! * [`treelut`] — TreeLUT-style gradient-boosted decision trees with a
//!   LUT cost model for the comparator + adder-tree hardware.
//!
//! The remaining Table IV rows (DWN, FINN, hls4ml, PolyLUT, PolyLUT-Add,
//! AmigoLUT) are reported from the paper's cited numbers by the table4
//! harness, clearly labelled `paper-reported`.

pub mod logicnets;
pub mod mlp;
pub mod treelut;
