//! Technology mapping: L-LUTs onto physical K=6 FPGA LUTs (P-LUTs).
//!
//! This is the substitute for Vivado synthesis (DESIGN.md §2).  Each
//! output bit of an L-LUT is an `A`-input boolean function (`A =
//! in_bits * fan_in` address bits).  Mapping follows what synthesis tools
//! do with ROM-style `case` blocks on UltraScale+:
//!
//! * `A <= 6`  — one LUT6;
//! * `A == 7`  — two LUT6 + the slice's dedicated F7 mux (free);
//! * `A == 8`  — four LUT6 + F7/F8 muxes (free);
//! * `A > 8`   — Shannon decomposition: two cofactor circuits of `A-1`
//!   inputs plus a fabric 2:1 mux (packed into LUT6s, counted).
//!
//! Before costing, each output bit's *true support* is computed from the
//! trained table — constant bits cost nothing and bits that ignore some
//! inputs map to smaller LUTs.  This is exactly the logic trimming a real
//! synthesis run performs, and it is why trained designs come in under
//! the worst-case `w * out_bits * cost(A)` bound.

use crate::netlist::Netlist;

/// Worst-case P-LUT count for one `a`-input boolean function (K = 6).
pub fn plut_cost(a: usize) -> usize {
    match a {
        0 => 0,           // constant: absorbed
        1 => 0,           // wire / inverter: absorbed into neighbours
        2..=6 => 1,
        7 => 2,           // 2 x LUT6 + F7MUX (dedicated, free)
        8 => 4,           // 4 x LUT6 + F7/F8 (dedicated, free)
        _ => 2 * plut_cost(a - 1) + 1, // Shannon + fabric mux
    }
}

/// Logic depth in P-LUT levels for one `a`-input function (fractions model
/// the dedicated-mux delay, which is much smaller than a LUT level).
pub fn plut_depth(a: usize) -> f64 {
    match a {
        0 | 1 => 0.0,
        2..=6 => 1.0,
        7 => 1.5,
        8 => 2.0,
        _ => plut_depth(a - 1) + 1.0,
    }
}

/// Mapping result for one netlist layer.
#[derive(Clone, Debug)]
pub struct MappedLayer {
    /// P-LUTs after support reduction.
    pub luts: usize,
    /// worst output-bit depth in P-LUT levels
    pub depth: f64,
    /// signal bits produced by this layer (`w * out_bits`) — the cost of
    /// registering its outputs.
    pub out_bits_total: usize,
    /// worst-case P-LUTs without support reduction (reported for ablation)
    pub luts_worst_case: usize,
}

/// Mapping result for a whole netlist.
#[derive(Clone, Debug)]
pub struct MappedNetlist {
    pub layers: Vec<MappedLayer>,
    /// primary input bits (for input-register accounting)
    pub input_bits: usize,
}

impl MappedNetlist {
    pub fn total_luts(&self) -> usize {
        self.layers.iter().map(|l| l.luts).sum()
    }

    pub fn total_luts_worst_case(&self) -> usize {
        self.layers.iter().map(|l| l.luts_worst_case).sum()
    }
}

/// Map a netlist. `optimize` enables support reduction and duplicate-unit
/// sharing (on for all real flows; off gives the worst-case bound used in
/// the ablation bench).
pub fn map_netlist(nl: &Netlist, optimize: bool) -> MappedNetlist {
    let layers = nl
        .layers
        .iter()
        .map(|layer| {
            let a_full = layer.in_bits * layer.fan_in;
            let mut luts = 0usize;
            let mut depth = 0f64;
            let worst = layer.w * layer.out_bits * plut_cost(a_full);
            // duplicate-unit sharing: two units with identical producers
            // and identical tables synthesize to one circuit (trained
            // LUT-NNs converge to shared functions surprisingly often —
            // the post-training table optimizations of ReducedLUT et al.
            // start from the same observation).
            let mut seen: std::collections::HashSet<(Vec<u32>, Vec<u16>)> =
                std::collections::HashSet::new();
            for u in 0..layer.w {
                if optimize {
                    let key = (layer.unit_conn(u).to_vec(),
                               layer.unit_table(u).to_vec());
                    if !seen.insert(key) {
                        continue; // shared with an earlier identical unit
                    }
                }
                let tt = layer.truth_table(u);
                for b in 0..layer.out_bits {
                    let a_eff = if optimize {
                        if tt.bit_constant(b).is_some() {
                            0
                        } else {
                            tt.bit_support(b).len()
                        }
                    } else {
                        a_full
                    };
                    luts += plut_cost(a_eff);
                    depth = depth.max(plut_depth(a_eff));
                }
            }
            MappedLayer {
                luts,
                depth,
                out_bits_total: layer.w * layer.out_bits,
                luts_worst_case: worst,
            }
        })
        .collect();
    MappedNetlist { layers, input_bits: nl.n_in * nl.in_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{LayerSpec, Netlist};

    #[test]
    fn cost_table() {
        assert_eq!(plut_cost(0), 0);
        assert_eq!(plut_cost(1), 0);
        assert_eq!(plut_cost(4), 1);
        assert_eq!(plut_cost(6), 1);
        assert_eq!(plut_cost(7), 2);
        assert_eq!(plut_cost(8), 4);
        assert_eq!(plut_cost(9), 9);   // 2*4+1
        assert_eq!(plut_cost(10), 19); // 2*9+1
    }

    #[test]
    fn depth_table() {
        assert_eq!(plut_depth(6), 1.0);
        assert_eq!(plut_depth(7), 1.5);
        assert_eq!(plut_depth(8), 2.0);
        assert_eq!(plut_depth(9), 3.0);
    }

    fn single_layer(tables: Vec<u16>, fan_in: usize, in_bits: usize,
                    out_bits: usize, w: usize, n_in: usize) -> Netlist {
        let conn: Vec<u32> = (0..w * fan_in).map(|i| (i % n_in) as u32).collect();
        let nl = Netlist {
            name: "t".into(),
            n_in,
            in_bits,
            layers: vec![LayerSpec { w, fan_in, in_bits, out_bits, conn, tables }],
        };
        nl.validate().unwrap();
        nl
    }

    #[test]
    fn constant_output_costs_zero() {
        let nl = single_layer(vec![1u16; 64], 6, 1, 1, 1, 8);
        let m = map_netlist(&nl, true);
        assert_eq!(m.total_luts(), 0);
        assert_eq!(m.total_luts_worst_case(), 1);
    }

    #[test]
    fn full_support_costs_one_lut6() {
        // parity of 6 inputs: depends on everything
        let tables: Vec<u16> =
            (0..64u32).map(|a| (a.count_ones() & 1) as u16).collect();
        let nl = single_layer(tables, 6, 1, 1, 1, 8);
        let m = map_netlist(&nl, true);
        assert_eq!(m.total_luts(), 1);
        assert_eq!(m.layers[0].depth, 1.0);
    }

    #[test]
    fn support_reduction_shrinks_wide_units() {
        // 8-address-bit unit that actually only uses 2 inputs
        let tables: Vec<u16> = (0..256u32)
            .map(|a| (((a & 1) ^ ((a >> 1) & 1)) & 1) as u16)
            .collect();
        let nl = single_layer(tables, 2, 4, 1, 1, 4);
        let opt = map_netlist(&nl, true);
        let raw = map_netlist(&nl, false);
        assert_eq!(opt.total_luts(), 1); // 2-input XOR -> 1 LUT
        assert_eq!(raw.total_luts(), 4); // worst case for A=8
        assert!(opt.layers[0].depth < raw.layers[0].depth);
    }

    #[test]
    fn duplicate_units_are_shared() {
        // two identical parity units + one distinct unit
        let parity: Vec<u16> =
            (0..16u32).map(|a| (a.count_ones() & 1) as u16).collect();
        let distinct: Vec<u16> = (0..16u32).map(|a| (a & 1) as u16).collect();
        let nl = Netlist {
            name: "dup".into(),
            n_in: 4,
            in_bits: 1,
            layers: vec![LayerSpec {
                w: 3,
                fan_in: 4,
                in_bits: 1,
                out_bits: 1,
                conn: vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3],
                tables: [parity.clone(), parity, distinct].concat(),
            }],
        };
        nl.validate().unwrap();
        let opt = map_netlist(&nl, true);
        // parity shared once (1 LUT) + distinct unit is a wire (cost 0)
        assert_eq!(opt.total_luts(), 1);
        assert_eq!(map_netlist(&nl, false).total_luts(), 3);
    }

    #[test]
    fn multibit_outputs_cost_per_bit() {
        // identity table over a 2-bit input: bit0 and bit1 are wires
        let nl = single_layer(vec![0, 1, 2, 3], 1, 2, 2, 1, 1);
        let m = map_netlist(&nl, true);
        assert_eq!(m.total_luts(), 0); // both bits are single-input wires
        // 2-bit function of 4 address bits: bit0 = a0^a2, bit1 = a1^a3
        let tables: Vec<u16> = (0..16u16).map(|a| (a ^ (a >> 2)) & 3).collect();
        let nl2 = single_layer(tables, 2, 2, 2, 1, 2);
        let m2 = map_netlist(&nl2, true);
        assert_eq!(m2.total_luts(), 2);
    }
}
