//! Deterministic RNG utilities (no external `rand` dependency).
//!
//! Xoshiro256** seeded via SplitMix64, plus the distributions the toolflow
//! needs: uniforms, Box–Muller normals, permutations and subset sampling.
//! Everything downstream (datasets, parameter init, random-connectivity
//! ablations) is reproducible from a single `u64` seed.

/// Xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// k distinct indices sampled from 0..n (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let s = r.sample_distinct(20, 8);
            let mut seen = std::collections::HashSet::new();
            assert!(s.iter().all(|&i| i < 20 && seen.insert(i)));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(17);
        let mut p = r.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
