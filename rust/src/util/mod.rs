//! Shared utilities: deterministic RNG, minimal JSON, small helpers.

pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

/// ceil(a / b) for positive integers.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// log2 of the next power of two (>= 1 input).
pub fn log2_ceil(mut n: usize) -> u32 {
    let mut bits = 0;
    let mut cap = 1usize;
    while cap < n {
        cap <<= 1;
        bits += 1;

    }
    bits
}

/// Simple wall-clock stopwatch for benches and perf logging.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 5), 1);
    }

    #[test]
    fn log2_ceil_cases() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(64), 6);
        assert_eq!(log2_ceil(65), 7);
    }
}
