//! Minimal property-based testing harness (the offline vendor set has no
//! `proptest`, so we provide the 10% of it these suites need): seeded
//! case generation, `forall`-style runners, and first-failure reporting
//! with the failing seed so any case can be replayed deterministically.

use super::rng::Rng;

/// Number of cases per property (override with NLA_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("NLA_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` generated inputs; panic with the failing seed.
///
/// `gen` receives a per-case RNG derived from (base_seed, case index); a
/// failure message names the case seed so `replay` can reproduce it.
pub fn forall<T, G, P>(name: &str, base_seed: u64, cases: usize,
                       mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = case_seed(base_seed, case);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Derive the RNG seed of one case (exposed for replay).
pub fn case_seed(base_seed: u64, case: usize) -> u64 {
    base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Replay a single failing case.
pub fn replay<T, G, P>(base_seed: u64, case: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed(base_seed, case));
    let input = gen(&mut rng);
    prop(&input).expect("replayed case must now pass");
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn vec_i32(rng: &mut Rng, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len)
            .map(|_| lo + rng.below((hi - lo + 1) as usize) as i32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("sum-commutes", 1, 32,
               |rng| (rng.below(100) as i64, rng.below(100) as i64),
               |&(a, b)| {
                   if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
               });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn forall_reports_failures_with_seed() {
        forall("always-fails", 2, 8, |rng| rng.below(10), |_| Err("no".into()));
    }

    #[test]
    fn case_seeds_are_distinct() {
        let seeds: std::collections::HashSet<u64> =
            (0..100).map(|c| case_seed(42, c)).collect();
        assert_eq!(seeds.len(), 100);
    }

    #[test]
    fn gen_helpers_bounds() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let v = gen::usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&v));
        }
        let xs = gen::vec_i32(&mut rng, 50, -2, 5);
        assert!(xs.iter().all(|&x| (-2..=5).contains(&x)));
    }
}
