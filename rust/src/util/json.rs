//! Minimal JSON parser/writer (the build environment is offline, so no
//! serde).  Covers the full JSON grammar we emit from `aot.py`
//! (objects, arrays, strings with escapes, numbers, booleans, null) plus
//! typed accessors with descriptive errors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), pos: self.i })
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(format!("expected '{s}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError { msg: "bad number".into(), pos: start })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| JsonError {
                                        msg: "bad \\u escape".into(),
                                        pos: self.i,
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| {
                                JsonError { msg: "bad \\u escape".into(), pos: self.i }
                            })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                            JsonError { msg: "invalid utf8".into(), pos: start }
                        })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return p.err("trailing data");
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access with a path error message.
    pub fn at(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> anyhow::Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.at("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"configs":{"nid":{"w":[60,20,9,3,1],"ok":true}},"x":1.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_have_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("[1] garbage").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn usize_vec_accessor() {
        let j = Json::parse("[1,2,3]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![1, 2, 3]);
    }
}
