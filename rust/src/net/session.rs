//! The stable serving API shape: named inputs/outputs, errors as
//! values (the `Session` trait idiom from deli-infer, specialized to
//! code-valued LUT netlists).
//!
//! [`Session`] is what a *consumer* of a served model programs
//! against; it deliberately hides whether the model runs in-process
//! ([`EngineSession`] over any `InferenceEngine`) or across the wire
//! (`net::client::NetSession` over a TCP connection).  Every failure
//! is a typed [`InferError`] value — a session call never panics on
//! bad input and never surfaces a transport problem as anything but
//! an error variant.
//!
//! A LUT netlist has one logical input tensor and one logical output
//! tensor, so the named-IO surface is small: inputs `["x"]` (row-major
//! `batch * n_in` codes), outputs `["y"]` (row-major `batch *
//! out_width` codes).  The names are part of the stable API so richer
//! models (e.g. a cascade exposing per-tier outputs) can extend the
//! map without breaking callers.

use std::collections::HashMap;
use std::fmt;

use crate::coordinator::InferenceEngine;

use super::wire;

/// The conventional input tensor name.
pub const INPUT_X: &str = "x";
/// The conventional output tensor name.
pub const OUTPUT_Y: &str = "y";

/// Typed inference failure — the error-as-value side of the session
/// API, with a lossless mapping onto wire error codes (so a TCP
/// session surfaces exactly what the server answered).
#[derive(Debug)]
pub enum InferError {
    /// Malformed frame or request body (wire code 1).
    BadFrame(String),
    /// The server hosts no model by this name (wire code 2).
    UnknownModel(String),
    /// Input shape/width rejected (wire code 3).
    BadInput(String),
    /// Admission control shed the request: the bounded queue is full
    /// (wire code 4).  Retry later — the server is alive.
    Overloaded,
    /// The server is draining and accepts no new work (wire code 5).
    ShuttingDown,
    /// Server-side failure while evaluating (wire code 6).
    Internal(String),
    /// The request's deadline budget cannot be met — already expired
    /// at admission, or the remaining budget is below the model's
    /// observed p50 service time (wire code 7).  Retrying with the
    /// same budget is futile.
    DeadlineExceeded(String),
    /// This connection is over its per-connection inflight quota
    /// (wire code 8).  The server has room; *this* connection must
    /// drain some of its own inflight work first.
    ConnQuota,
    /// The peer violated the protocol (unexpected kind, bad frame).
    Protocol(String),
    /// Transport failure (connect, read, write).
    Io(std::io::Error),
}

impl InferError {
    /// The wire error code this variant maps to (None for client-side
    /// transport/protocol failures, which have no frame).
    pub fn code(&self) -> Option<u16> {
        match self {
            InferError::BadFrame(_) => Some(wire::ERR_BAD_FRAME),
            InferError::UnknownModel(_) => Some(wire::ERR_UNKNOWN_MODEL),
            InferError::BadInput(_) => Some(wire::ERR_BAD_INPUT),
            InferError::Overloaded => Some(wire::ERR_OVERLOADED),
            InferError::ShuttingDown => Some(wire::ERR_SHUTTING_DOWN),
            InferError::Internal(_) => Some(wire::ERR_INTERNAL),
            InferError::DeadlineExceeded(_) => Some(wire::ERR_DEADLINE),
            InferError::ConnQuota => Some(wire::ERR_CONN_QUOTA),
            InferError::Protocol(_) | InferError::Io(_) => None,
        }
    }

    /// Whether an idempotent request that failed this way is worth
    /// retrying (see `net::client::RetryClient` for the policy that
    /// consumes this).  The taxonomy:
    ///
    /// * retry **capacity** answers ([`InferError::Overloaded`],
    ///   [`InferError::ConnQuota`]) — the request was provably *not*
    ///   admitted, so a retry cannot double-execute it and the
    ///   condition is transient by construction;
    /// * retry **transport/protocol** failures ([`InferError::Io`],
    ///   [`InferError::Protocol`], [`InferError::BadFrame`]) — the
    ///   request may or may not have executed, but inference is
    ///   idempotent and a fresh attempt on a fresh connection is safe;
    /// * retry [`InferError::ShuttingDown`] — a restarting server
    ///   comes back; this is what lets `RemoteEngine` survive a
    ///   restart mid-run;
    /// * never retry **semantic rejections** ([`InferError::BadInput`],
    ///   [`InferError::UnknownModel`], [`InferError::Internal`],
    ///   [`InferError::DeadlineExceeded`]) — the same request gets the
    ///   same answer; retrying only adds load where it cannot help.
    pub fn is_retryable(&self) -> bool {
        matches!(self,
                 InferError::Overloaded | InferError::ConnQuota
                 | InferError::ShuttingDown | InferError::BadFrame(_)
                 | InferError::Protocol(_) | InferError::Io(_))
    }

    /// Reconstruct the typed error a [`wire::Message::Error`] frame
    /// carries.  Unknown codes (a newer server) degrade to
    /// [`InferError::Protocol`] instead of being misread.
    pub fn from_wire(code: u16, message: &str) -> InferError {
        match code {
            wire::ERR_BAD_FRAME => InferError::BadFrame(message.into()),
            wire::ERR_UNKNOWN_MODEL => {
                InferError::UnknownModel(message.into())
            }
            wire::ERR_BAD_INPUT => InferError::BadInput(message.into()),
            wire::ERR_OVERLOADED => InferError::Overloaded,
            wire::ERR_SHUTTING_DOWN => InferError::ShuttingDown,
            wire::ERR_INTERNAL => InferError::Internal(message.into()),
            wire::ERR_DEADLINE => {
                InferError::DeadlineExceeded(message.into())
            }
            wire::ERR_CONN_QUOTA => InferError::ConnQuota,
            other => InferError::Protocol(format!(
                "unknown error code {other}: {message}")),
        }
    }
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferError::BadFrame(m) => write!(f, "bad frame: {m}"),
            InferError::UnknownModel(m) => {
                write!(f, "unknown model: {m}")
            }
            InferError::BadInput(m) => write!(f, "bad input: {m}"),
            InferError::Overloaded => {
                write!(f, "overloaded: request shed by admission control")
            }
            InferError::ShuttingDown => {
                write!(f, "server is shutting down")
            }
            InferError::Internal(m) => write!(f, "server error: {m}"),
            InferError::DeadlineExceeded(m) => {
                write!(f, "deadline exceeded: {m}")
            }
            InferError::ConnQuota => {
                write!(f, "per-connection inflight quota exceeded")
            }
            InferError::Protocol(m) => write!(f, "protocol error: {m}"),
            InferError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for InferError {}

impl From<std::io::Error> for InferError {
    fn from(e: std::io::Error) -> InferError {
        InferError::Io(e)
    }
}

impl From<wire::WireError> for InferError {
    fn from(e: wire::WireError) -> InferError {
        match e {
            wire::WireError::Io(io) => InferError::Io(io),
            other => InferError::Protocol(other.to_string()),
        }
    }
}

/// A served model behind named inputs/outputs and `Result`-typed
/// errors — the stable consumer-facing API shape, independent of
/// transport.
pub trait Session {
    /// Evaluate named input tensors to named output tensors.  For LUT
    /// netlists: one input [`INPUT_X`] of row-major codes whose length
    /// is a multiple of the model's `n_in`; one output [`OUTPUT_Y`] of
    /// `batch * out_width` codes.
    fn run(&mut self, inputs: &[(&str, &[i32])])
           -> Result<HashMap<String, Vec<i32>>, InferError>;

    /// Names `run` accepts, in declaration order.
    fn input_names(&self) -> &[String];

    /// Names `run` produces, in declaration order.
    fn output_names(&self) -> &[String];
}

/// Extract the single `x` input and derive the batch size — the shared
/// front door of every LUT session implementation.
pub(crate) fn single_input_batch<'a>(inputs: &[(&str, &'a [i32])],
                                     n_in: usize)
                                     -> Result<(&'a [i32], usize),
                                               InferError> {
    if inputs.len() != 1 || inputs[0].0 != INPUT_X {
        return Err(InferError::BadInput(format!(
            "expected exactly one input named '{INPUT_X}', got {:?}",
            inputs.iter().map(|(n, _)| *n).collect::<Vec<_>>())));
    }
    let x = inputs[0].1;
    if n_in == 0 {
        return Err(InferError::BadInput("model has no inputs".into()));
    }
    if x.is_empty() || x.len() % n_in != 0 {
        return Err(InferError::BadInput(format!(
            "input '{INPUT_X}' length {} is not a positive multiple of \
             n_in {n_in}", x.len())));
    }
    Ok((x, x.len() / n_in))
}

/// In-process [`Session`] over any [`InferenceEngine`] — the same API
/// shape as a TCP session, with the transport removed.  Conformance
/// tests pair the two to prove the wire adds nothing but frames.
pub struct EngineSession<E> {
    engine: E,
    inputs: Vec<String>,
    outputs: Vec<String>,
}

impl<E: InferenceEngine> EngineSession<E> {
    pub fn new(engine: E) -> EngineSession<E> {
        EngineSession {
            engine,
            inputs: vec![INPUT_X.to_string()],
            outputs: vec![OUTPUT_Y.to_string()],
        }
    }

    /// The wrapped engine (e.g. to inspect widths).
    pub fn engine(&self) -> &E {
        &self.engine
    }
}

impl<E: InferenceEngine> Session for EngineSession<E> {
    fn run(&mut self, inputs: &[(&str, &[i32])])
           -> Result<HashMap<String, Vec<i32>>, InferError> {
        let (x, batch) = single_input_batch(inputs, self.engine.n_in())?;
        let y = self
            .engine
            .run_batch(x, batch)
            .map_err(|e| InferError::Internal(format!("{e:#}")))?;
        let mut out = HashMap::new();
        out.insert(OUTPUT_Y.to_string(), y);
        Ok(out)
    }

    fn input_names(&self) -> &[String] {
        &self.inputs
    }

    fn output_names(&self) -> &[String] {
        &self.outputs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::testutil::{random_inputs, random_netlist};

    #[test]
    fn engine_session_matches_eval_one() {
        let nl = random_netlist(81, 6, 1, &[(4, 2, 2), (2, 2, 1)]);
        let mut s = EngineSession::new(nl.simulator());
        assert_eq!(s.input_names(), [INPUT_X.to_string()]);
        assert_eq!(s.output_names(), [OUTPUT_Y.to_string()]);
        let x = random_inputs(81, &nl, 5);
        let out = s.run(&[(INPUT_X, &x[..])]).unwrap();
        let y = &out[OUTPUT_Y];
        let ow = nl.out_width();
        for b in 0..5 {
            let want = nl.eval_one(&x[b * 6..(b + 1) * 6]).unwrap();
            assert_eq!(&y[b * ow..(b + 1) * ow], &want[..], "row {b}");
        }
    }

    #[test]
    fn engine_session_rejects_bad_inputs_as_values() {
        let nl = random_netlist(82, 6, 1, &[(4, 2, 2)]);
        let mut s = EngineSession::new(nl.simulator());
        let x = random_inputs(82, &nl, 1);
        // wrong name
        assert!(matches!(s.run(&[("z", &x[..])]),
                         Err(InferError::BadInput(_))));
        // two inputs
        assert!(matches!(s.run(&[(INPUT_X, &x[..]), (INPUT_X, &x[..])]),
                         Err(InferError::BadInput(_))));
        // not a multiple of n_in
        assert!(matches!(s.run(&[(INPUT_X, &x[..5])]),
                         Err(InferError::BadInput(_))));
        // empty
        assert!(matches!(s.run(&[(INPUT_X, &[][..])]),
                         Err(InferError::BadInput(_))));
    }

    #[test]
    fn wire_code_mapping_is_lossless() {
        for code in [wire::ERR_BAD_FRAME, wire::ERR_UNKNOWN_MODEL,
                     wire::ERR_BAD_INPUT, wire::ERR_OVERLOADED,
                     wire::ERR_SHUTTING_DOWN, wire::ERR_INTERNAL,
                     wire::ERR_DEADLINE, wire::ERR_CONN_QUOTA] {
            let e = InferError::from_wire(code, "m");
            assert_eq!(e.code(), Some(code));
        }
        // unknown codes degrade to Protocol, not a panic or a misread
        assert!(InferError::from_wire(999, "m").code().is_none());
    }

    #[test]
    fn retry_taxonomy_never_retries_semantic_rejections() {
        // capacity + transport + restart: retryable
        assert!(InferError::Overloaded.is_retryable());
        assert!(InferError::ConnQuota.is_retryable());
        assert!(InferError::ShuttingDown.is_retryable());
        assert!(InferError::BadFrame("x".into()).is_retryable());
        assert!(InferError::Protocol("x".into()).is_retryable());
        assert!(InferError::Io(std::io::Error::other("x"))
                    .is_retryable());
        // semantic: the same request gets the same answer
        assert!(!InferError::BadInput("x".into()).is_retryable());
        assert!(!InferError::UnknownModel("x".into()).is_retryable());
        assert!(!InferError::Internal("x".into()).is_retryable());
        assert!(!InferError::DeadlineExceeded("x".into()).is_retryable());
    }
}
