//! Deterministic fault injection for the serving path.
//!
//! A [`FaultPlan`] is a seeded (or fully scripted) schedule of
//! transport faults — delays, dropped connections, truncated frames,
//! corrupted bytes, partial writes — that the chaos test battery
//! threads into both the server's connection handlers and the client
//! via [`FaultyIo`], an I/O wrapper that consults the plan on every
//! `read`/`write`.  [`NetIo`] is the zero-cost-when-disabled switch
//! the production code actually holds: `Plain` is a bare
//! `TcpStream`, `Faulty` the wrapped one.
//!
//! Design constraints:
//!
//! * **Deterministic** — a plan draws every decision from one
//!   [`Rng`] behind a mutex with a global operation counter, so a
//!   given seed produces the same fault schedule for the same
//!   sequence of I/O operations.  (Across threads the *interleaving*
//!   of operations is scheduling-dependent; tests that need exact
//!   fault placement use [`FaultPlan::scripted`] on a single
//!   stream.)
//! * **Honest at the syscall boundary** — faults are expressed as
//!   real `io::Result` outcomes (`ConnectionReset`, short reads,
//!   partial writes) or real byte-level damage, never as magic
//!   side channels, so the code under test exercises exactly the
//!   paths a flaky network would.
//! * **One-way degradation** — once a plan kills a stream (drop /
//!   truncate), every later operation on that stream fails too;
//!   a connection never heals mid-life, matching TCP.
//!
//! The module is compiled unconditionally (integration tests and the
//! `serve_load` example need it from outside the crate) but nothing
//! in the serving path constructs a plan unless one is explicitly
//! configured — `NetIo::Plain` adds one enum-tag branch per I/O call.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::Rng;

/// One injected fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Stall the operation for this many milliseconds, then perform
    /// it normally (network jitter, a GC'd peer, a slow middlebox).
    Delay(u64),
    /// Fail with `ConnectionReset` and kill the stream.
    DropConnection,
    /// Perform roughly half of the operation, then kill the stream —
    /// the peer sees a frame cut mid-body.
    TruncateFrame,
    /// Flip one byte of the payload (the checksum must catch it).
    CorruptByte,
    /// Complete only part of the operation but report honest short
    /// counts — exercises `write_all`/`read_exact` resumption.
    Partial,
}

/// Injection counters — what a plan actually did, for test assertions
/// ("the chaos run was not a no-op").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub delays: u64,
    pub drops: u64,
    pub truncates: u64,
    pub corrupts: u64,
    pub partials: u64,
}

impl FaultCounts {
    pub fn total(&self) -> u64 {
        self.delays + self.drops + self.truncates + self.corrupts
            + self.partials
    }
}

/// Whether the intercepted operation is a read or a write — scripted
/// plans and directional modes can discriminate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

enum Mode {
    /// Each operation faults independently with probability `rate`;
    /// the fault kind is drawn uniformly.  `delay_ms` bounds the
    /// injected stalls so a seeded chaos run stays fast.
    Seeded { rate: f64, delay_ms: u64 },
    /// Exact placement: operation index -> fault, one-shot each.
    Scripted {
        events: std::collections::HashMap<u64, (Dir, Fault)>,
    },
    /// Every write stalls for `ms`; reads untouched.  The wedged-
    /// responder scenario the drain-deadline regression test needs.
    DelayWrites { ms: u64 },
}

struct Inner {
    mode: Mode,
    rng: Rng,
    op: u64,
    counts: FaultCounts,
}

/// A deterministic schedule of transport faults, shared by every
/// stream it is threaded into (`Arc<FaultPlan>`).
pub struct FaultPlan {
    inner: Mutex<Inner>,
}

impl FaultPlan {
    /// Seeded plan: every I/O operation faults independently with
    /// probability `rate` (kind drawn uniformly, delays capped at
    /// 5 ms).
    pub fn seeded(seed: u64, rate: f64) -> Arc<FaultPlan> {
        FaultPlan::seeded_with_delay(seed, rate, 5)
    }

    /// Seeded plan with an explicit delay bound in milliseconds.
    pub fn seeded_with_delay(seed: u64, rate: f64, delay_ms: u64)
                             -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            inner: Mutex::new(Inner {
                mode: Mode::Seeded { rate, delay_ms },
                rng: Rng::new(seed),
                op: 0,
                counts: FaultCounts::default(),
            }),
        })
    }

    /// Fully scripted plan: fault exactly the listed operations
    /// (global 0-based operation index across every stream sharing
    /// the plan), leave the rest untouched.
    pub fn scripted(events: &[(u64, Dir, Fault)]) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            inner: Mutex::new(Inner {
                mode: Mode::Scripted {
                    events: events
                        .iter()
                        .map(|&(op, dir, f)| (op, (dir, f)))
                        .collect(),
                },
                rng: Rng::new(0),
                op: 0,
                counts: FaultCounts::default(),
            }),
        })
    }

    /// Stall every write by `ms` milliseconds (reads untouched) — a
    /// responder that wedges without dying.
    pub fn delay_writes(ms: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            inner: Mutex::new(Inner {
                mode: Mode::DelayWrites { ms },
                rng: Rng::new(0),
                op: 0,
                counts: FaultCounts::default(),
            }),
        })
    }

    /// What the plan has injected so far.
    pub fn counts(&self) -> FaultCounts {
        self.inner.lock().unwrap().counts
    }

    /// Decide the fate of the next I/O operation.
    fn next(&self, dir: Dir) -> Option<Fault> {
        let mut guard = self.inner.lock().unwrap();
        let inner: &mut Inner = &mut guard;
        let op = inner.op;
        inner.op += 1;
        let fault = match &mut inner.mode {
            Mode::Seeded { rate, delay_ms } => {
                if inner.rng.uniform() < *rate {
                    let cap = (*delay_ms).max(1);
                    Some(match inner.rng.below(5) {
                        0 => Fault::Delay(1 + inner.rng.next_u64() % cap),
                        1 => Fault::DropConnection,
                        2 => Fault::TruncateFrame,
                        3 => Fault::CorruptByte,
                        _ => Fault::Partial,
                    })
                } else {
                    None
                }
            }
            Mode::Scripted { events } => match events.remove(&op) {
                Some((d, f)) if d == dir => Some(f),
                Some(_) | None => None,
            },
            Mode::DelayWrites { ms } => {
                if dir == Dir::Write {
                    Some(Fault::Delay(*ms))
                } else {
                    None
                }
            }
        };
        match fault {
            Some(Fault::Delay(_)) => inner.counts.delays += 1,
            Some(Fault::DropConnection) => inner.counts.drops += 1,
            Some(Fault::TruncateFrame) => inner.counts.truncates += 1,
            Some(Fault::CorruptByte) => inner.counts.corrupts += 1,
            Some(Fault::Partial) => inner.counts.partials += 1,
            None => {}
        }
        fault
    }

    /// A deterministic position in `0..len` for byte corruption.
    fn pos(&self, len: usize) -> usize {
        debug_assert!(len > 0);
        self.inner.lock().unwrap().rng.below(len)
    }
}

fn reset() -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionReset,
                   "injected connection reset")
}

/// An I/O wrapper that consults a [`FaultPlan`] on every operation.
/// Generic over the stream so unit tests can drive it with in-memory
/// pipes; the serving path always wraps a `TcpStream` (via
/// [`NetIo`]).
pub struct FaultyIo<S> {
    inner: S,
    plan: Arc<FaultPlan>,
    dead: bool,
}

impl<S> FaultyIo<S> {
    pub fn new(inner: S, plan: Arc<FaultPlan>) -> FaultyIo<S> {
        FaultyIo { inner, plan, dead: false }
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }
}

impl<S: Read> Read for FaultyIo<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.dead {
            return Err(reset());
        }
        if buf.is_empty() {
            return self.inner.read(buf);
        }
        match self.plan.next(Dir::Read) {
            None => self.inner.read(buf),
            Some(Fault::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.read(buf)
            }
            Some(Fault::DropConnection) => {
                self.dead = true;
                Err(reset())
            }
            Some(Fault::TruncateFrame) => {
                // deliver at most half of what arrives, swallow the
                // rest by dying: the caller's next read (read_exact
                // resumes) hits the dead stream
                let n = self.inner.read(buf)?;
                self.dead = true;
                if n == 0 {
                    return Ok(0);
                }
                Ok((n / 2).max(1))
            }
            Some(Fault::CorruptByte) => {
                let n = self.inner.read(buf)?;
                if n > 0 {
                    let p = self.plan.pos(n);
                    buf[p] ^= 0x40;
                }
                Ok(n)
            }
            Some(Fault::Partial) => {
                let m = (buf.len() / 2).max(1);
                self.inner.read(&mut buf[..m])
            }
        }
    }
}

impl<S: Write> Write for FaultyIo<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(reset());
        }
        if buf.is_empty() {
            return self.inner.write(buf);
        }
        match self.plan.next(Dir::Write) {
            None => self.inner.write(buf),
            Some(Fault::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.write(buf)
            }
            Some(Fault::DropConnection) => {
                self.dead = true;
                Err(reset())
            }
            Some(Fault::TruncateFrame) => {
                let k = (buf.len() / 2).max(1);
                let n = self.inner.write(&buf[..k])?;
                self.dead = true;
                Ok(n)
            }
            Some(Fault::CorruptByte) => {
                let mut damaged = buf.to_vec();
                let p = self.plan.pos(damaged.len());
                damaged[p] ^= 0x40;
                self.inner.write_all(&damaged)?;
                Ok(buf.len())
            }
            Some(Fault::Partial) => {
                let k = (buf.len() / 2).max(1);
                self.inner.write(&buf[..k])
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// The stream type the serving path actually holds: a bare
/// `TcpStream` in production, a fault-wrapped one under the chaos
/// battery.
pub enum NetIo {
    Plain(TcpStream),
    Faulty(FaultyIo<TcpStream>),
}

impl NetIo {
    /// Wrap `stream` in the plan if one is configured.
    pub fn wrap(stream: TcpStream, plan: Option<&Arc<FaultPlan>>)
                -> NetIo {
        match plan {
            None => NetIo::Plain(stream),
            Some(p) => NetIo::Faulty(FaultyIo::new(stream, p.clone())),
        }
    }

    /// The underlying socket (timeouts, peer addr, shutdown).
    pub fn stream(&self) -> &TcpStream {
        match self {
            NetIo::Plain(s) => s,
            NetIo::Faulty(f) => f.get_ref(),
        }
    }

    /// Best-effort full shutdown of the underlying socket.
    pub fn shutdown(&self) {
        let _ = self.stream().shutdown(Shutdown::Both);
    }
}

impl Read for NetIo {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            NetIo::Plain(s) => s.read(buf),
            NetIo::Faulty(f) => f.read(buf),
        }
    }
}

impl Write for NetIo {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            NetIo::Plain(s) => s.write(buf),
            NetIo::Faulty(f) => f.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            NetIo::Plain(s) => s.flush(),
            NetIo::Faulty(f) => f.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory byte source/sink standing in for a socket.
    struct Pipe {
        incoming: Vec<u8>,
        pos: usize,
        outgoing: Vec<u8>,
    }

    impl Pipe {
        fn with_incoming(bytes: &[u8]) -> Pipe {
            Pipe { incoming: bytes.to_vec(), pos: 0, outgoing: vec![] }
        }
    }

    impl Read for Pipe {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let n = buf.len().min(self.incoming.len() - self.pos);
            buf[..n].copy_from_slice(&self.incoming[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    impl Write for Pipe {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.outgoing.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn scripted_faults_land_on_exact_operations() {
        let plan = FaultPlan::scripted(&[
            (1, Dir::Read, Fault::CorruptByte),
            (2, Dir::Read, Fault::DropConnection),
        ]);
        let data = [10u8, 20, 30];
        let mut io = FaultyIo::new(Pipe::with_incoming(&data), plan.clone());
        // op 0: clean
        let mut b = [0u8; 1];
        assert_eq!(io.read(&mut b).unwrap(), 1);
        assert_eq!(b[0], 10);
        // op 1: corrupted (exactly one bit pattern xored in)
        assert_eq!(io.read(&mut b).unwrap(), 1);
        assert_eq!(b[0], 20 ^ 0x40);
        // op 2: reset, and the stream stays dead
        assert_eq!(io.read(&mut b).unwrap_err().kind(),
                   io::ErrorKind::ConnectionReset);
        assert_eq!(io.read(&mut b).unwrap_err().kind(),
                   io::ErrorKind::ConnectionReset);
        let c = plan.counts();
        assert_eq!((c.corrupts, c.drops, c.total()), (1, 1, 2));
    }

    #[test]
    fn scripted_dir_mismatch_is_a_no_op() {
        // a write fault scheduled on a read op index does not fire
        let plan = FaultPlan::scripted(&[(0, Dir::Write,
                                          Fault::DropConnection)]);
        let mut io = FaultyIo::new(Pipe::with_incoming(&[1]), plan.clone());
        let mut b = [0u8; 1];
        assert_eq!(io.read(&mut b).unwrap(), 1);
        assert_eq!(b[0], 1);
        assert_eq!(plan.counts().total(), 0);
    }

    #[test]
    fn partial_write_reports_honest_short_count() {
        let plan = FaultPlan::scripted(&[(0, Dir::Write, Fault::Partial)]);
        let mut io = FaultyIo::new(Pipe::with_incoming(&[]), plan);
        let n = io.write(&[1, 2, 3, 4]).unwrap();
        assert_eq!(n, 2);
        assert_eq!(io.get_ref().outgoing, vec![1, 2]);
        // write_all-style resumption completes on the clean stream
        let n = io.write(&[3, 4]).unwrap();
        assert_eq!(n, 2);
        assert_eq!(io.get_ref().outgoing, vec![1, 2, 3, 4]);
    }

    #[test]
    fn truncate_write_kills_the_stream_after_half() {
        let plan = FaultPlan::scripted(&[(0, Dir::Write,
                                          Fault::TruncateFrame)]);
        let mut io = FaultyIo::new(Pipe::with_incoming(&[]), plan);
        let n = io.write(&[1, 2, 3, 4]).unwrap();
        assert_eq!(n, 2);
        assert_eq!(io.write(&[3, 4]).unwrap_err().kind(),
                   io::ErrorKind::ConnectionReset);
    }

    #[test]
    fn seeded_plan_is_deterministic_and_respects_rate() {
        let run = |seed| {
            let plan = FaultPlan::seeded(seed, 0.25);
            let mut faults = Vec::new();
            for _ in 0..400 {
                faults.push(plan.next(Dir::Read));
            }
            (faults, plan.counts())
        };
        let (a, ca) = run(42);
        let (b, cb) = run(42);
        assert_eq!(a, b, "same seed, same schedule");
        assert_eq!(ca, cb);
        let (c, cc) = run(43);
        assert_ne!(a, c, "different seed, different schedule");
        // rate 0.25 over 400 draws: expect roughly 100, generously
        // bounded so the test never flakes on seed choice
        assert!(ca.total() > 40 && ca.total() < 200,
                "rate off: {} faults", ca.total());
        assert!(cc.total() > 40 && cc.total() < 200);
    }

    #[test]
    fn rate_zero_injects_nothing_rate_one_faults_everything() {
        let quiet = FaultPlan::seeded(7, 0.0);
        let loud = FaultPlan::seeded(7, 1.0);
        for _ in 0..100 {
            assert_eq!(quiet.next(Dir::Read), None);
            assert!(loud.next(Dir::Write).is_some());
        }
        assert_eq!(quiet.counts().total(), 0);
        assert_eq!(loud.counts().total(), 100);
    }

    #[test]
    fn delay_writes_mode_stalls_writes_only() {
        let plan = FaultPlan::delay_writes(1);
        assert_eq!(plan.next(Dir::Read), None);
        assert_eq!(plan.next(Dir::Write), Some(Fault::Delay(1)));
        assert_eq!(plan.next(Dir::Read), None);
        assert_eq!(plan.counts().delays, 1);
    }
}
