//! Client side of the NLWP protocol: a blocking connection handle
//! ([`Client`]), a resilient retrying wrapper ([`RetryClient`]), the
//! consumer-facing [`Session`] over it ([`NetSession`]), and an
//! [`InferenceEngine`] adapter ([`RemoteEngine`]) so the conformance
//! suite can hold a served model to the exact same contract as an
//! in-process executor — through restarts and injected faults.
//!
//! [`Client`] exposes both a synchronous request/response surface
//! (`infer`, `stats`, `ping`) and a split send/receive surface
//! (`send_infer` + `recv_frame`) for pipelining: a load generator may
//! keep many requests in flight on one connection, which is exactly
//! what drives the server's batcher to form large batches.
//!
//! [`RetryClient`] wraps the synchronous surface in a bounded retry
//! loop: capacity sheds and transport failures are retried with
//! decorrelated-jitter exponential backoff (fresh request ids each
//! attempt, reconnecting when the connection is suspect), semantic
//! rejections are returned immediately — the taxonomy lives on
//! [`InferError::is_retryable`].  All timing math is integer µs so
//! the Python mirror can pin the schedule bit-exactly.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::InferenceEngine;
use crate::util::{Json, Rng};

use super::fault::{FaultPlan, NetIo};
use super::session::{single_input_batch, InferError, Session, INPUT_X,
                     OUTPUT_Y};
use super::wire::{self, Frame, Message};

/// Bounded exponential backoff with decorrelated jitter (each sleep
/// is drawn from a window that grows with the previous sleep, so
/// synchronized retry storms decorrelate).  All integer µs.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts per request (1 = no retries).
    pub max_attempts: u32,
    /// First-retry backoff floor.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
    /// Seed for the jitter stream (deterministic tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
            seed: 0x5EED_BACC,
        }
    }
}

impl RetryPolicy {
    /// No retries at all (the raw-client behavior).
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, ..RetryPolicy::default() }
    }
}

/// One decorrelated-jitter step: uniform in
/// `[base, max(base + 1, 3 * prev))`, clamped to `cap` — the AWS
/// "decorrelated jitter" schedule in pure u64 µs arithmetic (no
/// floats, so the Python mirror reproduces it bit-exactly).
pub(crate) fn next_backoff_us(rng: &mut Rng, base_us: u64, cap_us: u64,
                              prev_us: u64) -> u64 {
    let span = prev_us.saturating_mul(3).saturating_sub(base_us).max(1);
    (base_us + rng.next_u64() % span).min(cap_us)
}

/// The first `n` backoff sleeps (µs) the policy would draw — pure, for
/// tests and capacity planning; pinned cross-language against the
/// Python mirror.
pub fn backoff_schedule(policy: &RetryPolicy, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(policy.seed);
    let base = policy.base.as_micros().max(1) as u64;
    let cap = (policy.cap.as_micros() as u64).max(base);
    let mut prev = base;
    (0..n)
        .map(|_| {
            prev = next_backoff_us(&mut rng, base, cap, prev);
            prev
        })
        .collect()
}

/// Connection-level knobs for [`Client`] / [`RetryClient`].
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Bound on each TCP connect attempt — a half-dead host answers
    /// with an error instead of hanging the caller indefinitely.
    pub connect_timeout: Duration,
    /// Read timeout on the connection (`None`: block forever).  The
    /// default is generous but finite, so a wedged server surfaces as
    /// a typed timeout a retry loop can act on.
    pub read_timeout: Option<Duration>,
    /// Retry behavior for [`RetryClient`] (ignored by raw [`Client`]
    /// calls).
    pub retry: RetryPolicy,
    /// Fault-injection plan wrapped around the connection's I/O
    /// (chaos tests only; `None` in production).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Some(Duration::from_secs(30)),
            retry: RetryPolicy::default(),
            fault: None,
        }
    }
}

/// One blocking NLWP connection.
pub struct Client {
    sock: TcpStream,
    writer: NetIo,
    reader: BufReader<NetIo>,
    next_id: u64,
}

impl Client {
    /// Connect to a [`NetServer`](super::server::NetServer) with
    /// default timeouts.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client, InferError> {
        Client::connect_with(addr, &ClientConfig::default())
    }

    /// Connect with explicit timeouts and (optionally) a fault plan.
    /// Every resolved address is tried, each bounded by
    /// `cfg.connect_timeout`.
    pub fn connect_with(addr: impl ToSocketAddrs, cfg: &ClientConfig)
                        -> Result<Client, InferError> {
        let addrs: Vec<SocketAddr> = addr.to_socket_addrs()?.collect();
        let mut last: Option<InferError> = None;
        for a in &addrs {
            match TcpStream::connect_timeout(a, cfg.connect_timeout) {
                Ok(s) => return Client::from_stream(s, cfg),
                Err(e) => last = Some(InferError::Io(e)),
            }
        }
        Err(last.unwrap_or_else(|| {
            InferError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "address resolved to nothing"))
        }))
    }

    fn from_stream(stream: TcpStream, cfg: &ClientConfig)
                   -> Result<Client, InferError> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(cfg.read_timeout)?;
        let rstream = stream.try_clone()?;
        let sock = stream.try_clone()?;
        let writer = NetIo::wrap(stream, cfg.fault.as_ref());
        let reader =
            BufReader::new(NetIo::wrap(rstream, cfg.fault.as_ref()));
        Ok(Client { sock, writer, reader, next_id: 1 })
    }

    /// Optional read timeout — lets tests and load generators fail
    /// fast instead of hanging on a wedged peer.
    pub fn set_read_timeout(&self, t: Option<Duration>)
                            -> Result<(), InferError> {
        self.sock.set_read_timeout(t)?;
        Ok(())
    }

    /// Send any request frame; returns the id the response will echo.
    pub fn send(&mut self, msg: &Message) -> Result<u64, InferError> {
        let id = self.next_id;
        self.next_id += 1;
        self.writer.write_all(&wire::encode_frame(id, msg))?;
        Ok(id)
    }

    /// Send one inference request without waiting (pipelining).
    pub fn send_infer(&mut self, model: &str, batch: u32, n_in: u32,
                      codes: Vec<i32>) -> Result<u64, InferError> {
        self.send_infer_deadline(model, batch, n_in, codes, None)
    }

    /// Send one inference request carrying an optional µs deadline
    /// budget (measured by the server from frame arrival).
    pub fn send_infer_deadline(&mut self, model: &str, batch: u32,
                               n_in: u32, codes: Vec<i32>,
                               deadline_us: Option<u64>)
                               -> Result<u64, InferError> {
        self.send(&Message::Infer {
            model: model.to_string(), batch, n_in, deadline_us, codes,
        })
    }

    /// Read the next frame off the wire.
    pub fn recv_frame(&mut self) -> Result<Frame, InferError> {
        Ok(wire::read_frame(&mut self.reader)?)
    }

    /// Read the response to request `id`.  Error frames for the
    /// request (including id-0 errors the server sends when a frame
    /// was too corrupt to carry a trustworthy id) become typed
    /// [`InferError`] values; anything else is a protocol violation.
    pub fn recv_response(&mut self, id: u64)
                         -> Result<Message, InferError> {
        let frame = self.recv_frame()?;
        match frame.msg {
            Message::Error { code, message }
                if frame.id == id || frame.id == 0 =>
            {
                Err(InferError::from_wire(code, &message))
            }
            msg if frame.id == id => Ok(msg),
            msg => Err(InferError::Protocol(format!(
                "response id {} does not match request id {id} \
                 (kind {})", frame.id, msg.kind()))),
        }
    }

    /// Round-trip a ping.
    pub fn ping(&mut self) -> Result<(), InferError> {
        let id = self.send(&Message::Ping)?;
        match self.recv_response(id)? {
            Message::Pong => Ok(()),
            other => Err(InferError::Protocol(format!(
                "expected PONG, got kind {}", other.kind()))),
        }
    }

    /// Synchronous inference: row-major `batch * n_in` codes in,
    /// row-major `batch * out_width` codes out.
    pub fn infer(&mut self, model: &str, batch: usize, n_in: usize,
                 codes: Vec<i32>) -> Result<Vec<i32>, InferError> {
        self.infer_deadline(model, batch, n_in, codes, None)
    }

    /// Synchronous inference with an optional µs deadline budget.
    pub fn infer_deadline(&mut self, model: &str, batch: usize,
                          n_in: usize, codes: Vec<i32>,
                          deadline_us: Option<u64>)
                          -> Result<Vec<i32>, InferError> {
        let id = self.send_infer_deadline(model, batch as u32,
                                          n_in as u32, codes,
                                          deadline_us)?;
        match self.recv_response(id)? {
            Message::Result { batch: b, codes, .. } => {
                if b as usize != batch {
                    return Err(InferError::Protocol(format!(
                        "result batch {b} != requested {batch}")));
                }
                Ok(codes)
            }
            other => Err(InferError::Protocol(format!(
                "expected RESULT, got kind {}", other.kind()))),
        }
    }

    /// Fetch the server's stats JSON (empty `model`: all models).
    pub fn stats(&mut self, model: &str) -> Result<String, InferError> {
        let id = self.send(&Message::Stats {
            model: model.to_string(),
        })?;
        match self.recv_response(id)? {
            Message::StatsResult { json } => Ok(json),
            other => Err(InferError::Protocol(format!(
                "expected STATS_RESULT, got kind {}", other.kind()))),
        }
    }

    /// Probe a hosted model's IO widths from the stats document.
    pub fn model_io(&mut self, model: &str)
                    -> Result<(usize, usize), InferError> {
        let json = self.stats(model)?;
        let parse = |json: &str| -> Result<(usize, usize)> {
            let doc = Json::parse(json)?;
            let arr = doc.at("models")?.as_arr()?;
            let entry = arr.first().ok_or_else(|| {
                anyhow::anyhow!("stats document lists no models")
            })?;
            Ok((entry.at("n_in")?.as_usize()?,
                entry.at("out_width")?.as_usize()?))
        };
        parse(&json).map_err(|e| {
            InferError::Protocol(format!("stats json: {e:#}"))
        })
    }
}

/// What a [`RetryClient`] has done so far — proof in tests that a
/// chaos run actually retried, and a production signal that the
/// server is shedding.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Individual attempts (requests + retries).
    pub attempts: u64,
    /// Attempts that were retries of a failed attempt.
    pub retries: u64,
    /// Connection re-establishments after a suspect failure.
    pub reconnects: u64,
    /// Requests that exhausted `max_attempts` on retryable errors.
    pub gave_up: u64,
    /// Total backoff slept, µs.
    pub backoff_us: u64,
}

/// A [`Client`] wrapped in bounded idempotent retries: capacity sheds
/// (`OVERLOADED`, `CONN_QUOTA`), transport failures and server
/// restarts are absorbed with decorrelated-jitter backoff; semantic
/// rejections (`BAD_INPUT`, `UNKNOWN_MODEL`, `DEADLINE`, `INTERNAL`)
/// pass straight through.  Inference is idempotent (same input, same
/// answer, no server-side state), so re-sending a request whose fate
/// is unknown is always safe — at worst the server computes it twice.
pub struct RetryClient {
    addrs: Vec<SocketAddr>,
    cfg: ClientConfig,
    conn: Option<Client>,
    rng: Rng,
    stats: RetryStats,
    ever_connected: bool,
}

impl RetryClient {
    /// Resolve `addr` and prepare a retrying client.  The connection
    /// itself is established lazily inside the retry loop, so a
    /// server that is still starting (or restarting) is handled like
    /// any other transient failure.
    pub fn connect(addr: impl ToSocketAddrs, cfg: ClientConfig)
                   -> Result<RetryClient, InferError> {
        let addrs: Vec<SocketAddr> = addr.to_socket_addrs()?.collect();
        if addrs.is_empty() {
            return Err(InferError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "address resolved to nothing")));
        }
        let rng = Rng::new(cfg.retry.seed);
        Ok(RetryClient {
            addrs,
            cfg,
            conn: None,
            rng,
            stats: RetryStats::default(),
            ever_connected: false,
        })
    }

    /// What the retry loop has done so far.
    pub fn retry_stats(&self) -> RetryStats {
        self.stats
    }

    fn ensure_conn(&mut self) -> Result<&mut Client, InferError> {
        if self.conn.is_none() {
            let mut last: Option<InferError> = None;
            for a in &self.addrs {
                match Client::connect_with(*a, &self.cfg) {
                    Ok(c) => {
                        self.conn = Some(c);
                        last = None;
                        break;
                    }
                    Err(e) => last = Some(e),
                }
            }
            match last {
                Some(e) => return Err(e),
                None => {
                    if self.ever_connected {
                        self.stats.reconnects += 1;
                    }
                    self.ever_connected = true;
                }
            }
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }

    /// Run `f` against a live connection, retrying per the policy.
    /// Fresh request ids per attempt fall out of the design: ids are
    /// per-connection counters, and a retried send is a new send.
    fn with_retry<T>(&mut self,
                     mut f: impl FnMut(&mut Client)
                                       -> Result<T, InferError>)
                     -> Result<T, InferError> {
        let base = (self.cfg.retry.base.as_micros() as u64).max(1);
        let cap = (self.cfg.retry.cap.as_micros() as u64).max(base);
        let max_attempts = self.cfg.retry.max_attempts.max(1);
        let mut prev = base;
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            self.stats.attempts += 1;
            let result = match self.ensure_conn() {
                Ok(c) => f(c),
                Err(e) => Err(e),
            };
            let e = match result {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            // drop a connection whose stream state is suspect; keep
            // it for pure capacity sheds (the stream is healthy and
            // reconnecting would only add SYN load)
            if matches!(e,
                        InferError::Io(_) | InferError::Protocol(_)
                        | InferError::BadFrame(_)
                        | InferError::ShuttingDown)
            {
                self.conn = None;
            }
            if !e.is_retryable() {
                return Err(e);
            }
            if attempt >= max_attempts {
                self.stats.gave_up += 1;
                return Err(e);
            }
            self.stats.retries += 1;
            let sleep_us = next_backoff_us(&mut self.rng, base, cap, prev);
            prev = sleep_us;
            self.stats.backoff_us += sleep_us;
            std::thread::sleep(Duration::from_micros(sleep_us));
        }
    }

    /// Synchronous inference with retries; `deadline_us` rides each
    /// attempt's frame.
    pub fn infer(&mut self, model: &str, batch: usize, n_in: usize,
                 codes: &[i32], deadline_us: Option<u64>)
                 -> Result<Vec<i32>, InferError> {
        self.with_retry(|c| {
            c.infer_deadline(model, batch, n_in, codes.to_vec(),
                             deadline_us)
        })
    }

    /// Ping with retries.
    pub fn ping(&mut self) -> Result<(), InferError> {
        self.with_retry(|c| c.ping())
    }

    /// Stats JSON with retries.
    pub fn stats(&mut self, model: &str) -> Result<String, InferError> {
        self.with_retry(|c| c.stats(model))
    }

    /// IO-width probe with retries.
    pub fn model_io(&mut self, model: &str)
                    -> Result<(usize, usize), InferError> {
        self.with_retry(|c| c.model_io(model))
    }
}

/// A served model behind the [`Session`] API: the TCP twin of
/// [`EngineSession`](super::session::EngineSession).  IO widths are
/// probed from the server at open time, so the caller needs nothing
/// but an address and a model name.
pub struct NetSession {
    client: Client,
    model: String,
    n_in: usize,
    inputs: Vec<String>,
    outputs: Vec<String>,
}

impl NetSession {
    pub fn open(addr: impl ToSocketAddrs, model: &str)
                -> Result<NetSession, InferError> {
        let mut client = Client::connect(addr)?;
        let (n_in, _) = client.model_io(model)?;
        Ok(NetSession {
            client,
            model: model.to_string(),
            n_in,
            inputs: vec![INPUT_X.to_string()],
            outputs: vec![OUTPUT_Y.to_string()],
        })
    }

    /// The underlying connection (e.g. for a stats query).
    pub fn client_mut(&mut self) -> &mut Client {
        &mut self.client
    }
}

impl Session for NetSession {
    fn run(&mut self, inputs: &[(&str, &[i32])])
           -> Result<HashMap<String, Vec<i32>>, InferError> {
        let (x, batch) = single_input_batch(inputs, self.n_in)?;
        let y = self.client.infer(&self.model, batch, self.n_in,
                                  x.to_vec())?;
        let mut out = HashMap::new();
        out.insert(OUTPUT_Y.to_string(), y);
        Ok(out)
    }

    fn input_names(&self) -> &[String] {
        &self.inputs
    }

    fn output_names(&self) -> &[String] {
        &self.outputs
    }
}

/// A served model viewed as an [`InferenceEngine`], so
/// [`check_conformance`](crate::coordinator::check_conformance) can
/// prove TCP answers bit-exact with the in-process executors.  Built
/// on [`RetryClient`], so a server restart or an injected fault
/// mid-conformance-run is absorbed instead of failing the contract.
///
/// `run_batch` deliberately does *not* pre-validate input length: the
/// request goes out with the model's declared `n_in`, so a short
/// input is rejected by the server's wire decode — conformance's
/// rejection case exercises the remote validation path, not a local
/// shortcut.
pub struct RemoteEngine {
    client: RetryClient,
    model: String,
    n_in: usize,
    out_width: usize,
}

impl RemoteEngine {
    pub fn open(addr: impl ToSocketAddrs, model: &str)
                -> Result<RemoteEngine, InferError> {
        RemoteEngine::open_with(addr, model, ClientConfig::default())
    }

    /// Open with explicit timeouts / retry policy / fault plan.
    pub fn open_with(addr: impl ToSocketAddrs, model: &str,
                     cfg: ClientConfig) -> Result<RemoteEngine, InferError> {
        let mut client = RetryClient::connect(addr, cfg)?;
        let (n_in, out_width) = client.model_io(model)?;
        Ok(RemoteEngine {
            client,
            model: model.to_string(),
            n_in,
            out_width,
        })
    }

    /// What the retry loop absorbed (attempts, reconnects, backoff).
    pub fn retry_stats(&self) -> RetryStats {
        self.client.retry_stats()
    }
}

impl InferenceEngine for RemoteEngine {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        let y = self
            .client
            .infer(&self.model, batch, self.n_in, x, None)
            .map_err(|e| anyhow::anyhow!("remote run_batch: {e}"))?;
        anyhow::ensure!(y.len() == batch * self.out_width,
                        "remote result len {} != batch {batch} * \
                         out_width {}", y.len(), self.out_width);
        Ok(y)
    }

    fn n_in(&self) -> usize {
        self.n_in
    }

    fn out_width(&self) -> usize {
        self.out_width
    }

    fn describe(&self) -> String {
        format!("remote model '{}': n_in {}, out_width {}", self.model,
                self.n_in, self.out_width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_stays_within_bounds_and_is_deterministic() {
        let policy = RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
            seed: 0xDECAF,
        };
        let a = backoff_schedule(&policy, 64);
        let b = backoff_schedule(&policy, 64);
        assert_eq!(a, b, "same seed, same schedule");
        for (i, &s) in a.iter().enumerate() {
            assert!((10_000..=1_000_000).contains(&s),
                    "sleep {i} = {s} µs outside [base, cap]");
        }
        // the window grows: late sleeps must be able to exceed the
        // first one (decorrelation, not a constant)
        assert!(a.iter().max() > a.first().as_ref(),
                "schedule never grew: {a:?}");
        let c = backoff_schedule(
            &RetryPolicy { seed: 0xDECAF + 1, ..policy }, 64);
        assert_ne!(a, c, "different seed, different jitter");
    }

    #[test]
    fn backoff_schedule_is_pinned_cross_language() {
        // python/tests/test_retry.py computes the same five values
        // from the same seed with its own Xoshiro256** port — a drift
        // in either implementation breaks one of the two tests
        let policy = RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
            seed: 0xDECAF,
        };
        assert_eq!(backoff_schedule(&policy, 5), PINNED_BACKOFF_US);
    }

    /// Shared with the Python mirror (see test_retry.py).
    const PINNED_BACKOFF_US: [u64; 5] =
        [15_407, 42_344, 15_890, 13_804, 23_193];

    #[test]
    fn zero_cap_and_tiny_base_never_panic() {
        let policy = RetryPolicy {
            max_attempts: 2,
            base: Duration::from_micros(0),
            cap: Duration::from_micros(0),
            seed: 1,
        };
        for s in backoff_schedule(&policy, 16) {
            assert_eq!(s, 1, "base floors at 1 µs and cap at base");
        }
    }

    #[test]
    fn connect_timeout_fails_fast_not_forever() {
        // RFC 5737 TEST-NET-1 address: connect attempts black-hole.
        // The call must come back around the configured timeout, not
        // hang — generous ceiling so loaded CI cannot flake it.
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(300),
            ..ClientConfig::default()
        };
        let t0 = std::time::Instant::now();
        let r = Client::connect_with("192.0.2.1:47999", &cfg);
        assert!(r.is_err(), "TEST-NET-1 must not accept");
        assert!(t0.elapsed() < Duration::from_secs(5),
                "connect took {:?}, timeout not applied", t0.elapsed());
    }
}
