//! Client side of the NLWP protocol: a blocking connection handle
//! ([`Client`]), the consumer-facing [`Session`] over it
//! ([`NetSession`]), and an [`InferenceEngine`] adapter
//! ([`RemoteEngine`]) so the conformance suite can hold a served
//! model to the exact same contract as an in-process executor.
//!
//! [`Client`] exposes both a synchronous request/response surface
//! (`infer`, `stats`, `ping`) and a split send/receive surface
//! (`send_infer` + `recv_frame`) for pipelining: a load generator may
//! keep many requests in flight on one connection, which is exactly
//! what drives the server's batcher to form large batches.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::InferenceEngine;
use crate::util::Json;

use super::session::{single_input_batch, InferError, Session, INPUT_X,
                     OUTPUT_Y};
use super::wire::{self, Frame, Message};

/// One blocking NLWP connection.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
}

impl Client {
    /// Connect to a [`NetServer`](super::server::NetServer).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client, InferError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader, next_id: 1 })
    }

    /// Optional read timeout — lets tests and load generators fail
    /// fast instead of hanging on a wedged peer.
    pub fn set_read_timeout(&self, t: Option<Duration>)
                            -> Result<(), InferError> {
        self.reader.get_ref().set_read_timeout(t)?;
        Ok(())
    }

    /// Send any request frame; returns the id the response will echo.
    pub fn send(&mut self, msg: &Message) -> Result<u64, InferError> {
        let id = self.next_id;
        self.next_id += 1;
        self.writer.write_all(&wire::encode_frame(id, msg))?;
        Ok(id)
    }

    /// Send one inference request without waiting (pipelining).
    pub fn send_infer(&mut self, model: &str, batch: u32, n_in: u32,
                      codes: Vec<i32>) -> Result<u64, InferError> {
        self.send(&Message::Infer {
            model: model.to_string(), batch, n_in, codes,
        })
    }

    /// Read the next frame off the wire.
    pub fn recv_frame(&mut self) -> Result<Frame, InferError> {
        Ok(wire::read_frame(&mut self.reader)?)
    }

    /// Read the response to request `id`.  Error frames for the
    /// request (including id-0 errors the server sends when a frame
    /// was too corrupt to carry a trustworthy id) become typed
    /// [`InferError`] values; anything else is a protocol violation.
    pub fn recv_response(&mut self, id: u64)
                         -> Result<Message, InferError> {
        let frame = self.recv_frame()?;
        match frame.msg {
            Message::Error { code, message }
                if frame.id == id || frame.id == 0 =>
            {
                Err(InferError::from_wire(code, &message))
            }
            msg if frame.id == id => Ok(msg),
            msg => Err(InferError::Protocol(format!(
                "response id {} does not match request id {id} \
                 (kind {})", frame.id, msg.kind()))),
        }
    }

    /// Round-trip a ping.
    pub fn ping(&mut self) -> Result<(), InferError> {
        let id = self.send(&Message::Ping)?;
        match self.recv_response(id)? {
            Message::Pong => Ok(()),
            other => Err(InferError::Protocol(format!(
                "expected PONG, got kind {}", other.kind()))),
        }
    }

    /// Synchronous inference: row-major `batch * n_in` codes in,
    /// row-major `batch * out_width` codes out.
    pub fn infer(&mut self, model: &str, batch: usize, n_in: usize,
                 codes: Vec<i32>) -> Result<Vec<i32>, InferError> {
        let id = self.send_infer(model, batch as u32, n_in as u32,
                                 codes)?;
        match self.recv_response(id)? {
            Message::Result { batch: b, codes, .. } => {
                if b as usize != batch {
                    return Err(InferError::Protocol(format!(
                        "result batch {b} != requested {batch}")));
                }
                Ok(codes)
            }
            other => Err(InferError::Protocol(format!(
                "expected RESULT, got kind {}", other.kind()))),
        }
    }

    /// Fetch the server's stats JSON (empty `model`: all models).
    pub fn stats(&mut self, model: &str) -> Result<String, InferError> {
        let id = self.send(&Message::Stats {
            model: model.to_string(),
        })?;
        match self.recv_response(id)? {
            Message::StatsResult { json } => Ok(json),
            other => Err(InferError::Protocol(format!(
                "expected STATS_RESULT, got kind {}", other.kind()))),
        }
    }

    /// Probe a hosted model's IO widths from the stats document.
    pub fn model_io(&mut self, model: &str)
                    -> Result<(usize, usize), InferError> {
        let json = self.stats(model)?;
        let parse = |json: &str| -> Result<(usize, usize)> {
            let doc = Json::parse(json)?;
            let arr = doc.at("models")?.as_arr()?;
            let entry = arr.first().ok_or_else(|| {
                anyhow::anyhow!("stats document lists no models")
            })?;
            Ok((entry.at("n_in")?.as_usize()?,
                entry.at("out_width")?.as_usize()?))
        };
        parse(&json).map_err(|e| {
            InferError::Protocol(format!("stats json: {e:#}"))
        })
    }
}

/// A served model behind the [`Session`] API: the TCP twin of
/// [`EngineSession`](super::session::EngineSession).  IO widths are
/// probed from the server at open time, so the caller needs nothing
/// but an address and a model name.
pub struct NetSession {
    client: Client,
    model: String,
    n_in: usize,
    inputs: Vec<String>,
    outputs: Vec<String>,
}

impl NetSession {
    pub fn open(addr: impl ToSocketAddrs, model: &str)
                -> Result<NetSession, InferError> {
        let mut client = Client::connect(addr)?;
        let (n_in, _) = client.model_io(model)?;
        Ok(NetSession {
            client,
            model: model.to_string(),
            n_in,
            inputs: vec![INPUT_X.to_string()],
            outputs: vec![OUTPUT_Y.to_string()],
        })
    }

    /// The underlying connection (e.g. for a stats query).
    pub fn client_mut(&mut self) -> &mut Client {
        &mut self.client
    }
}

impl Session for NetSession {
    fn run(&mut self, inputs: &[(&str, &[i32])])
           -> Result<HashMap<String, Vec<i32>>, InferError> {
        let (x, batch) = single_input_batch(inputs, self.n_in)?;
        let y = self.client.infer(&self.model, batch, self.n_in,
                                  x.to_vec())?;
        let mut out = HashMap::new();
        out.insert(OUTPUT_Y.to_string(), y);
        Ok(out)
    }

    fn input_names(&self) -> &[String] {
        &self.inputs
    }

    fn output_names(&self) -> &[String] {
        &self.outputs
    }
}

/// A served model viewed as an [`InferenceEngine`], so
/// [`check_conformance`](crate::coordinator::check_conformance) can
/// prove TCP answers bit-exact with the in-process executors.
///
/// `run_batch` deliberately does *not* pre-validate input length: the
/// request goes out with the model's declared `n_in`, so a short
/// input is rejected by the server's wire decode — conformance's
/// rejection case exercises the remote validation path, not a local
/// shortcut.
pub struct RemoteEngine {
    client: Client,
    model: String,
    n_in: usize,
    out_width: usize,
}

impl RemoteEngine {
    pub fn open(addr: impl ToSocketAddrs, model: &str)
                -> Result<RemoteEngine, InferError> {
        let mut client = Client::connect(addr)?;
        let (n_in, out_width) = client.model_io(model)?;
        Ok(RemoteEngine {
            client,
            model: model.to_string(),
            n_in,
            out_width,
        })
    }
}

impl InferenceEngine for RemoteEngine {
    fn run_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<i32>> {
        let y = self
            .client
            .infer(&self.model, batch, self.n_in, x.to_vec())
            .map_err(|e| anyhow::anyhow!("remote run_batch: {e}"))?;
        anyhow::ensure!(y.len() == batch * self.out_width,
                        "remote result len {} != batch {batch} * \
                         out_width {}", y.len(), self.out_width);
        Ok(y)
    }

    fn n_in(&self) -> usize {
        self.n_in
    }

    fn out_width(&self) -> usize {
        self.out_width
    }

    fn describe(&self) -> String {
        format!("remote model '{}': n_in {}, out_width {}", self.model,
                self.n_in, self.out_width)
    }
}
