//! The `nlwp` wire protocol: length-prefixed binary frames over TCP.
//!
//! Design goals, in order: *totality* (any byte stream either parses
//! into a validated frame or yields a typed [`WireError`] — decoding
//! never panics and never allocates more than the frame cap), *errors
//! as values* (a server answers malformed or rejected requests with
//! [`Message::Error`] frames; the connection aborts only when framing
//! sync is lost), and *cheapness* (one 24-byte header, no text
//! parsing on the request path — the nanoseconds the plan executor
//! saves are not spent re-tokenizing JSON).
//!
//! The python mirror (`python/compile/wire.py`) encodes the identical
//! bytes; the committed golden frames (`rust/tests/golden/
//! golden_frames.bin` for v2, `golden_frames_v1.bin` for the v1
//! back-compat surface) pin the cross-language contract the same way
//! the `.nlb` goldens pin the artifact format.
//!
//! ## Frame layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic "NLWP"
//! 4       2     version (1 or 2; encoders emit 2)
//! 6       2     kind (see the KIND_* constants)
//! 8       8     request id (echoed verbatim in the response)
//! 16      4     body length (<= MAX_BODY)
//! 20      4     body checksum (low 32 bits of FNV-1a over the body)
//! 24      ..    body (layout depends on kind and version)
//!
//! kind 1  INFER         u16 model-name length + UTF-8 name,
//!                       u32 batch, u32 n_in,
//!                       [v2 only] u64 deadline budget in µs
//!                       (NO_DEADLINE = none; 0 and values above
//!                       MAX_DEADLINE_US are malformed),
//!                       batch * n_in  i32 input codes (row-major)
//! kind 2  RESULT        u32 batch, u32 out_width,
//!                       batch * out_width  i32 output codes (row-major)
//! kind 3  ERROR         u16 error code (ERR_*),
//!                       u16 message length + UTF-8 message
//! kind 4  STATS         u16 model-name length + UTF-8 name
//!                       (length 0: every hosted model)
//! kind 5  STATS_RESULT  UTF-8 JSON document (the whole body)
//! kind 6  PING          empty body
//! kind 7  PONG          empty body
//! ```
//!
//! ## Versioning & recovery policy
//!
//! The version bumps on any layout change; readers accept the closed
//! range [`WIRE_MIN_VERSION`]..=[`WIRE_VERSION`] and reject the rest —
//! an old peer must never misparse a new frame.  v2 added exactly one
//! field (the INFER deadline); a v1 INFER decodes as "no deadline",
//! so v1 clients get full service from a v2 server.  Encoders emit
//! v2 by default; [`encode_frame_versioned`] emits v1 for compat
//! testing and old peers (and refuses to silently drop a deadline).
//!
//! Errors split into two classes:
//!
//! * **fatal** ([`WireError::is_fatal`]): bad magic, unknown version,
//!   a body length beyond [`MAX_BODY`], or transport I/O failure —
//!   framing sync is lost (or never existed), so the peer answers
//!   with one final [`Message::Error`] frame where possible and
//!   closes the connection;
//! * **recoverable**: checksum mismatch, unknown kind, malformed body
//!   (including a zero or over-cap deadline) — the full frame was
//!   consumed, sync holds, so the peer answers with a typed
//!   [`Message::Error`] and keeps the connection open.
//!
//! A single corrupted byte anywhere in a body is always caught: every
//! FNV-1a step is bijective modulo 2^32 in the running hash, so two
//! bodies differing in one byte can never share the truncated
//! checksum.

use std::fmt;
use std::io::Read;

use crate::netlist::fnv1a;

pub const WIRE_MAGIC: [u8; 4] = *b"NLWP";
/// Version emitted by encoders.
pub const WIRE_VERSION: u16 = 2;
/// Oldest version readers still accept (v1: INFER without deadline).
pub const WIRE_MIN_VERSION: u16 = 1;
/// Fixed frame-header size in bytes.
pub const HEADER_LEN: usize = 24;
/// Hard cap on a frame body — an adversarial length prefix is rejected
/// before any allocation (16 MiB ≈ a 4M-sample single-code batch, far
/// beyond any sane request).
pub const MAX_BODY: usize = 1 << 24;
/// Cap on a model-name field.
pub const MAX_NAME: usize = 256;
/// Cap on an error-message field (encoders truncate to fit).
pub const MAX_MESSAGE: usize = 4096;
/// Wire sentinel for "no deadline" in a v2 INFER body.
pub const NO_DEADLINE: u64 = u64::MAX;
/// Cap on a deadline budget: one hour in µs.  A budget of 0 (expired
/// before it was sent) or beyond the cap (indistinguishable from a
/// corrupt field) is malformed, not a larger grant.
pub const MAX_DEADLINE_US: u64 = 3_600_000_000;

pub const KIND_INFER: u16 = 1;
pub const KIND_RESULT: u16 = 2;
pub const KIND_ERROR: u16 = 3;
pub const KIND_STATS: u16 = 4;
pub const KIND_STATS_RESULT: u16 = 5;
pub const KIND_PING: u16 = 6;
pub const KIND_PONG: u16 = 7;

/// Error codes carried by [`Message::Error`] frames.
pub const ERR_BAD_FRAME: u16 = 1;
pub const ERR_UNKNOWN_MODEL: u16 = 2;
pub const ERR_BAD_INPUT: u16 = 3;
pub const ERR_OVERLOADED: u16 = 4;
pub const ERR_SHUTTING_DOWN: u16 = 5;
pub const ERR_INTERNAL: u16 = 6;
/// The request's deadline budget cannot be met (already expired at
/// admission, or the remaining budget is below the model's observed
/// p50 service time).  Retrying without a larger budget is futile.
pub const ERR_DEADLINE: u16 = 7;
/// This connection is over its per-connection inflight quota while
/// the server as a whole still has room — back off on *this*
/// connection; other connections are unaffected.
pub const ERR_CONN_QUOTA: u16 = 8;

/// One decoded frame body.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Evaluate `batch` row-major samples of `n_in` codes on `model`.
    /// `deadline_us` is the caller's whole-request latency budget in
    /// µs, measured by the server from frame arrival (`None`: no
    /// deadline; v1 frames always decode as `None`).
    Infer {
        model: String,
        batch: u32,
        n_in: u32,
        deadline_us: Option<u64>,
        codes: Vec<i32>,
    },
    /// Row-major output codes for a completed [`Message::Infer`].
    Result { batch: u32, out_width: u32, codes: Vec<i32> },
    /// A rejected or failed request — an answer, not a disconnect.
    Error { code: u16, message: String },
    /// Request serving statistics (`model` empty: all models).
    Stats { model: String },
    /// JSON statistics document (see `net::server` for the schema).
    StatsResult { json: String },
    /// Liveness / drain probe.
    Ping,
    /// Answer to [`Message::Ping`].
    Pong,
}

impl Message {
    pub fn kind(&self) -> u16 {
        match self {
            Message::Infer { .. } => KIND_INFER,
            Message::Result { .. } => KIND_RESULT,
            Message::Error { .. } => KIND_ERROR,
            Message::Stats { .. } => KIND_STATS,
            Message::StatsResult { .. } => KIND_STATS_RESULT,
            Message::Ping => KIND_PING,
            Message::Pong => KIND_PONG,
        }
    }
}

/// One frame: the echoed request id plus the decoded body.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub id: u64,
    pub msg: Message,
}

/// Typed decode/transport failure.  [`WireError::is_fatal`] tells a
/// peer whether framing sync survives (answer and continue) or not
/// (answer best-effort, then close).
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    BadVersion(u16),
    Oversize(u32),
    BadChecksum,
    UnknownKind(u16),
    Malformed(String),
}

impl WireError {
    /// True when the byte stream can no longer be trusted to be
    /// frame-aligned (close the connection after answering).
    pub fn is_fatal(&self) -> bool {
        matches!(self,
                 WireError::Io(_) | WireError::BadMagic(_)
                 | WireError::BadVersion(_) | WireError::Oversize(_))
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "transport: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad magic {m:02x?} (expected \"NLWP\")")
            }
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this peer \
                           speaks versions {WIRE_MIN_VERSION}..=\
                           {WIRE_VERSION})")
            }
            WireError::Oversize(n) => {
                write!(f, "body length {n} exceeds the {MAX_BODY}-byte cap")
            }
            WireError::BadChecksum => {
                write!(f, "body checksum mismatch (frame corrupt)")
            }
            WireError::UnknownKind(k) => {
                write!(f, "unknown frame kind {k}")
            }
            WireError::Malformed(m) => write!(f, "malformed body: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Low 32 bits of FNV-1a — the body checksum.  Public so tests (and
/// fuzzers) can forge frames whose checksum is valid but whose body
/// is semantically hostile.
pub fn body_checksum(body: &[u8]) -> u32 {
    fnv1a(body) as u32
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32s(out: &mut Vec<u8>, vs: &[i32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_name(out: &mut Vec<u8>, name: &str) {
    debug_assert!(name.len() <= MAX_NAME, "encoder name too long");
    put_u16(out, name.len() as u16);
    out.extend_from_slice(name.as_bytes());
}

/// Serialize one frame at the current wire version.  Encoding is
/// canonical: decoding the result and re-encoding it reproduces the
/// bytes (the golden-frame test holds both implementations to this).
pub fn encode_frame(id: u64, msg: &Message) -> Vec<u8> {
    encode_frame_versioned(id, msg, WIRE_VERSION)
}

/// Serialize one frame at an explicit wire version (compat testing,
/// talking to old peers).
///
/// # Panics
///
/// Panics on a version outside [`WIRE_MIN_VERSION`]..=[`WIRE_VERSION`]
/// and on a v1 INFER carrying a deadline — v1 cannot represent one,
/// and silently dropping a latency budget would be worse than
/// refusing.
pub fn encode_frame_versioned(id: u64, msg: &Message, version: u16)
                              -> Vec<u8> {
    assert!((WIRE_MIN_VERSION..=WIRE_VERSION).contains(&version),
            "cannot encode wire version {version}");
    let mut body = Vec::new();
    match msg {
        Message::Infer { model, batch, n_in, deadline_us, codes } => {
            put_name(&mut body, model);
            put_u32(&mut body, *batch);
            put_u32(&mut body, *n_in);
            match version {
                1 => assert!(deadline_us.is_none(),
                             "wire v1 cannot carry a deadline"),
                _ => {
                    let raw = match deadline_us {
                        None => NO_DEADLINE,
                        Some(d) => {
                            debug_assert!(
                                (1..=MAX_DEADLINE_US).contains(d),
                                "encoder deadline {d} outside \
                                 1..={MAX_DEADLINE_US}");
                            *d
                        }
                    };
                    put_u64(&mut body, raw);
                }
            }
            put_i32s(&mut body, codes);
        }
        Message::Result { batch, out_width, codes } => {
            put_u32(&mut body, *batch);
            put_u32(&mut body, *out_width);
            put_i32s(&mut body, codes);
        }
        Message::Error { code, message } => {
            put_u16(&mut body, *code);
            // truncate at a char boundary so the field always fits
            let mut cut = message.len().min(MAX_MESSAGE);
            while cut > 0 && !message.is_char_boundary(cut) {
                cut -= 1;
            }
            put_u16(&mut body, cut as u16);
            body.extend_from_slice(&message.as_bytes()[..cut]);
        }
        Message::Stats { model } => {
            put_name(&mut body, model);
        }
        Message::StatsResult { json } => {
            body.extend_from_slice(json.as_bytes());
        }
        Message::Ping | Message::Pong => {}
    }
    debug_assert!(body.len() <= MAX_BODY, "encoder body over cap");
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&WIRE_MAGIC);
    put_u16(&mut out, version);
    put_u16(&mut out, msg.kind());
    put_u64(&mut out, id);
    put_u32(&mut out, body.len() as u32);
    put_u32(&mut out, body_checksum(&body));
    out.extend_from_slice(&body);
    out
}

/// Bounds-checked little-endian cursor over a frame body.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Malformed(format!(
                "{what} needs {n} bytes at offset {}, only {} left",
                self.pos, self.remaining())));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn i32s(&mut self, count: usize, what: &str)
            -> Result<Vec<i32>, WireError> {
        let n = count.checked_mul(4).ok_or_else(|| {
            WireError::Malformed(format!("{what}: count overflow"))
        })?;
        Ok(self.take(n, what)?
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn name(&mut self, what: &str) -> Result<String, WireError> {
        let len = self.u16(what)? as usize;
        if len > MAX_NAME {
            return Err(WireError::Malformed(format!(
                "{what} length {len} exceeds the {MAX_NAME}-byte cap")));
        }
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| {
            WireError::Malformed(format!("{what} is not UTF-8"))
        })
    }
}

/// Decoded header: the fixed part of a frame, validated except for the
/// body checksum (which needs the body).
struct Header {
    version: u16,
    kind: u16,
    id: u64,
    body_len: usize,
    body_sum: u32,
}

fn decode_header(h: &[u8; HEADER_LEN]) -> Result<Header, WireError> {
    if h[..4] != WIRE_MAGIC {
        return Err(WireError::BadMagic([h[0], h[1], h[2], h[3]]));
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if !(WIRE_MIN_VERSION..=WIRE_VERSION).contains(&version) {
        return Err(WireError::BadVersion(version));
    }
    let kind = u16::from_le_bytes([h[6], h[7]]);
    let id = u64::from_le_bytes(h[8..16].try_into().unwrap());
    let body_len = u32::from_le_bytes(h[16..20].try_into().unwrap());
    if body_len as usize > MAX_BODY {
        return Err(WireError::Oversize(body_len));
    }
    let body_sum = u32::from_le_bytes(h[20..24].try_into().unwrap());
    Ok(Header { version, kind, id, body_len: body_len as usize, body_sum })
}

fn decode_body(version: u16, kind: u16, body: &[u8])
               -> Result<Message, WireError> {
    let mut c = Cursor::new(body);
    let msg = match kind {
        KIND_INFER => {
            let model = c.name("model name")?;
            let batch = c.u32("batch")?;
            let n_in = c.u32("n_in")?;
            let deadline_us = if version >= 2 {
                match c.u64("deadline")? {
                    NO_DEADLINE => None,
                    0 => {
                        return Err(WireError::Malformed(
                            "deadline budget 0 µs (already expired; \
                             omit the deadline or grant a budget)"
                                .into()));
                    }
                    d if d > MAX_DEADLINE_US => {
                        return Err(WireError::Malformed(format!(
                            "deadline budget {d} µs exceeds the \
                             {MAX_DEADLINE_US} µs cap")));
                    }
                    d => Some(d),
                }
            } else {
                None
            };
            let count = (batch as usize)
                .checked_mul(n_in as usize)
                .ok_or_else(|| {
                    WireError::Malformed("batch * n_in overflow".into())
                })?;
            let codes = c.i32s(count, "input codes")?;
            Message::Infer { model, batch, n_in, deadline_us, codes }
        }
        KIND_RESULT => {
            let batch = c.u32("batch")?;
            let out_width = c.u32("out_width")?;
            let count = (batch as usize)
                .checked_mul(out_width as usize)
                .ok_or_else(|| {
                    WireError::Malformed("batch * out_width overflow".into())
                })?;
            let codes = c.i32s(count, "output codes")?;
            Message::Result { batch, out_width, codes }
        }
        KIND_ERROR => {
            let code = c.u16("error code")?;
            let len = c.u16("message length")? as usize;
            let bytes = c.take(len, "message")?;
            let message = String::from_utf8(bytes.to_vec()).map_err(|_| {
                WireError::Malformed("error message is not UTF-8".into())
            })?;
            Message::Error { code, message }
        }
        KIND_STATS => Message::Stats { model: c.name("model name")? },
        KIND_STATS_RESULT => {
            let bytes = c.take(c.remaining(), "stats json")?;
            let json = String::from_utf8(bytes.to_vec()).map_err(|_| {
                WireError::Malformed("stats json is not UTF-8".into())
            })?;
            Message::StatsResult { json }
        }
        KIND_PING => Message::Ping,
        KIND_PONG => Message::Pong,
        other => return Err(WireError::UnknownKind(other)),
    };
    if c.remaining() != 0 {
        return Err(WireError::Malformed(format!(
            "{} trailing bytes after the body", c.remaining())));
    }
    Ok(msg)
}

/// Parse exactly one frame from the front of `bytes`; returns the
/// frame and the number of bytes consumed.  Total: any input either
/// parses or yields a typed error, never panics.
pub fn decode_frame(bytes: &[u8]) -> Result<(Frame, usize), WireError> {
    if bytes.len() < HEADER_LEN {
        return Err(WireError::Malformed(format!(
            "truncated header: {} bytes, need {HEADER_LEN}", bytes.len())));
    }
    let header: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
    let h = decode_header(&header)?;
    let total = HEADER_LEN + h.body_len;
    if bytes.len() < total {
        return Err(WireError::Malformed(format!(
            "truncated body: frame needs {total} bytes, have {}",
            bytes.len())));
    }
    let body = &bytes[HEADER_LEN..total];
    if body_checksum(body) != h.body_sum {
        return Err(WireError::BadChecksum);
    }
    let msg = decode_body(h.version, h.kind, body)?;
    Ok((Frame { id: h.id, msg }, total))
}

/// Read one frame from a blocking stream.  Fatal errors ([`WireError::
/// is_fatal`]) mean the stream is no longer frame-aligned; recoverable
/// ones consumed the whole frame, so the caller may answer with a
/// [`Message::Error`] and keep reading.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut hb = [0u8; HEADER_LEN];
    r.read_exact(&mut hb)?;
    let h = decode_header(&hb)?;
    let mut body = vec![0u8; h.body_len];
    r.read_exact(&mut body)?;
    if body_checksum(&body) != h.body_sum {
        return Err(WireError::BadChecksum);
    }
    let msg = decode_body(h.version, h.kind, &body)?;
    Ok(Frame { id: h.id, msg })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<(u64, Message)> {
        vec![
            (1, Message::Ping),
            (2, Message::Pong),
            (0x0123_4567_89AB_CDEF,
             Message::Infer { model: "nid".into(), batch: 2, n_in: 3,
                              deadline_us: None,
                              codes: vec![0, 1, -2, 3, 2, 1] }),
            (6, Message::Infer { model: "dl".into(), batch: 1, n_in: 2,
                                 deadline_us: Some(250_000),
                                 codes: vec![1, 0] }),
            (7, Message::Result { batch: 2, out_width: 1,
                                  codes: vec![1, -3] }),
            (8, Message::Error { code: ERR_OVERLOADED,
                                 message: "shed".into() }),
            (9, Message::Stats { model: String::new() }),
            (10, Message::Stats { model: "jsc".into() }),
            (11, Message::StatsResult { json: "{\"x\":1}".into() }),
            (12, Message::Error { code: ERR_DEADLINE,
                                  message: "late".into() }),
            (13, Message::Error { code: ERR_CONN_QUOTA,
                                  message: "quota".into() }),
        ]
    }

    #[test]
    fn roundtrip_every_kind() {
        for (id, msg) in sample_frames() {
            let bytes = encode_frame(id, &msg);
            let (frame, used) = decode_frame(&bytes).unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(frame.id, id);
            assert_eq!(frame.msg, msg);
            // canonical: re-encoding reproduces the bytes
            assert_eq!(encode_frame(frame.id, &frame.msg), bytes);
        }
    }

    #[test]
    fn v1_roundtrip_and_cross_version_decode() {
        for (id, msg) in sample_frames() {
            if let Message::Infer { deadline_us: Some(_), .. } = msg {
                continue; // unrepresentable in v1 (panics, tested below)
            }
            let bytes = encode_frame_versioned(id, &msg, 1);
            assert_eq!(bytes[4..6], 1u16.to_le_bytes());
            let (frame, used) = decode_frame(&bytes).unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(frame.id, id);
            // a v1 frame decodes to the same message (deadline None)
            assert_eq!(frame.msg, msg);
            // canonical per version: v1 re-encoding reproduces bytes
            assert_eq!(encode_frame_versioned(frame.id, &frame.msg, 1),
                       bytes);
        }
    }

    #[test]
    #[should_panic(expected = "wire v1 cannot carry a deadline")]
    fn v1_refuses_to_drop_a_deadline() {
        let msg = Message::Infer { model: "m".into(), batch: 1, n_in: 1,
                                   deadline_us: Some(5), codes: vec![0] };
        let _ = encode_frame_versioned(3, &msg, 1);
    }

    /// Rewrite the raw deadline field of an encoded v2 INFER frame and
    /// fix the checksum, so only the deadline validation can reject it.
    fn with_raw_deadline(model: &str, raw: u64) -> Vec<u8> {
        let msg = Message::Infer { model: model.into(), batch: 1, n_in: 1,
                                   deadline_us: None, codes: vec![7] };
        let mut bytes = encode_frame(20, &msg);
        let off = HEADER_LEN + 2 + model.len() + 4 + 4;
        bytes[off..off + 8].copy_from_slice(&raw.to_le_bytes());
        let sum = body_checksum(&bytes[HEADER_LEN..]);
        bytes[20..24].copy_from_slice(&sum.to_le_bytes());
        bytes
    }

    #[test]
    fn deadline_validation_rejects_zero_and_oversize() {
        // zero budget: malformed, recoverable
        let err = decode_frame(&with_raw_deadline("m", 0)).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "got {err:?}");
        assert!(!err.is_fatal());
        // just over the cap: malformed, recoverable
        let err = decode_frame(&with_raw_deadline("m", MAX_DEADLINE_US + 1))
            .unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "got {err:?}");
        assert!(!err.is_fatal());
        // boundary values decode
        for (raw, want) in [(1, Some(1)),
                            (MAX_DEADLINE_US, Some(MAX_DEADLINE_US)),
                            (NO_DEADLINE, None)] {
            let (frame, _) =
                decode_frame(&with_raw_deadline("m", raw)).unwrap();
            match frame.msg {
                Message::Infer { deadline_us, .. } => {
                    assert_eq!(deadline_us, want, "raw {raw}");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn rejects_truncation_at_every_length() {
        let bytes = encode_frame(3, &Message::Infer {
            model: "m".into(), batch: 2, n_in: 2,
            deadline_us: Some(1000), codes: vec![1, 2, 3, 4],
        });
        for n in 0..bytes.len() {
            assert!(decode_frame(&bytes[..n]).is_err(),
                    "prefix {n} accepted");
        }
    }

    #[test]
    fn single_byte_body_corruption_is_always_caught() {
        let bytes = encode_frame(4, &Message::Infer {
            model: "model".into(), batch: 3, n_in: 4,
            deadline_us: Some(123_456), codes: (0..12).collect(),
        });
        for pos in HEADER_LEN..bytes.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut evil = bytes.clone();
                evil[pos] ^= flip;
                match decode_frame(&evil) {
                    Err(WireError::BadChecksum) => {}
                    other => panic!(
                        "body byte {pos} ^ {flip:#x}: expected checksum \
                         failure, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn bad_magic_is_fatal() {
        let mut bytes = encode_frame(5, &Message::Ping);
        bytes[0] = b'X';
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::BadMagic(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn future_version_is_fatal() {
        let mut bytes = encode_frame(5, &Message::Ping);
        bytes[4] = WIRE_VERSION as u8 + 1;
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::BadVersion(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn version_zero_is_fatal() {
        let mut bytes = encode_frame(5, &Message::Ping);
        bytes[4] = 0;
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::BadVersion(0)));
        assert!(err.is_fatal());
    }

    #[test]
    fn oversize_length_is_fatal_and_rejected_before_allocation() {
        let mut bytes = encode_frame(5, &Message::Ping);
        bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::Oversize(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn unknown_kind_is_recoverable() {
        let mut bytes = encode_frame(5, &Message::Ping);
        bytes[6] = 0xEE;
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::UnknownKind(_)));
        assert!(!err.is_fatal());
    }

    #[test]
    fn checksum_and_malformed_are_recoverable() {
        assert!(!WireError::BadChecksum.is_fatal());
        assert!(!WireError::Malformed("x".into()).is_fatal());
    }

    #[test]
    fn rejects_overlong_name() {
        // hand-build an infer body with a name over the cap, with a
        // consistent checksum so only the name check can reject it
        let mut body = Vec::new();
        put_u16(&mut body, (MAX_NAME + 1) as u16);
        body.extend_from_slice(&vec![b'a'; MAX_NAME + 1]);
        put_u32(&mut body, 1);
        put_u32(&mut body, 0);
        put_u64(&mut body, NO_DEADLINE);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&WIRE_MAGIC);
        put_u16(&mut bytes, WIRE_VERSION);
        put_u16(&mut bytes, KIND_INFER);
        put_u64(&mut bytes, 1);
        put_u32(&mut bytes, body.len() as u32);
        put_u32(&mut bytes, body_checksum(&body));
        bytes.extend_from_slice(&body);
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "got {err:?}");
    }

    #[test]
    fn rejects_trailing_bytes_in_body() {
        // a Ping body must be empty: splice one byte in and fix up the
        // header so only the body-shape check can reject it
        let mut bytes = encode_frame(6, &Message::Ping);
        bytes.push(0x55);
        let blen = 1u32;
        bytes[16..20].copy_from_slice(&blen.to_le_bytes());
        let sum = body_checksum(&[0x55]);
        bytes[20..24].copy_from_slice(&sum.to_le_bytes());
        let err = decode_frame(&bytes).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "got {err:?}");
    }

    #[test]
    fn error_message_is_truncated_at_char_boundary() {
        let long = "é".repeat(MAX_MESSAGE); // 2 bytes per char
        let bytes = encode_frame(1, &Message::Error {
            code: ERR_INTERNAL, message: long,
        });
        let (frame, _) = decode_frame(&bytes).unwrap();
        match frame.msg {
            Message::Error { message, .. } => {
                assert!(message.len() <= MAX_MESSAGE);
                assert!(!message.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn stream_reader_handles_back_to_back_frames_and_eof() {
        let mut stream = Vec::new();
        for (id, msg) in sample_frames() {
            stream.extend_from_slice(&encode_frame(id, &msg));
        }
        let mut r = std::io::Cursor::new(stream);
        for (id, msg) in sample_frames() {
            let frame = read_frame(&mut r).unwrap();
            assert_eq!(frame.id, id);
            assert_eq!(frame.msg, msg);
        }
        // clean EOF at a frame boundary surfaces as a fatal Io error
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(err, WireError::Io(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn stream_reader_accepts_mixed_version_frames() {
        // a v1 INFER between two v2 frames: the reader tracks the
        // per-frame version, not a per-connection one
        let infer = Message::Infer { model: "m".into(), batch: 1, n_in: 1,
                                     deadline_us: None, codes: vec![4] };
        let mut stream = Vec::new();
        stream.extend_from_slice(&encode_frame(1, &Message::Ping));
        stream.extend_from_slice(&encode_frame_versioned(2, &infer, 1));
        stream.extend_from_slice(&encode_frame(3, &infer));
        let mut r = std::io::Cursor::new(stream);
        assert_eq!(read_frame(&mut r).unwrap().msg, Message::Ping);
        assert_eq!(read_frame(&mut r).unwrap().msg, infer);
        assert_eq!(read_frame(&mut r).unwrap().msg, infer);
    }

    #[test]
    fn mid_frame_eof_is_fatal_io() {
        let bytes = encode_frame(9, &Message::Stats { model: "m".into() });
        // cut inside the body: header parses, body read hits EOF
        let mut r = std::io::Cursor::new(bytes[..HEADER_LEN + 1].to_vec());
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(err, WireError::Io(_)));
        assert!(err.is_fatal());
    }

    #[test]
    fn zero_width_result_roundtrips() {
        // out_width 0 (a hollow model) is representable: batch > 0,
        // empty codes
        let msg = Message::Result { batch: 3, out_width: 0,
                                    codes: vec![] };
        let bytes = encode_frame(12, &msg);
        let (frame, _) = decode_frame(&bytes).unwrap();
        assert_eq!(frame.msg, msg);
    }
}
