//! Network-facing serving layer: the NLWP wire protocol and the TCP
//! frontend over the coordinator's batching
//! [`InferenceServer`](crate::coordinator::InferenceServer).
//!
//! * [`wire`] — the length-prefixed binary framing (magic, version,
//!   request id, checksummed body) with total decode: every corrupt
//!   byte stream yields a typed [`wire::WireError`], never a panic.
//! * [`session`] — the transport-independent consumer API:
//!   [`Session`] (named inputs/outputs, errors as values) and the
//!   typed [`InferError`].
//! * [`server`] — [`NetServer`]: per-connection reader/writer thread
//!   pairs feeding the batching router, admission control with
//!   explicit sheds, graceful drain, stats over the wire.
//! * [`client`] — [`Client`] (sync + pipelined), [`RetryClient`]
//!   (bounded decorrelated-jitter retries over idempotent requests),
//!   [`NetSession`] (`Session` over TCP) and [`RemoteEngine`] (so the
//!   conformance suite holds the wire path to bit-exactness with
//!   in-process executors, retrying through restarts and chaos).
//! * [`fault`] — deterministic fault injection ([`FaultPlan`],
//!   [`fault::FaultyIo`], [`fault::NetIo`]): seeded or scripted
//!   schedules of delays, resets, truncations, corruption and partial
//!   I/O, threadable into both server connections and clients so the
//!   chaos battery can prove the failure story instead of asserting
//!   it.
//!
//! The design point mirrors the deployment story of an FPGA LUT
//! model: the network frontend must never be the reason the answer is
//! wrong (corruption is detected, overload is an explicit typed shed,
//! shutdown flushes in-flight work) and must never amplify load
//! (bounded admission, bounded per-connection quotas, bounded writer
//! queues, backpressure to TCP).

pub mod client;
pub mod fault;
pub mod server;
pub mod session;
pub mod wire;

pub use client::{Client, ClientConfig, NetSession, RemoteEngine,
                 RetryClient, RetryPolicy, RetryStats};
pub use fault::{Fault, FaultCounts, FaultPlan};
pub use server::{NetConfig, NetServer};
pub use session::{EngineSession, InferError, Session, INPUT_X, OUTPUT_Y};
