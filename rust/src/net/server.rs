//! TCP serving frontend: the socket between [`InferenceServer`]'s
//! router/batcher and the outside world.
//!
//! One [`NetServer`] owns one `InferenceServer` and a listening
//! socket.  Each accepted connection gets a **reader** thread (decode
//! frames, admit or shed, submit to the batching router) and a
//! **writer** thread (wait for answers, encode responses) joined by a
//! bounded queue — so a client may pipeline arbitrarily many requests
//! on one connection and the batcher sees them all concurrently, while
//! responses stay in request order per connection (ids are still
//! echoed, so clients need not rely on ordering).
//!
//! # Admission control
//!
//! Admission is two-level.  The frontend bounds *admitted rows*
//! (samples submitted to the router whose responses have not yet been
//! written) globally at [`NetConfig::max_inflight`], and per
//! connection at [`NetConfig::max_inflight_per_conn`] (default a
//! quarter of the global bound) so one greedy pipelining client
//! cannot hold every slot.  A request over the global bound is
//! answered with `ERR_OVERLOADED`; one over its connection's quota
//! (while the server as a whole still has room) with
//! `ERR_CONN_QUOTA` — both explicit sheds, counted per model, per
//! connection and globally, never a silent drop and never unbounded
//! queue growth.  Row accounting is released only after the response
//! bytes are handed to the kernel, so a slow client reading responses
//! lazily cannot park unbounded result data in the writer queue
//! either.
//!
//! # Deadlines (wire v2)
//!
//! A v2 `INFER` frame may carry a µs latency budget, measured from
//! frame arrival.  Admission sheds with `ERR_DEADLINE` when the
//! budget is already spent, or when the *remaining* budget is below
//! the model's observed p50 service time (a cheap, cached estimate —
//! refreshed at most every 50 ms from the inner server's latency
//! reservoir): work that would almost surely come back late is
//! answered immediately instead of clogging the queue for requests
//! that can still make it.  Shedding happens entirely at admission —
//! an *admitted* request is always answered exactly once, which keeps
//! the frontend's delivery contract trivial to state and to test;
//! the p50 estimate already includes router queueing, so admission
//! sees through to the whole service time.  Sheds are counted as
//! `deadline_sheds` per model and globally.
//!
//! # Graceful drain ([`NetServer::shutdown`])
//!
//! 1. stop accepting: the accept loop observes the stop flag and
//!    drops the listener — new connections are refused by the OS;
//! 2. reject new work: readers answer every further `INFER` frame
//!    with `ERR_SHUTTING_DOWN`;
//! 3. flush in-flight work: wait (bounded by
//!    [`NetConfig::drain_wait`]) until every admitted row's response
//!    has been written;
//! 4. close: force-shutdown all connection sockets (unblocking idle
//!    readers), join every connection thread, then stop the inner
//!    `InferenceServer` (which flushes its own final batches).
//!
//! Shutdown is idempotent and also runs on `Drop`.
//!
//! # Statistics over the wire
//!
//! A `STATS` frame is answered with a JSON document (schema below) —
//! the same numbers [`InferenceServer::model_stats`] reports
//! in-process, extended with frontend counters:
//!
//! ```json
//! {
//!   "models": [{"model": "nid", "n_in": 16, "out_width": 1,
//!               "backend": "plan-w1", "lane_width": 1,
//!               "requests": 0, "batches": 0, "mean_occupancy": 0.0,
//!               "max_batch_seen": 0,
//!               "latency_us": {"count": 0, "mean": 0.0, "p50": 0.0,
//!                              "p99": 0.0, "p999": 0.0},
//!               "net": {"requests": 0, "rows": 0, "shed": 0,
//!                       "deadline_sheds": 0, "quota_sheds": 0}}],
//!   "server": {"accepted_conns": 0, "open_conns": 0, "inflight": 0,
//!              "max_inflight": 1024, "max_inflight_per_conn": 256,
//!              "shed_total": 0, "deadline_sheds": 0, "quota_sheds": 0,
//!              "draining": false,
//!              "connections": [{"conn": 1, "inflight": 0,
//!                               "requests": 0, "quota_sheds": 0}],
//!              "plan_cache": {"compiles": 1, "memory_hits": 0,
//!                             "disk_hits": 0}}
//! }
//! ```

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{InferenceServer, Pending};
use crate::util::Json;

use super::fault::{FaultPlan, NetIo};
use super::wire::{self, Frame, Message, WireError};

/// How long a cached per-model p50 service-time estimate stays fresh
/// before an admission check refreshes it from the inner server's
/// latency reservoir (which sorts a sample buffer — too expensive per
/// request).
const P50_REFRESH_US: u64 = 50_000;

/// Frontend tuning knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Global bound on admitted in-flight rows (samples); requests
    /// past it are shed with `ERR_OVERLOADED`.  Also the largest
    /// admissible single request: a batch wider than the bound is
    /// always shed, even on an idle server.
    pub max_inflight: usize,
    /// Per-connection bound on admitted in-flight rows; requests past
    /// it are shed with `ERR_CONN_QUOTA` while other connections keep
    /// full service.  `None`: a quarter of `max_inflight` (min 1).
    /// `Some(usize::MAX)` effectively disables the quota.
    pub max_inflight_per_conn: Option<usize>,
    /// Writer-queue depth per connection (frames).  A full queue
    /// blocks the reader, which backpressures the TCP stream.
    pub writer_queue: usize,
    /// How long [`NetServer::shutdown`] waits for in-flight responses
    /// to flush before force-closing connections.
    pub drain_wait: Duration,
    /// Accept-loop poll interval (the listener is non-blocking so the
    /// stop flag is observed promptly).
    pub accept_poll: Duration,
    /// Fault-injection plan threaded into every connection's I/O
    /// (chaos tests only; `None` in production).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_inflight: 1024,
            max_inflight_per_conn: None,
            writer_queue: 256,
            drain_wait: Duration::from_secs(5),
            accept_poll: Duration::from_millis(2),
            fault: None,
        }
    }
}

impl NetConfig {
    /// The effective per-connection row quota.
    pub fn conn_quota(&self) -> usize {
        self.max_inflight_per_conn
            .unwrap_or(self.max_inflight / 4)
            .max(1)
    }
}

/// Per-model frontend counters (the batcher's own stats live in the
/// inner server).
#[derive(Default)]
struct NetCounters {
    requests: AtomicU64,
    rows: AtomicU64,
    shed: AtomicU64,
    deadline_shed: AtomicU64,
    quota_shed: AtomicU64,
}

struct ModelMeta {
    name: String,
    n_in: usize,
    out_width: usize,
    /// lane width the inner server's workers execute this model at
    /// (`plan-w{N}` in the STATS document)
    lane_width: usize,
    net: NetCounters,
    /// cached p50 service time in µs (f64 bits; 0.0 until measured) —
    /// the deadline-shedding estimate
    p50_bits: AtomicU64,
    /// µs-since-start stamp of the last p50 refresh (`u64::MAX`:
    /// never refreshed)
    p50_stamp_us: AtomicU64,
}

/// Per-connection admission state (lives in `Shared::conn_states` for
/// the whole connection lifetime; also feeds the STATS document).
struct ConnState {
    id: u64,
    /// rows this connection has admitted whose responses are not yet
    /// written (bounded by the per-connection quota)
    inflight: AtomicUsize,
    /// INFER requests admitted on this connection
    requests: AtomicU64,
    /// requests shed because this connection was over its quota
    quota_shed: AtomicU64,
}

struct Shared {
    server: InferenceServer,
    models: Vec<ModelMeta>,
    by_name: HashMap<String, usize>,
    cfg: NetConfig,
    /// resolved once from the config so every admission check agrees
    conn_quota: usize,
    /// epoch for the p50-cache stamps
    start: Instant,
    stop: AtomicBool,
    /// admitted rows whose responses are not yet written
    inflight: AtomicUsize,
    shed_total: AtomicU64,
    deadline_shed_total: AtomicU64,
    quota_shed_total: AtomicU64,
    accepted: AtomicU64,
    open: AtomicUsize,
    next_conn: AtomicU64,
    /// socket clones for force-close on drain, keyed by connection id
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// per-connection admission state, keyed by connection id
    conn_states: Mutex<HashMap<u64, Arc<ConnState>>>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Handle to a running TCP frontend.
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
    done: AtomicBool,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections for `server`'s hosted models.
    pub fn bind(server: InferenceServer, addr: impl ToSocketAddrs,
                cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let models: Vec<ModelMeta> = server
            .models()
            .into_iter()
            .map(|name| {
                let (n_in, out_width) = server
                    .model_io(&name)
                    .expect("hosted model has IO widths");
                let lane_width = server
                    .model_lane_width(&name)
                    .expect("hosted model has a lane width");
                ModelMeta { name, n_in, out_width, lane_width,
                            net: NetCounters::default(),
                            p50_bits: AtomicU64::new(0f64.to_bits()),
                            p50_stamp_us: AtomicU64::new(u64::MAX) }
            })
            .collect();
        let by_name = models
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name.clone(), i))
            .collect();
        let conn_quota = cfg.conn_quota();
        let shared = Arc::new(Shared {
            server,
            models,
            by_name,
            cfg,
            conn_quota,
            start: Instant::now(),
            stop: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            shed_total: AtomicU64::new(0),
            deadline_shed_total: AtomicU64::new(0),
            quota_shed_total: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            open: AtomicUsize::new(0),
            next_conn: AtomicU64::new(1),
            conns: Mutex::new(HashMap::new()),
            conn_states: Mutex::new(HashMap::new()),
            threads: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("nla-net-accept".into())
                .spawn(move || accept_loop(&listener, &shared))
                .expect("spawn accept thread")
        };
        log::info!("net frontend listening on {addr} ({} models, \
                    max_inflight {}, per-conn quota {})",
                   shared.models.len(), shared.cfg.max_inflight,
                   shared.conn_quota);
        Ok(NetServer {
            shared,
            addr,
            accept: Mutex::new(Some(accept)),
            done: AtomicBool::new(false),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The wrapped inference server (e.g. for in-process stats).
    pub fn inner(&self) -> &InferenceServer {
        &self.shared.server
    }

    /// Currently admitted in-flight rows.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Requests shed by admission control (global bound) since start.
    pub fn shed_total(&self) -> u64 {
        self.shared.shed_total.load(Ordering::SeqCst)
    }

    /// Requests shed because their deadline budget could not be met.
    pub fn deadline_sheds_total(&self) -> u64 {
        self.shared.deadline_shed_total.load(Ordering::SeqCst)
    }

    /// Requests shed by per-connection quotas.
    pub fn quota_sheds_total(&self) -> u64 {
        self.shared.quota_shed_total.load(Ordering::SeqCst)
    }

    /// Connections accepted since start.
    pub fn accepted_conns(&self) -> u64 {
        self.shared.accepted.load(Ordering::SeqCst)
    }

    /// Connections currently open.
    pub fn open_conns(&self) -> usize {
        self.shared.open.load(Ordering::SeqCst)
    }

    /// Graceful drain (see the module doc for the four phases).
    /// Idempotent; also runs on `Drop`.
    pub fn shutdown(&self) {
        if self.done.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        // 1: the accept loop polls the flag; joining it guarantees the
        // listener is dropped and new connections are refused
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
        // 2 runs in the readers (stop flag); 3: wait for in-flight
        // responses to flush.  Zero must hold across a settle window:
        // a reader that loaded the stop flag as false may still be a
        // few instructions from admitting, and force-closing under it
        // would lose that request's answer.  Every sleep is clamped to
        // the time left, so `drain_wait` bounds phase 3 exactly — a
        // streak reset just before the deadline cannot ride past it.
        let deadline = Instant::now() + self.shared.cfg.drain_wait;
        let mut zero_streak = 0;
        while zero_streak < 3 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let left = deadline - now;
            if self.shared.inflight.load(Ordering::SeqCst) == 0 {
                zero_streak += 1;
                std::thread::sleep(left.min(Duration::from_millis(5)));
            } else {
                zero_streak = 0;
                std::thread::sleep(left.min(Duration::from_millis(1)));
            }
        }
        // 4: force-close every connection socket (unblocks idle
        // readers) and join the connection threads
        {
            let mut conns = self.shared.conns.lock().unwrap();
            for (_, s) in conns.drain() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        let handles =
            std::mem::take(&mut *self.shared.threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // finally stop the batcher itself (flushes its own tail)
        self.shared.server.shutdown();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.accepted.fetch_add(1, Ordering::SeqCst);
                if let Err(e) = spawn_connection(shared, stream) {
                    log::warn!("net: connection setup failed: {e:#}");
                }
                // opportunistic tidy-up so a long-lived server does
                // not accumulate finished join handles
                shared
                    .threads
                    .lock()
                    .unwrap()
                    .retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(shared.cfg.accept_poll);
            }
            Err(e) => {
                log::warn!("net: accept failed: {e}");
                std::thread::sleep(shared.cfg.accept_poll);
            }
        }
    }
    // listener drops here: further connects are refused by the OS
}

/// Frames queued from a connection's reader to its writer.
enum Out {
    /// Already-encoded response bytes (errors, pongs, stats).
    Ready(Vec<u8>),
    /// An admitted inference: the writer waits for the answers, then
    /// encodes the result frame and releases the admission rows.
    Infer { id: u64, model: usize, batch: usize, pending: Vec<Pending> },
}

fn spawn_connection(shared: &Arc<Shared>, stream: TcpStream)
                    -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    let conn_id = shared.next_conn.fetch_add(1, Ordering::SeqCst);
    // a clone for the force-close registry and one for the writer
    let force = stream.try_clone()?;
    let wstream = stream.try_clone()?;
    shared.conns.lock().unwrap().insert(conn_id, force);
    let conn = Arc::new(ConnState {
        id: conn_id,
        inflight: AtomicUsize::new(0),
        requests: AtomicU64::new(0),
        quota_shed: AtomicU64::new(0),
    });
    shared.conn_states.lock().unwrap().insert(conn_id, conn.clone());
    shared.open.fetch_add(1, Ordering::SeqCst);
    let rio = NetIo::wrap(stream, shared.cfg.fault.as_ref());
    let wio = NetIo::wrap(wstream, shared.cfg.fault.as_ref());
    let (tx, rx) = sync_channel::<Out>(shared.cfg.writer_queue.max(1));
    let reader = {
        let shared = shared.clone();
        let conn = conn.clone();
        std::thread::Builder::new()
            .name(format!("nla-net-read-{conn_id}"))
            .spawn(move || reader_loop(&shared, rio, &conn, &tx))
            .expect("spawn reader")
    };
    let writer = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("nla-net-write-{conn_id}"))
            .spawn(move || writer_loop(&shared, wio, &rx, &conn))
            .expect("spawn writer")
    };
    let mut threads = shared.threads.lock().unwrap();
    threads.push(reader);
    threads.push(writer);
    Ok(())
}

fn error_frame(id: u64, code: u16, message: String) -> Vec<u8> {
    wire::encode_frame(id, &Message::Error { code, message })
}

fn reader_loop(shared: &Arc<Shared>, mut io: NetIo, conn: &Arc<ConnState>,
               tx: &SyncSender<Out>) {
    loop {
        match wire::read_frame(&mut io) {
            Ok(frame) => {
                // deadline budgets are measured from frame arrival
                let arrived = Instant::now();
                if !handle_frame(shared, frame, arrived, conn, tx) {
                    break;
                }
            }
            Err(e) if e.is_fatal() => {
                // framing sync is lost: answer best-effort (not on
                // plain transport errors — the peer is gone), close.
                // The id of an undecodable frame cannot be trusted, so
                // the final error carries id 0.
                if !matches!(e, WireError::Io(_)) {
                    let _ = tx.try_send(Out::Ready(error_frame(
                        0, wire::ERR_BAD_FRAME, e.to_string())));
                }
                break;
            }
            Err(e) => {
                // recoverable: the whole frame was consumed, so answer
                // with a typed error and keep the connection open
                if tx.send(Out::Ready(error_frame(
                        0, wire::ERR_BAD_FRAME, e.to_string())))
                    .is_err()
                {
                    break;
                }
            }
        }
    }
    // tx drops here; the writer drains the queue and cleans up
}

/// Process one decoded frame.  Returns false when the connection
/// should close (writer gone).
fn handle_frame(shared: &Arc<Shared>, frame: Frame, arrived: Instant,
                conn: &Arc<ConnState>, tx: &SyncSender<Out>) -> bool {
    let id = frame.id;
    let out = match frame.msg {
        Message::Ping => {
            Out::Ready(wire::encode_frame(id, &Message::Pong))
        }
        Message::Stats { model } => match stats_json(shared, &model) {
            Ok(json) => Out::Ready(wire::encode_frame(
                id, &Message::StatsResult { json })),
            Err((code, msg)) => Out::Ready(error_frame(id, code, msg)),
        },
        Message::Infer { model, batch, n_in, deadline_us, codes } => {
            let req = InferReq { id, model, batch, n_in, deadline_us,
                                 codes, arrived };
            admit_infer(shared, conn, req)
        }
        // a client must not send response kinds; answer (don't abort —
        // framing is intact) and continue
        Message::Result { .. } | Message::StatsResult { .. }
        | Message::Error { .. } | Message::Pong => {
            Out::Ready(error_frame(
                id, wire::ERR_BAD_FRAME,
                "unexpected response-kind frame".into()))
        }
    };
    tx.send(out).is_ok()
}

/// One decoded INFER request on its way into admission.
struct InferReq {
    id: u64,
    model: String,
    batch: u32,
    n_in: u32,
    deadline_us: Option<u64>,
    codes: Vec<i32>,
    arrived: Instant,
}

/// The model's p50 service time in µs (0.0 until measured), from a
/// per-model cache refreshed at most every [`P50_REFRESH_US`] —
/// `InferenceServer::model_stats` sorts a latency reservoir, far too
/// expensive per admission check.  One thread wins the refresh CAS;
/// the rest read the (possibly one-interval-stale) cached value.
fn model_p50_us(shared: &Arc<Shared>, idx: usize) -> f64 {
    let meta = &shared.models[idx];
    let now = shared.start.elapsed().as_micros() as u64;
    let stamp = meta.p50_stamp_us.load(Ordering::SeqCst);
    let stale = stamp == u64::MAX
        || now.saturating_sub(stamp) >= P50_REFRESH_US;
    if stale
        && meta
            .p50_stamp_us
            .compare_exchange(stamp, now, Ordering::SeqCst,
                              Ordering::SeqCst)
            .is_ok()
    {
        if let Ok(st) = shared.server.model_stats(&meta.name) {
            meta.p50_bits
                .store(st.latency.p50.to_bits(), Ordering::SeqCst);
        }
    }
    f64::from_bits(meta.p50_bits.load(Ordering::SeqCst))
}

/// Validate, admit (or shed) and submit one inference request;
/// returns what the writer should send.
fn admit_infer(shared: &Arc<Shared>, conn: &Arc<ConnState>, req: InferReq)
               -> Out {
    let InferReq { id, model, batch, n_in, deadline_us, codes, arrived } =
        req;
    if shared.stop.load(Ordering::SeqCst) {
        return Out::Ready(error_frame(
            id, wire::ERR_SHUTTING_DOWN,
            "server is draining; no new work accepted".into()));
    }
    let Some(&idx) = shared.by_name.get(&model) else {
        return Out::Ready(error_frame(
            id, wire::ERR_UNKNOWN_MODEL,
            format!("no model named '{model}' is hosted")));
    };
    let meta = &shared.models[idx];
    let batch = batch as usize;
    if batch == 0 {
        return Out::Ready(error_frame(
            id, wire::ERR_BAD_INPUT, "batch must be at least 1".into()));
    }
    if n_in as usize != meta.n_in {
        return Out::Ready(error_frame(
            id, wire::ERR_BAD_INPUT,
            format!("model '{model}' expects n_in {}, request declares \
                     {n_in}", meta.n_in)));
    }
    debug_assert_eq!(codes.len(), batch * meta.n_in,
                     "wire decode guarantees the code count");
    // deadline shedding: answer now if the budget is spent, or if the
    // remaining budget is below the model's observed p50 service time
    // (then the answer would almost surely come back late — shed it
    // before it consumes an admission slot)
    if let Some(budget) = deadline_us {
        let elapsed = arrived.elapsed().as_micros() as u64;
        let remaining = budget.saturating_sub(elapsed);
        let p50 = if remaining > 0 {
            model_p50_us(shared, idx)
        } else {
            0.0
        };
        if remaining == 0 || (p50 > 0.0 && (remaining as f64) < p50) {
            meta.net.deadline_shed.fetch_add(1, Ordering::SeqCst);
            shared.deadline_shed_total.fetch_add(1, Ordering::SeqCst);
            let why = if remaining == 0 {
                format!("budget {budget} µs already spent at admission")
            } else {
                format!("remaining budget {remaining} µs is below the \
                         model's observed p50 service time {p50:.0} µs")
            };
            return Out::Ready(error_frame(id, wire::ERR_DEADLINE, why));
        }
    }
    // admission level 1: this connection's quota
    let mut cur = conn.inflight.load(Ordering::SeqCst);
    loop {
        if cur.saturating_add(batch) > shared.conn_quota {
            meta.net.quota_shed.fetch_add(1, Ordering::SeqCst);
            conn.quota_shed.fetch_add(1, Ordering::SeqCst);
            shared.quota_shed_total.fetch_add(1, Ordering::SeqCst);
            return Out::Ready(error_frame(
                id, wire::ERR_CONN_QUOTA,
                format!("connection quota exceeded ({cur} of {} rows \
                         in flight on this connection)",
                        shared.conn_quota)));
        }
        match conn.inflight.compare_exchange(
            cur, cur + batch, Ordering::SeqCst, Ordering::SeqCst)
        {
            Ok(_) => break,
            Err(now) => cur = now,
        }
    }
    // admission level 2: the global bound — on shed, hand back the
    // per-connection reservation too
    let mut cur = shared.inflight.load(Ordering::SeqCst);
    loop {
        if cur.saturating_add(batch) > shared.cfg.max_inflight {
            conn.inflight.fetch_sub(batch, Ordering::SeqCst);
            meta.net.shed.fetch_add(1, Ordering::SeqCst);
            shared.shed_total.fetch_add(1, Ordering::SeqCst);
            return Out::Ready(error_frame(
                id, wire::ERR_OVERLOADED,
                format!("admission queue full ({} of {} rows in \
                         flight)", cur, shared.cfg.max_inflight)));
        }
        match shared.inflight.compare_exchange(
            cur, cur + batch, Ordering::SeqCst, Ordering::SeqCst)
        {
            Ok(_) => break,
            Err(now) => cur = now,
        }
    }
    // submit row by row: the router re-batches per model across every
    // connection, so a k-row request and k single-row requests take
    // the same path
    let mut pending = Vec::with_capacity(batch);
    for b in 0..batch {
        let row = codes[b * meta.n_in..(b + 1) * meta.n_in].to_vec();
        match shared.server.submit(&meta.name, row) {
            Ok(p) => pending.push(p),
            Err(e) => {
                // inner server stopped under us: release the rows and
                // answer with a value, as always
                shared.inflight.fetch_sub(batch, Ordering::SeqCst);
                conn.inflight.fetch_sub(batch, Ordering::SeqCst);
                return Out::Ready(error_frame(
                    id, wire::ERR_SHUTTING_DOWN, format!("{e:#}")));
            }
        }
    }
    conn.requests.fetch_add(1, Ordering::SeqCst);
    meta.net.requests.fetch_add(1, Ordering::SeqCst);
    meta.net.rows.fetch_add(batch as u64, Ordering::SeqCst);
    Out::Infer { id, model: idx, batch, pending }
}

fn writer_loop(shared: &Arc<Shared>, mut io: NetIo, rx: &Receiver<Out>,
               conn: &Arc<ConnState>) {
    // once the socket dies we keep draining the queue so admission
    // rows are always released, but stop writing
    let mut dead = false;
    while let Ok(out) = rx.recv() {
        match out {
            Out::Ready(bytes) => {
                if !dead && io.write_all(&bytes).is_err() {
                    dead = true;
                }
            }
            Out::Infer { id, model, batch, pending } => {
                if dead {
                    // abandon the answers (workers' sends fail
                    // harmlessly) but release the admission rows
                    drop(pending);
                    shared.inflight.fetch_sub(batch, Ordering::SeqCst);
                    conn.inflight.fetch_sub(batch, Ordering::SeqCst);
                    continue;
                }
                let ow = shared.models[model].out_width;
                let mut codes: Vec<i32> = Vec::with_capacity(batch * ow);
                let mut stopped = false;
                for p in pending {
                    match p.wait() {
                        Ok(mut y) => codes.append(&mut y),
                        Err(_) => {
                            stopped = true;
                            break;
                        }
                    }
                }
                let msg = if stopped {
                    Message::Error {
                        code: wire::ERR_SHUTTING_DOWN,
                        message: "server stopped before the request \
                                  completed".into(),
                    }
                } else {
                    Message::Result {
                        batch: batch as u32,
                        out_width: ow as u32,
                        codes,
                    }
                };
                if io.write_all(&wire::encode_frame(id, &msg)).is_err() {
                    dead = true;
                }
                // release only after the response bytes are out (or
                // the socket is known dead): "in flight" means "the
                // answer has not reached the kernel yet"
                shared.inflight.fetch_sub(batch, Ordering::SeqCst);
                conn.inflight.fetch_sub(batch, Ordering::SeqCst);
            }
        }
    }
    io.shutdown();
    shared.conns.lock().unwrap().remove(&conn.id);
    shared.conn_states.lock().unwrap().remove(&conn.id);
    shared.open.fetch_sub(1, Ordering::SeqCst);
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// Build the stats JSON document (`model` empty: every hosted model).
fn stats_json(shared: &Arc<Shared>, model: &str)
              -> Result<String, (u16, String)> {
    use std::collections::BTreeMap;
    let indices: Vec<usize> = if model.is_empty() {
        (0..shared.models.len()).collect()
    } else {
        match shared.by_name.get(model) {
            Some(&i) => vec![i],
            None => {
                return Err((wire::ERR_UNKNOWN_MODEL, format!(
                    "no model named '{model}' is hosted")));
            }
        }
    };
    let mut models = Vec::new();
    for i in indices {
        let meta = &shared.models[i];
        let st = shared
            .server
            .model_stats(&meta.name)
            .map_err(|e| (wire::ERR_INTERNAL, format!("{e:#}")))?;
        let mut lat = BTreeMap::new();
        lat.insert("count".into(), num(st.latency.count as f64));
        lat.insert("mean".into(), num(st.latency.mean));
        lat.insert("p50".into(), num(st.latency.p50));
        lat.insert("p99".into(), num(st.latency.p99));
        lat.insert("p999".into(), num(st.latency.p999));
        let mut net = BTreeMap::new();
        net.insert("requests".into(),
                   num(meta.net.requests.load(Ordering::SeqCst) as f64));
        net.insert("rows".into(),
                   num(meta.net.rows.load(Ordering::SeqCst) as f64));
        net.insert("shed".into(),
                   num(meta.net.shed.load(Ordering::SeqCst) as f64));
        net.insert("deadline_sheds".into(),
                   num(meta.net.deadline_shed.load(Ordering::SeqCst)
                       as f64));
        net.insert("quota_sheds".into(),
                   num(meta.net.quota_shed.load(Ordering::SeqCst)
                       as f64));
        let mut m = BTreeMap::new();
        m.insert("model".into(), Json::Str(meta.name.clone()));
        m.insert("n_in".into(), num(meta.n_in as f64));
        m.insert("out_width".into(), num(meta.out_width as f64));
        m.insert("backend".into(),
                 Json::Str(format!("plan-w{}", meta.lane_width)));
        m.insert("lane_width".into(), num(meta.lane_width as f64));
        m.insert("requests".into(), num(st.requests as f64));
        m.insert("batches".into(), num(st.batches as f64));
        m.insert("mean_occupancy".into(), num(st.mean_occupancy));
        m.insert("max_batch_seen".into(), num(st.max_batch_seen as f64));
        m.insert("latency_us".into(), Json::Obj(lat));
        m.insert("net".into(), Json::Obj(net));
        models.push(Json::Obj(m));
    }
    let mut srv = BTreeMap::new();
    srv.insert("accepted_conns".into(),
               num(shared.accepted.load(Ordering::SeqCst) as f64));
    srv.insert("open_conns".into(),
               num(shared.open.load(Ordering::SeqCst) as f64));
    srv.insert("inflight".into(),
               num(shared.inflight.load(Ordering::SeqCst) as f64));
    srv.insert("max_inflight".into(),
               num(shared.cfg.max_inflight as f64));
    srv.insert("max_inflight_per_conn".into(),
               num(shared.conn_quota as f64));
    srv.insert("shed_total".into(),
               num(shared.shed_total.load(Ordering::SeqCst) as f64));
    srv.insert("deadline_sheds".into(),
               num(shared.deadline_shed_total.load(Ordering::SeqCst)
                   as f64));
    srv.insert("quota_sheds".into(),
               num(shared.quota_shed_total.load(Ordering::SeqCst)
                   as f64));
    srv.insert("draining".into(),
               Json::Bool(shared.stop.load(Ordering::SeqCst)));
    // live per-connection admission state, sorted by connection id —
    // which connections hold slots and which are being throttled
    let mut conn_list: Vec<Arc<ConnState>> = shared
        .conn_states
        .lock()
        .unwrap()
        .values()
        .cloned()
        .collect();
    conn_list.sort_by_key(|c| c.id);
    let conns_json = conn_list
        .into_iter()
        .map(|c| {
            let mut o = BTreeMap::new();
            o.insert("conn".into(), num(c.id as f64));
            o.insert("inflight".into(),
                     num(c.inflight.load(Ordering::SeqCst) as f64));
            o.insert("requests".into(),
                     num(c.requests.load(Ordering::SeqCst) as f64));
            o.insert("quota_sheds".into(),
                     num(c.quota_shed.load(Ordering::SeqCst) as f64));
            Json::Obj(o)
        })
        .collect();
    srv.insert("connections".into(), Json::Arr(conns_json));
    // plan-cache telemetry (stable keys, asserted in tests/net.rs):
    // how the hosted plans came to exist — compiled here, shared from
    // an identical registration, or cold-loaded from the persistent
    // cache (zero-copy mapped unless --no-mmap / fallback)
    let (compiles, memory_hits) = shared.server.plan_cache_counts();
    let mut pc = BTreeMap::new();
    pc.insert("compiles".into(), num(compiles as f64));
    pc.insert("memory_hits".into(), num(memory_hits as f64));
    pc.insert("disk_hits".into(),
              num(shared.server.plan_cache_disk_hits() as f64));
    srv.insert("plan_cache".into(), Json::Obj(pc));
    let mut root = BTreeMap::new();
    root.insert("models".into(), Json::Arr(models));
    root.insert("server".into(), Json::Obj(srv));
    Ok(Json::Obj(root).to_string())
}
