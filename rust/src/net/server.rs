//! TCP serving frontend: the socket between [`InferenceServer`]'s
//! router/batcher and the outside world.
//!
//! One [`NetServer`] owns one `InferenceServer` and a listening
//! socket.  Each accepted connection gets a **reader** thread (decode
//! frames, admit or shed, submit to the batching router) and a
//! **writer** thread (wait for answers, encode responses) joined by a
//! bounded queue — so a client may pipeline arbitrarily many requests
//! on one connection and the batcher sees them all concurrently, while
//! responses stay in request order per connection (ids are still
//! echoed, so clients need not rely on ordering).
//!
//! # Admission control
//!
//! The frontend bounds *admitted rows* (samples submitted to the
//! router whose responses have not yet been written) at
//! [`NetConfig::max_inflight`].  A request that would exceed the bound
//! is answered immediately with an `ERR_OVERLOADED` error frame — an
//! explicit shed, counted per model and globally, never a silent drop
//! and never unbounded queue growth.  Row accounting is released only
//! after the response bytes are handed to the kernel, so a slow
//! client reading responses lazily cannot park unbounded result data
//! in the writer queue either.
//!
//! # Graceful drain ([`NetServer::shutdown`])
//!
//! 1. stop accepting: the accept loop observes the stop flag and
//!    drops the listener — new connections are refused by the OS;
//! 2. reject new work: readers answer every further `INFER` frame
//!    with `ERR_SHUTTING_DOWN`;
//! 3. flush in-flight work: wait (bounded by
//!    [`NetConfig::drain_wait`]) until every admitted row's response
//!    has been written;
//! 4. close: force-shutdown all connection sockets (unblocking idle
//!    readers), join every connection thread, then stop the inner
//!    `InferenceServer` (which flushes its own final batches).
//!
//! Shutdown is idempotent and also runs on `Drop`.
//!
//! # Statistics over the wire
//!
//! A `STATS` frame is answered with a JSON document (schema below) —
//! the same numbers [`InferenceServer::model_stats`] reports
//! in-process, extended with frontend counters:
//!
//! ```json
//! {
//!   "models": [{"model": "nid", "n_in": 16, "out_width": 1,
//!               "backend": "plan-w1", "lane_width": 1,
//!               "requests": 0, "batches": 0, "mean_occupancy": 0.0,
//!               "max_batch_seen": 0,
//!               "latency_us": {"count": 0, "mean": 0.0, "p50": 0.0,
//!                              "p99": 0.0, "p999": 0.0},
//!               "net": {"requests": 0, "rows": 0, "shed": 0}}],
//!   "server": {"accepted_conns": 0, "open_conns": 0, "inflight": 0,
//!              "max_inflight": 1024, "shed_total": 0,
//!              "draining": false,
//!              "plan_cache": {"compiles": 1, "memory_hits": 0,
//!                             "disk_hits": 0}}
//! }
//! ```

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{InferenceServer, Pending};
use crate::util::Json;

use super::wire::{self, Frame, Message, WireError};

/// Frontend tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Bound on admitted in-flight rows (samples); requests past it
    /// are shed with `ERR_OVERLOADED`.  Also the largest admissible
    /// single request: a batch wider than the bound is always shed,
    /// even on an idle server.
    pub max_inflight: usize,
    /// Writer-queue depth per connection (frames).  A full queue
    /// blocks the reader, which backpressures the TCP stream.
    pub writer_queue: usize,
    /// How long [`NetServer::shutdown`] waits for in-flight responses
    /// to flush before force-closing connections.
    pub drain_wait: Duration,
    /// Accept-loop poll interval (the listener is non-blocking so the
    /// stop flag is observed promptly).
    pub accept_poll: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_inflight: 1024,
            writer_queue: 256,
            drain_wait: Duration::from_secs(5),
            accept_poll: Duration::from_millis(2),
        }
    }
}

/// Per-model frontend counters (the batcher's own stats live in the
/// inner server).
#[derive(Default)]
struct NetCounters {
    requests: AtomicU64,
    rows: AtomicU64,
    shed: AtomicU64,
}

struct ModelMeta {
    name: String,
    n_in: usize,
    out_width: usize,
    /// lane width the inner server's workers execute this model at
    /// (`plan-w{N}` in the STATS document)
    lane_width: usize,
    net: NetCounters,
}

struct Shared {
    server: InferenceServer,
    models: Vec<ModelMeta>,
    by_name: HashMap<String, usize>,
    cfg: NetConfig,
    stop: AtomicBool,
    /// admitted rows whose responses are not yet written
    inflight: AtomicUsize,
    shed_total: AtomicU64,
    accepted: AtomicU64,
    open: AtomicUsize,
    next_conn: AtomicU64,
    /// socket clones for force-close on drain, keyed by connection id
    conns: Mutex<HashMap<u64, TcpStream>>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Handle to a running TCP frontend.
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
    done: AtomicBool,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections for `server`'s hosted models.
    pub fn bind(server: InferenceServer, addr: impl ToSocketAddrs,
                cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let models: Vec<ModelMeta> = server
            .models()
            .into_iter()
            .map(|name| {
                let (n_in, out_width) = server
                    .model_io(&name)
                    .expect("hosted model has IO widths");
                let lane_width = server
                    .model_lane_width(&name)
                    .expect("hosted model has a lane width");
                ModelMeta { name, n_in, out_width, lane_width,
                            net: NetCounters::default() }
            })
            .collect();
        let by_name = models
            .iter()
            .enumerate()
            .map(|(i, m)| (m.name.clone(), i))
            .collect();
        let shared = Arc::new(Shared {
            server,
            models,
            by_name,
            cfg,
            stop: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            shed_total: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            open: AtomicUsize::new(0),
            next_conn: AtomicU64::new(1),
            conns: Mutex::new(HashMap::new()),
            threads: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("nla-net-accept".into())
                .spawn(move || accept_loop(&listener, &shared))
                .expect("spawn accept thread")
        };
        log::info!("net frontend listening on {addr} ({} models, \
                    max_inflight {})",
                   shared.models.len(), cfg.max_inflight);
        Ok(NetServer {
            shared,
            addr,
            accept: Mutex::new(Some(accept)),
            done: AtomicBool::new(false),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The wrapped inference server (e.g. for in-process stats).
    pub fn inner(&self) -> &InferenceServer {
        &self.shared.server
    }

    /// Currently admitted in-flight rows.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Requests shed by admission control since start.
    pub fn shed_total(&self) -> u64 {
        self.shared.shed_total.load(Ordering::SeqCst)
    }

    /// Connections accepted since start.
    pub fn accepted_conns(&self) -> u64 {
        self.shared.accepted.load(Ordering::SeqCst)
    }

    /// Connections currently open.
    pub fn open_conns(&self) -> usize {
        self.shared.open.load(Ordering::SeqCst)
    }

    /// Graceful drain (see the module doc for the four phases).
    /// Idempotent; also runs on `Drop`.
    pub fn shutdown(&self) {
        if self.done.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        // 1: the accept loop polls the flag; joining it guarantees the
        // listener is dropped and new connections are refused
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
        // 2 runs in the readers (stop flag); 3: wait for in-flight
        // responses to flush.  Zero must hold across a settle window:
        // a reader that loaded the stop flag as false may still be a
        // few instructions from admitting, and force-closing under it
        // would lose that request's answer.
        let deadline = Instant::now() + self.shared.cfg.drain_wait;
        let mut zero_streak = 0;
        while zero_streak < 3 && Instant::now() < deadline {
            if self.shared.inflight.load(Ordering::SeqCst) == 0 {
                zero_streak += 1;
                std::thread::sleep(Duration::from_millis(5));
            } else {
                zero_streak = 0;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // 4: force-close every connection socket (unblocks idle
        // readers) and join the connection threads
        {
            let mut conns = self.shared.conns.lock().unwrap();
            for (_, s) in conns.drain() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        let handles =
            std::mem::take(&mut *self.shared.threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // finally stop the batcher itself (flushes its own tail)
        self.shared.server.shutdown();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.accepted.fetch_add(1, Ordering::SeqCst);
                if let Err(e) = spawn_connection(shared, stream) {
                    log::warn!("net: connection setup failed: {e:#}");
                }
                // opportunistic tidy-up so a long-lived server does
                // not accumulate finished join handles
                shared
                    .threads
                    .lock()
                    .unwrap()
                    .retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(shared.cfg.accept_poll);
            }
            Err(e) => {
                log::warn!("net: accept failed: {e}");
                std::thread::sleep(shared.cfg.accept_poll);
            }
        }
    }
    // listener drops here: further connects are refused by the OS
}

/// Frames queued from a connection's reader to its writer.
enum Out {
    /// Already-encoded response bytes (errors, pongs, stats).
    Ready(Vec<u8>),
    /// An admitted inference: the writer waits for the answers, then
    /// encodes the result frame and releases the admission rows.
    Infer { id: u64, model: usize, batch: usize, pending: Vec<Pending> },
}

fn spawn_connection(shared: &Arc<Shared>, stream: TcpStream)
                    -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    let conn_id = shared.next_conn.fetch_add(1, Ordering::SeqCst);
    // a clone for the force-close registry and one for the writer
    let force = stream.try_clone()?;
    let wstream = stream.try_clone()?;
    shared.conns.lock().unwrap().insert(conn_id, force);
    shared.open.fetch_add(1, Ordering::SeqCst);
    let (tx, rx) = sync_channel::<Out>(shared.cfg.writer_queue.max(1));
    let reader = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("nla-net-read-{conn_id}"))
            .spawn(move || reader_loop(&shared, stream, &tx))
            .expect("spawn reader")
    };
    let writer = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name(format!("nla-net-write-{conn_id}"))
            .spawn(move || writer_loop(&shared, wstream, &rx, conn_id))
            .expect("spawn writer")
    };
    let mut threads = shared.threads.lock().unwrap();
    threads.push(reader);
    threads.push(writer);
    Ok(())
}

fn error_frame(id: u64, code: u16, message: String) -> Vec<u8> {
    wire::encode_frame(id, &Message::Error { code, message })
}

fn reader_loop(shared: &Arc<Shared>, mut stream: TcpStream,
               tx: &SyncSender<Out>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(frame) => {
                if !handle_frame(shared, frame, tx) {
                    break;
                }
            }
            Err(e) if e.is_fatal() => {
                // framing sync is lost: answer best-effort (not on
                // plain transport errors — the peer is gone), close.
                // The id of an undecodable frame cannot be trusted, so
                // the final error carries id 0.
                if !matches!(e, WireError::Io(_)) {
                    let _ = tx.try_send(Out::Ready(error_frame(
                        0, wire::ERR_BAD_FRAME, e.to_string())));
                }
                break;
            }
            Err(e) => {
                // recoverable: the whole frame was consumed, so answer
                // with a typed error and keep the connection open
                if tx.send(Out::Ready(error_frame(
                        0, wire::ERR_BAD_FRAME, e.to_string())))
                    .is_err()
                {
                    break;
                }
            }
        }
    }
    // tx drops here; the writer drains the queue and cleans up
}

/// Process one decoded frame.  Returns false when the connection
/// should close (writer gone).
fn handle_frame(shared: &Arc<Shared>, frame: Frame, tx: &SyncSender<Out>)
                -> bool {
    let id = frame.id;
    let out = match frame.msg {
        Message::Ping => {
            Out::Ready(wire::encode_frame(id, &Message::Pong))
        }
        Message::Stats { model } => match stats_json(shared, &model) {
            Ok(json) => Out::Ready(wire::encode_frame(
                id, &Message::StatsResult { json })),
            Err((code, msg)) => Out::Ready(error_frame(id, code, msg)),
        },
        Message::Infer { model, batch, n_in, codes } => {
            admit_infer(shared, id, &model, batch, n_in, codes)
        }
        // a client must not send response kinds; answer (don't abort —
        // framing is intact) and continue
        Message::Result { .. } | Message::StatsResult { .. }
        | Message::Error { .. } | Message::Pong => {
            Out::Ready(error_frame(
                id, wire::ERR_BAD_FRAME,
                "unexpected response-kind frame".into()))
        }
    };
    tx.send(out).is_ok()
}

/// Validate, admit (or shed) and submit one inference request;
/// returns what the writer should send.
fn admit_infer(shared: &Arc<Shared>, id: u64, model: &str, batch: u32,
               n_in: u32, codes: Vec<i32>) -> Out {
    if shared.stop.load(Ordering::SeqCst) {
        return Out::Ready(error_frame(
            id, wire::ERR_SHUTTING_DOWN,
            "server is draining; no new work accepted".into()));
    }
    let Some(&idx) = shared.by_name.get(model) else {
        return Out::Ready(error_frame(
            id, wire::ERR_UNKNOWN_MODEL,
            format!("no model named '{model}' is hosted")));
    };
    let meta = &shared.models[idx];
    let batch = batch as usize;
    if batch == 0 {
        return Out::Ready(error_frame(
            id, wire::ERR_BAD_INPUT, "batch must be at least 1".into()));
    }
    if n_in as usize != meta.n_in {
        return Out::Ready(error_frame(
            id, wire::ERR_BAD_INPUT,
            format!("model '{model}' expects n_in {}, request declares \
                     {n_in}", meta.n_in)));
    }
    debug_assert_eq!(codes.len(), batch * meta.n_in,
                     "wire decode guarantees the code count");
    // admission: reserve `batch` rows or shed explicitly
    let mut cur = shared.inflight.load(Ordering::SeqCst);
    loop {
        if cur + batch > shared.cfg.max_inflight {
            meta.net.shed.fetch_add(1, Ordering::SeqCst);
            shared.shed_total.fetch_add(1, Ordering::SeqCst);
            return Out::Ready(error_frame(
                id, wire::ERR_OVERLOADED,
                format!("admission queue full ({} of {} rows in \
                         flight)", cur, shared.cfg.max_inflight)));
        }
        match shared.inflight.compare_exchange(
            cur, cur + batch, Ordering::SeqCst, Ordering::SeqCst)
        {
            Ok(_) => break,
            Err(now) => cur = now,
        }
    }
    // submit row by row: the router re-batches per model across every
    // connection, so a k-row request and k single-row requests take
    // the same path
    let mut pending = Vec::with_capacity(batch);
    for b in 0..batch {
        let row = codes[b * meta.n_in..(b + 1) * meta.n_in].to_vec();
        match shared.server.submit(&meta.name, row) {
            Ok(p) => pending.push(p),
            Err(e) => {
                // inner server stopped under us: release the rows and
                // answer with a value, as always
                shared.inflight.fetch_sub(batch, Ordering::SeqCst);
                return Out::Ready(error_frame(
                    id, wire::ERR_SHUTTING_DOWN, format!("{e:#}")));
            }
        }
    }
    meta.net.requests.fetch_add(1, Ordering::SeqCst);
    meta.net.rows.fetch_add(batch as u64, Ordering::SeqCst);
    Out::Infer { id, model: idx, batch, pending }
}

fn writer_loop(shared: &Arc<Shared>, mut stream: TcpStream,
               rx: &Receiver<Out>, conn_id: u64) {
    // once the socket dies we keep draining the queue so admission
    // rows are always released, but stop writing
    let mut dead = false;
    while let Ok(out) = rx.recv() {
        match out {
            Out::Ready(bytes) => {
                if !dead && stream.write_all(&bytes).is_err() {
                    dead = true;
                }
            }
            Out::Infer { id, model, batch, pending } => {
                if dead {
                    // abandon the answers (workers' sends fail
                    // harmlessly) but release the admission rows
                    drop(pending);
                    shared.inflight.fetch_sub(batch, Ordering::SeqCst);
                    continue;
                }
                let ow = shared.models[model].out_width;
                let mut codes: Vec<i32> = Vec::with_capacity(batch * ow);
                let mut stopped = false;
                for p in pending {
                    match p.wait() {
                        Ok(mut y) => codes.append(&mut y),
                        Err(_) => {
                            stopped = true;
                            break;
                        }
                    }
                }
                let msg = if stopped {
                    Message::Error {
                        code: wire::ERR_SHUTTING_DOWN,
                        message: "server stopped before the request \
                                  completed".into(),
                    }
                } else {
                    Message::Result {
                        batch: batch as u32,
                        out_width: ow as u32,
                        codes,
                    }
                };
                if stream.write_all(&wire::encode_frame(id, &msg))
                    .is_err()
                {
                    dead = true;
                }
                // release only after the response bytes are out (or
                // the socket is known dead): "in flight" means "the
                // answer has not reached the kernel yet"
                shared.inflight.fetch_sub(batch, Ordering::SeqCst);
            }
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    shared.conns.lock().unwrap().remove(&conn_id);
    shared.open.fetch_sub(1, Ordering::SeqCst);
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// Build the stats JSON document (`model` empty: every hosted model).
fn stats_json(shared: &Arc<Shared>, model: &str)
              -> Result<String, (u16, String)> {
    use std::collections::BTreeMap;
    let indices: Vec<usize> = if model.is_empty() {
        (0..shared.models.len()).collect()
    } else {
        match shared.by_name.get(model) {
            Some(&i) => vec![i],
            None => {
                return Err((wire::ERR_UNKNOWN_MODEL, format!(
                    "no model named '{model}' is hosted")));
            }
        }
    };
    let mut models = Vec::new();
    for i in indices {
        let meta = &shared.models[i];
        let st = shared
            .server
            .model_stats(&meta.name)
            .map_err(|e| (wire::ERR_INTERNAL, format!("{e:#}")))?;
        let mut lat = BTreeMap::new();
        lat.insert("count".into(), num(st.latency.count as f64));
        lat.insert("mean".into(), num(st.latency.mean));
        lat.insert("p50".into(), num(st.latency.p50));
        lat.insert("p99".into(), num(st.latency.p99));
        lat.insert("p999".into(), num(st.latency.p999));
        let mut net = BTreeMap::new();
        net.insert("requests".into(),
                   num(meta.net.requests.load(Ordering::SeqCst) as f64));
        net.insert("rows".into(),
                   num(meta.net.rows.load(Ordering::SeqCst) as f64));
        net.insert("shed".into(),
                   num(meta.net.shed.load(Ordering::SeqCst) as f64));
        let mut m = BTreeMap::new();
        m.insert("model".into(), Json::Str(meta.name.clone()));
        m.insert("n_in".into(), num(meta.n_in as f64));
        m.insert("out_width".into(), num(meta.out_width as f64));
        m.insert("backend".into(),
                 Json::Str(format!("plan-w{}", meta.lane_width)));
        m.insert("lane_width".into(), num(meta.lane_width as f64));
        m.insert("requests".into(), num(st.requests as f64));
        m.insert("batches".into(), num(st.batches as f64));
        m.insert("mean_occupancy".into(), num(st.mean_occupancy));
        m.insert("max_batch_seen".into(), num(st.max_batch_seen as f64));
        m.insert("latency_us".into(), Json::Obj(lat));
        m.insert("net".into(), Json::Obj(net));
        models.push(Json::Obj(m));
    }
    let mut srv = BTreeMap::new();
    srv.insert("accepted_conns".into(),
               num(shared.accepted.load(Ordering::SeqCst) as f64));
    srv.insert("open_conns".into(),
               num(shared.open.load(Ordering::SeqCst) as f64));
    srv.insert("inflight".into(),
               num(shared.inflight.load(Ordering::SeqCst) as f64));
    srv.insert("max_inflight".into(),
               num(shared.cfg.max_inflight as f64));
    srv.insert("shed_total".into(),
               num(shared.shed_total.load(Ordering::SeqCst) as f64));
    srv.insert("draining".into(),
               Json::Bool(shared.stop.load(Ordering::SeqCst)));
    // plan-cache telemetry (stable keys, asserted in tests/net.rs):
    // how the hosted plans came to exist — compiled here, shared from
    // an identical registration, or cold-loaded from the persistent
    // cache (zero-copy mapped unless --no-mmap / fallback)
    let (compiles, memory_hits) = shared.server.plan_cache_counts();
    let mut pc = BTreeMap::new();
    pc.insert("compiles".into(), num(compiles as f64));
    pc.insert("memory_hits".into(), num(memory_hits as f64));
    pc.insert("disk_hits".into(),
              num(shared.server.plan_cache_disk_hits() as f64));
    srv.insert("plan_cache".into(), Json::Obj(pc));
    let mut root = BTreeMap::new();
    root.insert("models".into(), Json::Arr(models));
    root.insert("server".into(), Json::Obj(srv));
    Ok(Json::Obj(root).to_string())
}
