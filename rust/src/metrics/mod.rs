//! Evaluation metrics: classification accuracy, confusion counts, and
//! latency statistics for the serving path.

/// Accuracy of predictions vs labels.
pub fn accuracy(pred: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(labels).filter(|(p, y)| p == y).count();
    hits as f64 / pred.len() as f64
}

/// Argmax over each row of `codes` (row-major, `width` per row) — class
/// prediction for multi-class heads (codes are monotone in value).
pub fn argmax_rows(codes: &[i32], width: usize) -> Vec<i32> {
    assert!(width > 0);
    codes
        .chunks_exact(width)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

/// Binary prediction from single-unit output codes: positive iff the code
/// is in the upper half of the range (value > 0 in midrise decoding).
pub fn binary_rows(codes: &[i32], out_bits: usize) -> Vec<i32> {
    let thr = 1i32 << (out_bits - 1);
    codes.iter().map(|&c| (c >= thr) as i32).collect()
}

/// K x K confusion matrix (rows = true, cols = predicted).
pub fn confusion(pred: &[i32], labels: &[i32], k: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; k]; k];
    for (&p, &y) in pred.iter().zip(labels) {
        m[y as usize][p as usize] += 1;
    }
    m
}

/// Reservoir size for [`LatencyStats`]: percentiles beyond this many
/// recorded samples are estimated from a uniform random subsample
/// (Vitter's Algorithm R), so an always-on server's per-model stats
/// stay bounded — ~512 KiB per model — instead of growing 8 bytes per
/// request forever.  Mean and count stay exact (running sum).
const LATENCY_RESERVOIR: usize = 1 << 16;

/// Online latency statistics (microseconds) for the serving path.
/// Bounded: exact mean/count, reservoir-sampled percentiles past
/// [`LATENCY_RESERVOIR`] samples.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
    count: u64,
    sum: f64,
    /// LCG state for reservoir replacement (deterministic, seeded 0)
    rng: u64,
}

impl LatencyStats {
    pub fn record(&mut self, micros: f64) {
        self.count += 1;
        self.sum += micros;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(micros);
        } else {
            // Algorithm R: keep each of the `count` samples in the
            // reservoir with equal probability.  Lemire's widening
            // multiply maps the full 64-bit state uniformly onto
            // [0, count) — no modulo bias, no truncation to 31 bits.
            self.rng = self
                .rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = ((self.rng as u128 * self.count as u128) >> 64) as u64;
            if (j as usize) < LATENCY_RESERVOIR {
                self.samples[j as usize] = micros;
            }
        }
    }

    /// Total samples recorded (exact, not capped by the reservoir).
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Exact mean over every recorded sample.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Point-in-time summary with the percentiles the serving path
    /// reports (p50/p99/p999); one sort instead of three.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let at = |p: f64| {
            let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
            s[idx.min(s.len() - 1)]
        };
        LatencySummary {
            count: self.count(),
            mean: self.mean(),
            p50: at(50.0),
            p99: at(99.0),
            p999: at(99.9),
        }
    }
}

/// Snapshot of a [`LatencyStats`] (microseconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
}

/// Batch-occupancy statistics for the dynamic-batching server: how many
/// batches were dispatched, how full they were, and the largest one —
/// the signal for tuning `max_batch`/`max_wait` per model.
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    batches: u64,
    requests: u64,
    max_size: usize,
}

impl BatchStats {
    /// Record one dispatched batch of `size` requests.
    pub fn record(&mut self, size: usize) {
        self.batches += 1;
        self.requests += size as u64;
        self.max_size = self.max_size.max(size);
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn max_size(&self) -> usize {
        self.max_size
    }

    /// Mean requests per dispatched batch (0 when nothing dispatched).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / self.batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax_rows(&[1, 3, 3, 0, 5, 5], 3), vec![1, 1]);
    }

    #[test]
    fn binary_threshold() {
        // out_bits=2 -> threshold 2
        assert_eq!(binary_rows(&[0, 1, 2, 3], 2), vec![0, 0, 1, 1]);
    }

    #[test]
    fn confusion_counts() {
        let m = confusion(&[0, 1, 1, 0], &[0, 1, 0, 0], 2);
        assert_eq!(m[0][0], 2);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[1][0], 0);
    }

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(50.0), 51.0); // round(49.5) = 50 -> s[50]
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.count(), 100);
    }

    #[test]
    fn latency_summary_matches_percentiles() {
        let mut s = LatencyStats::default();
        // record out of order; summary sorts internally
        for i in (1..=1000).rev() {
            s.record(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.count, 1000);
        assert!((sum.mean - s.mean()).abs() < 1e-9);
        assert_eq!(sum.p50, s.percentile(50.0));
        assert_eq!(sum.p99, s.percentile(99.0));
        assert_eq!(sum.p999, s.percentile(99.9));
        assert!(sum.p50 <= sum.p99 && sum.p99 <= sum.p999);
        assert_eq!(LatencyStats::default().summary(),
                   LatencySummary::default());
    }

    #[test]
    fn latency_reservoir_bounds_memory_keeps_exact_mean() {
        let mut s = LatencyStats::default();
        let n = LATENCY_RESERVOIR + 5000;
        for i in 0..n {
            s.record((i % 1000) as f64);
        }
        assert_eq!(s.count(), n);
        assert!(s.samples.len() <= LATENCY_RESERVOIR, "reservoir overflow");
        let want =
            (0..n).map(|i| (i % 1000) as f64).sum::<f64>() / n as f64;
        assert!((s.mean() - want).abs() < 1e-6, "mean must stay exact");
        let sum = s.summary();
        assert_eq!(sum.count, n);
        // percentiles are estimated from the reservoir but must stay
        // inside the observed value range and ordered
        assert!(sum.p50 >= 0.0 && sum.p999 <= 999.0);
        assert!(sum.p50 <= sum.p99 && sum.p99 <= sum.p999);
    }

    #[test]
    fn batch_occupancy() {
        let mut b = BatchStats::default();
        assert_eq!(b.mean_occupancy(), 0.0);
        b.record(4);
        b.record(8);
        b.record(12);
        assert_eq!(b.batches(), 3);
        assert_eq!(b.requests(), 24);
        assert_eq!(b.max_size(), 12);
        assert!((b.mean_occupancy() - 8.0).abs() < 1e-9);
    }
}
