//! Evaluation metrics: classification accuracy, confusion counts, and
//! latency statistics for the serving path.

/// Accuracy of predictions vs labels.
pub fn accuracy(pred: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(labels).filter(|(p, y)| p == y).count();
    hits as f64 / pred.len() as f64
}

/// Argmax over each row of `codes` (row-major, `width` per row) — class
/// prediction for multi-class heads (codes are monotone in value).
pub fn argmax_rows(codes: &[i32], width: usize) -> Vec<i32> {
    assert!(width > 0);
    codes
        .chunks_exact(width)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

/// Binary prediction from single-unit output codes: positive iff the code
/// is in the upper half of the range (value > 0 in midrise decoding).
pub fn binary_rows(codes: &[i32], out_bits: usize) -> Vec<i32> {
    let thr = 1i32 << (out_bits - 1);
    codes.iter().map(|&c| (c >= thr) as i32).collect()
}

/// K x K confusion matrix (rows = true, cols = predicted).
pub fn confusion(pred: &[i32], labels: &[i32], k: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; k]; k];
    for (&p, &y) in pred.iter().zip(labels) {
        m[y as usize][p as usize] += 1;
    }
    m
}

/// Online latency statistics (microseconds) for the serving benches.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, micros: f64) {
        self.samples.push(micros);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax_rows(&[1, 3, 3, 0, 5, 5], 3), vec![1, 1]);
    }

    #[test]
    fn binary_threshold() {
        // out_bits=2 -> threshold 2
        assert_eq!(binary_rows(&[0, 1, 2, 3], 2), vec![0, 0, 1, 1]);
    }

    #[test]
    fn confusion_counts() {
        let m = confusion(&[0, 1, 1, 0], &[0, 1, 0, 0], 2);
        assert_eq!(m[0][0], 2);
        assert_eq!(m[0][1], 1);
        assert_eq!(m[1][1], 1);
        assert_eq!(m[1][0], 0);
    }

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(50.0), 51.0); // round(49.5) = 50 -> s[50]
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.count(), 100);
    }
}
