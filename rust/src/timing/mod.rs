//! Timing, pipelining and area-delay reporting — the Vivado P&R substitute.
//!
//! The paper evaluates two pipelining strategies (§III-C): a register
//! after *every* L-LUT layer (throughput-optimized) and a register after
//! every *three* layers (latency-optimized), with Vivado retiming enabled.
//! We model a pipeline stage's clock period as
//!
//! ```text
//! T_stage = T0 + T_LUT * depth(stage) + T_NET * (layers_in_stage - 1)
//!           + T_CONG * log2(LUTs_in_stage + 1)
//! ```
//!
//! where `depth` sums the mapped P-LUT levels of the stage's layers, the
//! `T_NET` term charges the inter-layer routing hop, and the congestion
//! term grows with stage size (wider designs route slower — the dominant
//! effect in Table III, where tiny NID clocks 1.6x faster than MNIST at
//! identical logic depth).  Constants are calibrated against the paper's
//! Table III (see `calibration` tests; model-vs-paper is printed by the
//! table3 bench).  FF counts place register cuts by a retiming-style DP
//! that minimizes registered bits subject to the stage-length bound —
//! matching Vivado-with-retiming behaviour, and reproducing e.g. the
//! paper's 5464 -> 713 FF drop on MNIST between the two strategies.

use crate::mapper::MappedNetlist;

/// Calibrated delay-model constants (ns).  See module docs.
#[derive(Clone, Copy, Debug)]
pub struct DelayModel {
    pub t0: f64,
    pub t_lut: f64,
    pub t_net: f64,
    pub t_cong: f64,
}

impl Default for DelayModel {
    fn default() -> Self {
        DelayModel { t0: 0.25, t_lut: 0.15, t_net: 0.10, t_cong: 0.045 }
    }
}

/// Pipelining strategy (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pipelining {
    /// register after every L-LUT layer (throughput-optimized)
    EveryLayer,
    /// register after at most `k` layers, cuts placed by retiming DP
    EveryK(usize),
    /// fully combinational (single stage)
    None,
}

/// Post-P&R style report for one design point.
#[derive(Clone, Debug)]
pub struct TimingReport {
    pub luts: usize,
    pub ffs: usize,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub stages: usize,
    /// LUT x ns, the paper's headline metric
    pub area_delay: f64,
    /// stage boundaries: index i = last layer of stage i
    pub cuts: Vec<usize>,
}

fn stage_period(m: &MappedNetlist, lo: usize, hi: usize, dm: &DelayModel) -> f64 {
    let depth: f64 = m.layers[lo..=hi].iter().map(|l| l.depth).sum();
    let luts: usize = m.layers[lo..=hi].iter().map(|l| l.luts).sum();
    dm.t0
        + dm.t_lut * depth
        + dm.t_net * (hi - lo) as f64
        + dm.t_cong * ((luts + 1) as f64).log2()
}

/// Retiming-style cut placement: split layers into contiguous stages of at
/// most `k` layers minimizing total registered bits (cut width), then
/// report the critical stage period.
fn place_cuts(m: &MappedNetlist, k: usize) -> Vec<usize> {
    let n = m.layers.len();
    if n == 0 {
        return vec![];
    }
    // dp[i] = (min registered bits for layers 0..=i with a cut after i)
    let width = |i: usize| m.layers[i].out_bits_total;
    let mut dp = vec![usize::MAX; n];
    let mut prev = vec![usize::MAX; n];
    for i in 0..n {
        for j in i.saturating_sub(k - 1)..=i {
            // stage = layers j..=i ; previous cut after j-1
            let base = if j == 0 {
                0
            } else if dp[j - 1] == usize::MAX {
                continue;
            } else {
                dp[j - 1]
            };
            let cost = base + width(i);
            if cost < dp[i] {
                dp[i] = cost;
                prev[i] = j;
            }
        }
    }
    // reconstruct cuts (cut after last layer is the output register)
    let mut cuts = Vec::new();
    let mut i = n - 1;
    loop {
        cuts.push(i);
        let j = prev[i];
        if j == 0 {
            break;
        }
        i = j - 1;
    }
    cuts.reverse();
    cuts
}

/// Evaluate a mapped netlist under a pipelining strategy.
pub fn evaluate(m: &MappedNetlist, strategy: Pipelining,
                dm: &DelayModel) -> TimingReport {
    let n = m.layers.len();
    let cuts: Vec<usize> = match strategy {
        Pipelining::EveryLayer => (0..n).collect(),
        Pipelining::EveryK(k) => place_cuts(m, k.max(1)),
        Pipelining::None => vec![n.saturating_sub(1)],
    };
    let mut period: f64 = 0.0;
    let mut lo = 0usize;
    let mut ffs = 0usize;
    for &hi in &cuts {
        period = period.max(stage_period(m, lo, hi, dm));
        ffs += m.layers[hi].out_bits_total;
        lo = hi + 1;
    }
    let stages = cuts.len();
    let fmax_mhz = 1000.0 / period;
    let latency_ns = stages as f64 * period;
    let luts = m.total_luts();
    TimingReport {
        luts,
        ffs,
        fmax_mhz,
        latency_ns,
        stages,
        area_delay: luts as f64 * latency_ns,
        cuts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::MappedLayer;

    fn mapped(widths: &[(usize, f64, usize)]) -> MappedNetlist {
        // (luts, depth, out_bits_total)
        MappedNetlist {
            layers: widths
                .iter()
                .map(|&(luts, depth, ob)| MappedLayer {
                    luts,
                    depth,
                    out_bits_total: ob,
                    luts_worst_case: luts,
                })
                .collect(),
            input_bits: 64,
        }
    }

    #[test]
    fn every_layer_registers_everything() {
        let m = mapped(&[(100, 1.0, 100), (50, 1.0, 50), (10, 1.0, 10)]);
        let r = evaluate(&m, Pipelining::EveryLayer, &DelayModel::default());
        assert_eq!(r.stages, 3);
        assert_eq!(r.ffs, 160);
        assert_eq!(r.cuts, vec![0, 1, 2]);
    }

    #[test]
    fn every_k_reduces_stages_and_ffs() {
        let m = mapped(&[
            (2160, 1.0, 2160), (360, 1.0, 360), (2160, 1.0, 2160),
            (360, 1.0, 360), (60, 1.0, 60), (60, 1.0, 60),
        ]);
        let dm = DelayModel::default();
        let p1 = evaluate(&m, Pipelining::EveryLayer, &dm);
        let p3 = evaluate(&m, Pipelining::EveryK(3), &dm);
        assert_eq!(p1.stages, 6);
        assert!(p3.stages <= 3);
        // retiming DP avoids registering the wide 2160-bit layers
        assert!(p3.ffs < 1000, "ffs {}", p3.ffs);
        assert!(p3.ffs < p1.ffs / 5);
        // fewer stages -> lower latency even at slightly lower fmax
        assert!(p3.latency_ns < p1.latency_ns);
        assert!(p3.fmax_mhz < p1.fmax_mhz);
    }

    #[test]
    fn cut_dp_prefers_narrow_layers() {
        // widths: 1000, 10, 1000, 10 with k=2 -> cuts after layers 1 and 3
        let m = mapped(&[
            (10, 1.0, 1000), (10, 1.0, 10), (10, 1.0, 1000), (10, 1.0, 10),
        ]);
        let r = evaluate(&m, Pipelining::EveryK(2), &DelayModel::default());
        assert_eq!(r.cuts, vec![1, 3]);
        assert_eq!(r.ffs, 20);
    }

    #[test]
    fn combinational_single_stage() {
        let m = mapped(&[(10, 1.0, 10), (5, 1.0, 5)]);
        let r = evaluate(&m, Pipelining::None, &DelayModel::default());
        assert_eq!(r.stages, 1);
        assert_eq!(r.ffs, 5);
    }

    #[test]
    fn deeper_luts_slow_the_clock() {
        let shallow = mapped(&[(100, 1.0, 100)]);
        let deep = mapped(&[(100, 2.0, 100)]);
        let dm = DelayModel::default();
        let a = evaluate(&shallow, Pipelining::EveryLayer, &dm);
        let b = evaluate(&deep, Pipelining::EveryLayer, &dm);
        assert!(b.fmax_mhz < a.fmax_mhz);
    }

    #[test]
    fn congestion_slows_wide_designs() {
        let small = mapped(&[(60, 1.0, 60)]);
        let big = mapped(&[(5000, 1.0, 5000)]);
        let dm = DelayModel::default();
        let a = evaluate(&small, Pipelining::EveryLayer, &dm);
        let b = evaluate(&big, Pipelining::EveryLayer, &dm);
        assert!(b.fmax_mhz < a.fmax_mhz);
        // shape check against Table III: tiny NID ~1.5x faster than MNIST
        let ratio = a.fmax_mhz / b.fmax_mhz;
        assert!(ratio > 1.2 && ratio < 2.5, "ratio {ratio}");
    }

    /// Calibration: the model applied to the *paper's own designs*
    /// (layer shapes from Table II, LUT counts from Table IV) must land
    /// within 2x of the paper's reported Fmax on every Table III row —
    /// it is a delay *model*, relative comparisons are what must hold.
    #[test]
    fn calibration_within_2x_of_table3() {
        let dm = DelayModel::default();
        struct Row {
            name: &'static str,
            layers: Vec<(usize, f64, usize)>,
            fmax_p1: f64,
            fmax_p3: f64,
        }
        let rows = vec![
            Row {
                name: "mnist",
                layers: vec![(2160, 1.0, 2160), (360, 1.0, 360),
                             (2160, 1.0, 2160), (360, 1.0, 360),
                             (60, 1.0, 60), (60, 1.0, 60)],
                fmax_p1: 916.0,
                fmax_p3: 849.0,
            },
            Row {
                name: "jsc_cb",
                layers: vec![(2560, 2.0, 1280), (2560, 2.0, 640),
                             (1280, 2.0, 320), (640, 2.0, 160),
                             (320, 2.0, 80), (160, 2.0, 40), (160, 2.0, 40)],
                fmax_p1: 994.0,
                fmax_p3: 352.0,
            },
            Row {
                name: "nid",
                layers: vec![(60, 1.0, 120), (20, 1.0, 40), (9, 1.0, 18),
                             (3, 1.0, 6), (2, 1.0, 2)],
                fmax_p1: 1479.0,
                fmax_p3: 1471.0,
            },
        ];
        for row in rows {
            let m = mapped(&row.layers);
            let p1 = evaluate(&m, Pipelining::EveryLayer, &dm);
            let p3 = evaluate(&m, Pipelining::EveryK(3), &dm);
            for (got, want, tag) in [(p1.fmax_mhz, row.fmax_p1, "p1"),
                                     (p3.fmax_mhz, row.fmax_p3, "p3")] {
                let ratio = got / want;
                assert!(
                    (0.5..2.0).contains(&ratio),
                    "{} {}: model {got:.0} vs paper {want:.0}",
                    row.name, tag
                );
            }
        }
    }
}
