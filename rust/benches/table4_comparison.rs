//! Table IV: NeuraLUT-Assemble vs prior ultra-low-latency models.
//!
//! Our rows are measured end-to-end on the synthetic datasets through the
//! shared mapper/timing model; LogicNets-style and TreeLUT-style baselines
//! are fully implemented and run through the same hardware model; the
//! remaining prior-work rows are reprinted from the paper (labelled
//! "(paper)") so the area-delay-product ordering can be compared.
//! (`cargo bench --bench table4_comparison`)

#[path = "common/mod.rs"]
mod common;

use neuralut::baselines::logicnets::{LogicNetsConfig, LogicNetsModel};
use neuralut::baselines::treelut::{TreeLutConfig, TreeLutModel};
use neuralut::config::Meta;
use neuralut::dataset;
use neuralut::mapper::map_netlist;
use neuralut::report::{pct, ratio_line, sci, Table};
use neuralut::runtime::Runtime;
use neuralut::timing::{evaluate, DelayModel, Pipelining};

fn main() {
    let meta = Meta::load(Meta::default_dir()).expect("run `make artifacts`");
    let rt = Runtime::new().expect("pjrt");
    let dm = DelayModel::default();
    let mut table = Table::new(
        "Table IV — comparison (ours measured on synthetic data; '(paper)' rows reported)",
        &["dataset", "model", "acc", "LUT", "FF", "Fmax (MHz)",
          "latency (ns)", "AreaxDelay"],
    );

    let mut ours_adp = std::collections::BTreeMap::new();
    for config in ["mnist", "jsc_cb", "jsc_oml", "nid"] {
        let opts = common::options(config, 7);
        let r = common::run(&rt, &meta, &opts);
        let p3 = evaluate(&r.mapped, Pipelining::EveryK(3), &dm);
        ours_adp.insert(config.to_string(), p3.area_delay);
        table.row(&[
            config.into(),
            "NeuraLUT-Assemble (ours, measured)".into(),
            pct(r.netlist_acc),
            p3.luts.to_string(),
            p3.ffs.to_string(),
            format!("{:.0}", p3.fmax_mhz),
            format!("{:.1}", p3.latency_ns),
            sci(p3.area_delay),
        ]);
    }

    // ---- fully implemented baselines, same datasets + hardware model ----
    // LogicNets-style on NID
    {
        let opts = common::options("nid", 7);
        let top = &meta.config("nid").unwrap().topology;
        let splits = dataset::generate(&top.dataset, top.beta_in, &opts.gen).unwrap();
        let mut ln = LogicNetsModel::new(&LogicNetsConfig::nid());
        ln.train(&splits.train, 3 * common::scale(), 0.02);
        let nl = ln.to_netlist().unwrap();
        let acc = ln.netlist_accuracy(&nl, &splits.test).unwrap();
        let mapped = map_netlist(&nl, true);
        let p3 = evaluate(&mapped, Pipelining::EveryK(3), &dm);
        table.row(&[
            "nid".into(),
            "LogicNets-style (ours, measured)".into(),
            pct(acc),
            p3.luts.to_string(),
            p3.ffs.to_string(),
            format!("{:.0}", p3.fmax_mhz),
            format!("{:.1}", p3.latency_ns),
            sci(p3.area_delay),
        ]);
    }
    // TreeLUT-style on NID + JSC OpenML
    for config in ["nid", "jsc_oml"] {
        let opts = common::options(config, 7);
        let top = &meta.config(config).unwrap().topology;
        let splits = dataset::generate(&top.dataset, top.beta_in, &opts.gen).unwrap();
        let t = TreeLutModel::train(
            &splits.train,
            &TreeLutConfig { n_trees: 16 * common::scale(), depth: 3,
                             ..Default::default() },
        );
        let acc = t.accuracy(&splits.test);
        let hm = t.hardware_model();
        let p = evaluate(&hm, Pipelining::EveryLayer, &dm);
        table.row(&[
            config.into(),
            "TreeLUT-style (ours, measured)".into(),
            pct(acc),
            p.luts.to_string(),
            p.ffs.to_string(),
            format!("{:.0}", p.fmax_mhz),
            format!("{:.1}", p.latency_ns),
            sci(p.area_delay),
        ]);
    }

    // ---- paper-reported rows ----
    for row in common::PAPER_ROWS {
        table.row(&[
            row.dataset.into(),
            row.model.into(),
            pct(row.acc),
            row.luts.to_string(),
            row.ffs.to_string(),
            row.fmax.to_string(),
            format!("{:.1}", row.latency_ns),
            sci(row.luts as f64 * row.latency_ns),
        ]);
    }
    table.print();

    // headline ratios: ours vs best prior work per dataset (paper: 1.06x,
    // 8.42x, 1.54x, 4.07x vs the best prior; up to 62x vs NeuraLUT)
    println!("\nheadline area-delay ratios (paper-reported prior work / our measured design):");
    for (config, best_prior) in [("mnist", 1.12e4), ("jsc_cb", 4.10e5),
                                 ("jsc_oml", 6.03e3), ("nid", 5.17e2)] {
        if let Some(&ours) = ours_adp.get(config) {
            println!("  {}", ratio_line(config, ours, best_prior));
        }
    }
    if let Some(&ours) = ours_adp.get("mnist") {
        println!("  {}", ratio_line("mnist vs NeuraLUT (paper 62x)", ours, 6.58e5));
    }
    if let Some(&ours) = ours_adp.get("jsc_cb") {
        println!("  {}", ratio_line("jsc_cb vs NeuraLUT (paper 26x)", ours, 1.29e6));
    }
}
