//! Table II: reference floating-point fully-connected accuracy vs the
//! NeuraLUT-Assemble quantized model, per dataset, plus the architecture
//! parameters used.  (`cargo bench --bench table2_accuracy`)

#[path = "common/mod.rs"]
mod common;

use neuralut::baselines::mlp::Mlp;
use neuralut::config::Meta;
use neuralut::dataset;
use neuralut::report::{pct, Table};
use neuralut::runtime::Runtime;

fn main() {
    let meta = Meta::load(Meta::default_dir()).expect("run `make artifacts`");
    let rt = Runtime::new().expect("pjrt");
    let mut table = Table::new(
        "Table II — FP-FC reference vs NeuraLUT-Assemble (scaled synthetic data)",
        &["dataset", "FP-FC acc", "ours (QAT)", "ours (netlist)",
          "w_l", "F", "beta", "L/N/S"],
    );

    let configs = ["mnist", "jsc_cb", "jsc_oml", "nid"];
    for config in configs {
        let cfg = meta.config(config).unwrap();
        let top = &cfg.topology;
        let opts = common::options(config, 7);

        // FP-FC reference: dense float MLP with hidden widths ~ layer widths
        let splits = dataset::generate(&top.dataset, top.beta_in, &opts.gen)
            .expect("dataset");
        // two wide hidden layers (depth-4 per-sample SGD is unstable);
        // this is the accuracy *ceiling* reference, not a topology match
        let h0 = top.w[0].min(128).max(64);
        let mut mlp = Mlp::new(top.n_in, &[h0, h0 / 2], top.n_classes, 42);
        let epochs = 6 * common::scale();
        mlp.train(&splits.train, epochs, 0.008, 43);
        let fp_acc = mlp.accuracy(&splits.test);

        let r = common::run(&rt, &meta, &opts);
        table.row(&[
            config.to_string(),
            pct(fp_acc),
            pct(r.qat_acc),
            pct(r.netlist_acc),
            format!("{:?}", top.w),
            format!("{:?}", top.f),
            format!("{:?}", top.beta),
            format!("{}/{}/{}", top.l_sub, top.n_hidden, top.s),
        ]);
    }
    table.print();
    println!(
        "\npaper's Table II reference points: MNIST 98.4/97.9, JSC-CB 76.0/75.0, \
         JSC-OML 77.0/76.0, NID 92.5/93.0 (FP-FC / ours). Shape criterion: \
         ours within ~1-2pp of the FP-FC reference on the same data."
    );
}
