//! L3 hot-path microbenchmarks: netlist simulator throughput (gather vs
//! bit-plane kernels, single- and multi-threaded) and the batching
//! server, used for EXPERIMENTS.md §Hot path.  Custom harness (no
//! criterion offline); medians over repeated runs.
//! (`cargo bench --bench netlist_hotpath`)

use std::time::Instant;

use neuralut::coordinator::{InferenceServer, ServerConfig};
use neuralut::mapper::map_netlist;
use neuralut::netlist::testutil::{random_inputs, random_netlist,
                                  random_reducible_netlist};
use neuralut::netlist::{optimize, Netlist, OptLevel, SimOptions,
                        ThreadMode};
use neuralut::report::Table;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    median(times)
}

fn sim_row(table: &mut Table, name: &str, nl: &Netlist, opts: SimOptions,
           batch: usize) -> f64 {
    let x = random_inputs(9, nl, batch);
    let mut sim = nl.simulator_with(opts);
    let t = bench(9, || {
        let out = sim.eval_batch(&x, batch);
        std::hint::black_box(&out);
    });
    table.row(&[
        name.into(),
        batch.to_string(),
        format!("{:.1} us", t * 1e6),
        format!("{:.2} Msamples/s", batch as f64 / t / 1e6),
    ]);
    t
}

fn main() {
    let mut table = Table::new(
        "netlist simulator + server hot path",
        &["case", "batch", "median time", "throughput"],
    );

    // MNIST-shaped boolean netlist: 784 x 1b inputs, layers like the preset
    let mnist_like = random_netlist(
        1, 784, 1, &[(360, 6, 1), (60, 6, 1), (10, 6, 6)]);
    // JSC-shaped multi-bit netlist with dense tables: raw addr width 8 and
    // full support, so only the gather kernel applies
    let jsc_dense = random_netlist(
        2, 16, 4, &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4), (5, 2, 8)]);
    // Same shape with trained-like tables (per-bit support <= 6): this is
    // the mixed-width case the bit-plane engine exists for
    let jsc_reduc = random_reducible_netlist(
        3, 16, 4, &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4), (5, 2, 8)],
        6);
    {
        let s = jsc_reduc.simulator();
        assert_eq!(s.bitplane_layers(), jsc_reduc.layers.len(),
                   "reducible netlist must compile fully to bit-plane");
    }

    let default_opts = SimOptions::default();
    let gather_only = SimOptions { bitplane: false, ..Default::default() };

    for batch in [1usize, 64, 1024] {
        sim_row(&mut table, "mnist-like (mostly 1-bit)", &mnist_like,
                default_opts, batch);
    }
    for batch in [1usize, 64, 1024] {
        sim_row(&mut table, "jsc-like dense 4-bit (gather)", &jsc_dense,
                default_opts, batch);
    }

    // headline comparison: mixed-width netlist, gather vs bit-plane,
    // then bit-plane with intra-batch threads
    let mut speedup_256 = 0.0;
    for batch in [64usize, 256, 1024] {
        let tg = sim_row(&mut table, "jsc-like reducible (gather)",
                         &jsc_reduc, gather_only, batch);
        let tb = sim_row(&mut table, "jsc-like reducible (bit-plane)",
                         &jsc_reduc, default_opts, batch);
        if batch == 256 {
            speedup_256 = tg / tb;
        }
    }
    // raw vs optimized: the netlist optimizer (const-fold, dead-logic,
    // CSE) runs once at load time; the simulator then compiles fewer
    // units and planes.  The mapper must agree that the optimized
    // netlist is a strictly smaller design on this reducible netlist
    // (dead units and constant-fed address bits are common in it, as in
    // trained tables), and the optimized hot path must never be slower.
    let (jsc_opt, opt_report) = optimize(&jsc_reduc, OptLevel::Full);
    println!("optimizer on jsc-like reducible: {}", opt_report.summary());
    let raw_pluts = map_netlist(&jsc_reduc, true).total_luts();
    let opt_pluts = map_netlist(&jsc_opt, true).total_luts();
    println!("mapped P-LUTs: raw {raw_pluts} -> optimized {opt_pluts}");
    assert!(opt_pluts < raw_pluts,
            "optimized netlist must map strictly smaller: \
             {opt_pluts} !< {raw_pluts}");
    let mut t_raw_1024 = 0.0;
    let mut t_opt_1024 = 0.0;
    for batch in [256usize, 1024] {
        let tr = sim_row(&mut table, "jsc-like reducible (raw netlist)",
                         &jsc_reduc, default_opts, batch);
        let to = sim_row(&mut table, "jsc-like reducible (optimized)",
                         &jsc_opt, default_opts, batch);
        if batch == 1024 {
            t_raw_1024 = tr;
            t_opt_1024 = to;
        }
    }
    println!("optimized vs raw simulator @ batch 1024: {:.2}x",
             t_raw_1024 / t_opt_1024);
    // enforced, not just printed: serving an optimized netlist must
    // never cost throughput (generous slack absorbs runner noise; the
    // expected direction is a clear win — fewer units and planes)
    assert!(t_opt_1024 <= t_raw_1024 * 1.15,
            "optimized eval {:.1}us regressed past raw {:.1}us",
            t_opt_1024 * 1e6, t_raw_1024 * 1e6);

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    for threads in [2usize, cores.max(2)] {
        sim_row(&mut table,
                &format!("jsc-like reducible (bit-plane x{threads}t)"),
                &jsc_reduc,
                SimOptions { threads, ..Default::default() }, 4096);
        sim_row(&mut table,
                &format!("mnist-like (bit-plane x{threads}t)"),
                &mnist_like,
                SimOptions { threads, ..Default::default() }, 4096);
    }

    // persistent pool vs per-call scoped spawning.  Small batches are
    // the regime the pool exists for: a scoped spawn never amortizes
    // there (the scoped path stays serial below its work floor), while
    // waking parked workers does.  At large batch both modes fan out
    // identically and the pool only saves the per-layer spawn/join.
    let pooled = |threads| SimOptions {
        threads, mode: ThreadMode::Pooled, ..Default::default()
    };
    let scoped = |threads| SimOptions {
        threads, mode: ThreadMode::Scoped, ..Default::default()
    };
    let mut small_batch_speedup = 0.0;
    for batch in [16usize, 64] {
        for threads in [2usize, 4] {
            let ts = sim_row(
                &mut table,
                &format!("mnist-like scoped x{threads}t"),
                &mnist_like, scoped(threads), batch);
            let tp = sim_row(
                &mut table,
                &format!("mnist-like pooled x{threads}t"),
                &mnist_like, pooled(threads), batch);
            if batch == 64 && threads == 2 {
                small_batch_speedup = ts / tp;
            }
        }
    }
    let big = cores.max(2);
    let ts_large = sim_row(&mut table,
                           &format!("mnist-like scoped x{big}t"),
                           &mnist_like, scoped(big), 4096);
    let tp_large = sim_row(&mut table,
                           &format!("mnist-like pooled x{big}t"),
                           &mnist_like, pooled(big), 4096);

    // per-sample eval_one (the naive baseline the batched path replaced)
    {
        let batch = 1024usize;
        let x = random_inputs(9, &mnist_like, batch);
        let t = bench(5, || {
            for b in 0..batch {
                let out = mnist_like
                    .eval_one(&x[b * 784..(b + 1) * 784])
                    .unwrap();
                std::hint::black_box(&out);
            }
        });
        table.row(&[
            "mnist-like eval_one loop (baseline)".into(),
            batch.to_string(),
            format!("{:.1} us", t * 1e6),
            format!("{:.2} Msamples/s", batch as f64 / t / 1e6),
        ]);
    }

    // batching server end-to-end (threads + channels + sim)
    for sim_threads in [1usize, 2] {
        let server = InferenceServer::start_single(
            mnist_like.clone(),
            ServerConfig { sim_threads, ..Default::default() });
        let model = server.default_model().to_string();
        let n = 4096usize;
        let rows: Vec<Vec<i32>> = {
            let x = random_inputs(11, &mnist_like, n);
            (0..n).map(|b| x[b * 784..(b + 1) * 784].to_vec()).collect()
        };
        let t = Instant::now();
        server.infer_many(&model, rows).unwrap();
        let secs = t.elapsed().as_secs_f64();
        let st = server.model_stats(&model).unwrap();
        table.row(&[
            format!("server e2e x{sim_threads}t ({} batches, occ {:.0}, \
                     mean {:.0}us p99 {:.0}us p999 {:.0}us)",
                    st.batches, st.mean_occupancy, st.latency.mean,
                    st.latency.p99, st.latency.p999),
            n.to_string(),
            format!("{:.1} ms", secs * 1e3),
            format!("{:.2} Msamples/s", n as f64 / secs / 1e6),
        ]);
        server.shutdown();
    }

    table.print();
    println!("\nmixed-width bit-plane speedup vs gather @ batch 256: \
              {speedup_256:.2}x (acceptance floor: 2x)");
    // CI runs this bench as a smoke gate: the floor is enforced, not
    // just printed.  The margin is algorithmic (~64 samples per table
    // eval), so runner noise cannot plausibly eat a 3x cushion.
    assert!(speedup_256 >= 2.0,
            "bit-plane speedup {speedup_256:.2}x fell below the 2x floor");
    println!("pooled vs scoped workers @ batch 64 x2t: \
              {small_batch_speedup:.2}x (pool wakes where a spawn never \
              amortizes)");
    println!("pooled vs scoped workers @ batch 4096 x{big}t: {:.2}x",
             ts_large / tp_large);
    // the pool must never lose at large batch (identical chunking, no
    // spawn/join); generous slack absorbs CI runner noise
    assert!(tp_large <= ts_large * 1.25,
            "pooled large-batch eval {:.1}us regressed past scoped {:.1}us",
            tp_large * 1e6, ts_large * 1e6);
}
