//! L3 hot-path microbenchmarks: netlist simulator throughput (gather vs
//! bitsliced kernels) and the batching server, used for the §Perf pass.
//! Custom harness (no criterion offline); medians over repeated runs.
//! (`cargo bench --bench netlist_hotpath`)

use std::time::Instant;

use neuralut::coordinator::{InferenceServer, ServerConfig};
use neuralut::netlist::testutil::{random_inputs as random_inputs_pub,
                                  random_netlist as random_netlist_pub};
use neuralut::report::Table;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    median(times)
}

fn main() {
    let mut table = Table::new(
        "netlist simulator + server hot path",
        &["case", "batch", "median time", "throughput"],
    );

    // MNIST-shaped boolean netlist: 784 x 1b inputs, layers like the preset
    let mnist_like = random_netlist_pub(
        1, 784, 1, &[(360, 6, 1), (60, 6, 1), (10, 6, 6)]);
    // JSC-shaped multi-bit netlist
    let jsc_like = random_netlist_pub(
        2, 16, 4, &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4), (5, 2, 8)]);

    for (name, nl, n_in) in [("mnist-like (mostly 1-bit)", &mnist_like, 784),
                             ("jsc-like (4-bit)", &jsc_like, 16)] {
        for batch in [1usize, 64, 1024] {
            let x = random_inputs_pub(9, nl, batch);
            let mut sim = nl.simulator();
            let t = bench(9, || {
                let out = sim.eval_batch(&x, batch);
                std::hint::black_box(&out);
            });
            table.row(&[
                name.into(),
                batch.to_string(),
                format!("{:.1} us", t * 1e6),
                format!("{:.2} Msamples/s", batch as f64 / t / 1e6),
            ]);
        }
        let _ = n_in;
    }

    // per-sample eval_one (the naive baseline the batched path replaced)
    {
        let batch = 1024usize;
        let x = random_inputs_pub(9, &mnist_like, batch);
        let t = bench(5, || {
            for b in 0..batch {
                let out = mnist_like
                    .eval_one(&x[b * 784..(b + 1) * 784])
                    .unwrap();
                std::hint::black_box(&out);
            }
        });
        table.row(&[
            "mnist-like eval_one loop (baseline)".into(),
            batch.to_string(),
            format!("{:.1} us", t * 1e6),
            format!("{:.2} Msamples/s", batch as f64 / t / 1e6),
        ]);
    }

    // batching server end-to-end (threads + channels + sim)
    {
        let server = InferenceServer::start(mnist_like.clone(),
                                            ServerConfig::default());
        let n = 4096usize;
        let rows: Vec<Vec<i32>> = {
            let x = random_inputs_pub(11, &mnist_like, n);
            (0..n).map(|b| x[b * 784..(b + 1) * 784].to_vec()).collect()
        };
        let t = Instant::now();
        server.infer_many(rows).unwrap();
        let secs = t.elapsed().as_secs_f64();
        let (_, batches, mean, p99) = server.stats();
        table.row(&[
            format!("server e2e ({batches} batches, mean {mean:.0}us p99 {p99:.0}us)"),
            n.to_string(),
            format!("{:.1} ms", secs * 1e3),
            format!("{:.2} Msamples/s", n as f64 / secs / 1e6),
        ]);
        server.shutdown();
    }

    table.print();
}
