//! L3 hot-path microbenchmarks: netlist simulator throughput (gather vs
//! bit-plane kernels, interpreted walk vs compiled execution plan,
//! scalar vs wide-word lanes, single- and multi-threaded) and the
//! batching server, used for EXPERIMENTS.md §Hot path.  Custom harness (no criterion offline);
//! medians over repeated runs.  (`cargo bench --bench netlist_hotpath`)
//!
//! Two side outputs:
//! * `-- --quick` runs every case with minimal reps and **skips the
//!   timing assertions** (structural assertions still run) — the CI
//!   smoke mode, where the compiled path is exercised, not timed;
//! * every run writes `BENCH_netlist_hotpath.json` (rows with µs,
//!   ns/sample and throughput) so the perf trajectory is machine-
//!   readable across PRs.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use neuralut::coordinator::{InferenceServer, ServerConfig};
use neuralut::mapper::map_netlist;
use neuralut::netlist::testutil::{random_inputs, random_netlist,
                                  random_reducible_netlist};
use neuralut::netlist::{compile, optimize, LaneSelect, Netlist, OptLevel,
                        PlanCache, PlanOptions, SimOptions, ThreadMode};
use neuralut::report::Table;
use neuralut::util::Json;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    median(times)
}

/// Accumulates the printed table and the machine-readable JSON rows.
struct Harness {
    table: Table,
    rows: Vec<Json>,
    reps: usize,
    quick: bool,
}

impl Harness {
    fn record(&mut self, case: &str, batch: usize, secs: f64) {
        self.table.row(&[
            case.into(),
            batch.to_string(),
            format!("{:.1} us", secs * 1e6),
            format!("{:.2} Msamples/s", batch as f64 / secs / 1e6),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("case".into(), Json::Str(case.into()));
        obj.insert("batch".into(), Json::Num(batch as f64));
        obj.insert("us".into(), Json::Num(secs * 1e6));
        obj.insert("ns_per_sample".into(),
                   Json::Num(secs * 1e9 / batch as f64));
        obj.insert("msamples_per_s".into(),
                   Json::Num(batch as f64 / secs / 1e6));
        self.rows.push(Json::Obj(obj));
    }

    fn sim_row(&mut self, name: &str, nl: &Netlist, opts: SimOptions,
               batch: usize) -> f64 {
        let x = random_inputs(9, nl, batch);
        let mut sim = nl.simulator_with(opts);
        let reps = self.reps;
        let t = bench(reps, || {
            let out = sim.eval_batch(&x, batch);
            std::hint::black_box(&out);
        });
        self.record(name, batch, t);
        t
    }

    fn write_json(&self) {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("netlist_hotpath".into()));
        root.insert("quick".into(), Json::Bool(self.quick));
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("rows".into(), Json::Arr(self.rows.clone()));
        let path = "BENCH_netlist_hotpath.json";
        match std::fs::write(path, Json::Obj(root).to_string()) {
            Ok(()) => println!("wrote {path} ({} rows)", self.rows.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut h = Harness {
        table: Table::new(
            "netlist simulator + server hot path",
            &["case", "batch", "median time", "throughput"],
        ),
        rows: Vec::new(),
        reps: if quick { 2 } else { 9 },
        quick,
    };
    if quick {
        println!("--quick: minimal reps, timing assertions skipped");
    }

    // MNIST-shaped boolean netlist: 784 x 1b inputs, layers like the preset
    let mnist_like = random_netlist(
        1, 784, 1, &[(360, 6, 1), (60, 6, 1), (10, 6, 6)]);
    // JSC-shaped multi-bit netlist with dense tables: raw addr width 8 and
    // full support, so only the gather kernel applies
    let jsc_dense = random_netlist(
        2, 16, 4, &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4), (5, 2, 8)]);
    // Same shape with trained-like tables (per-bit support <= 6): this is
    // the mixed-width case the bit-plane engine exists for
    let jsc_reduc = random_reducible_netlist(
        3, 16, 4, &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4), (5, 2, 8)],
        6);
    {
        let s = jsc_reduc.simulator();
        assert_eq!(s.bitplane_layers(), jsc_reduc.layers.len(),
                   "reducible netlist must compile fully to bit-plane");
    }

    let default_opts = SimOptions::default();
    let gather_only = SimOptions { bitplane: false, ..Default::default() };
    let interpreted = SimOptions { compiled: false, ..Default::default() };

    for batch in [1usize, 64, 1024] {
        h.sim_row("mnist-like (mostly 1-bit)", &mnist_like, default_opts,
                  batch);
    }
    for batch in [1usize, 64, 1024] {
        h.sim_row("jsc-like dense 4-bit (gather)", &jsc_dense,
                  default_opts, batch);
    }

    // headline comparison: mixed-width netlist, gather vs bit-plane,
    // then bit-plane with intra-batch threads
    let mut speedup_256 = 0.0;
    for batch in [64usize, 256, 1024] {
        let tg = h.sim_row("jsc-like reducible (gather)", &jsc_reduc,
                           gather_only, batch);
        let tb = h.sim_row("jsc-like reducible (bit-plane)", &jsc_reduc,
                           default_opts, batch);
        if batch == 256 {
            speedup_256 = tg / tb;
        }
    }

    // scalar vs wide-word lanes on the same compiled plan: identical
    // bit-plane kernels, W 64-sample words per table evaluation (the
    // lane ops auto-vectorize to the CPU's SIMD width).  The contract
    // (enforced below, skipped under --quick): wide lanes strictly beat
    // the scalar path once the batch fills several lane blocks
    // (batch >= 1024, i.e. >= 16 words per plane); small batches carry
    // no such promise — that is why auto-selection keeps them scalar.
    let lane = |lanes| SimOptions { lanes, ..Default::default() };
    let mut wide_speedup_1024 = 0.0;
    for batch in [64usize, 256, 1024, 4096] {
        let t1 = h.sim_row("jsc-like reducible (lanes w1)", &jsc_reduc,
                           lane(LaneSelect::W1), batch);
        let t4 = h.sim_row("jsc-like reducible (lanes w4)", &jsc_reduc,
                           lane(LaneSelect::W4), batch);
        let t8 = h.sim_row("jsc-like reducible (lanes w8)", &jsc_reduc,
                           lane(LaneSelect::W8), batch);
        println!("wide lanes @ batch {batch}: w4 {:.2}x, w8 {:.2}x vs \
                  scalar", t1 / t4, t1 / t8);
        if batch == 1024 {
            wide_speedup_1024 = t1 / t4;
        }
        if !quick && batch >= 1024 {
            assert!(t4 < t1,
                    "w4 eval {:.1}us not faster than scalar {:.1}us at \
                     batch {batch}", t4 * 1e6, t1 * 1e6);
            assert!(t8 < t1,
                    "w8 eval {:.1}us not faster than scalar {:.1}us at \
                     batch {batch}", t8 * 1e6, t1 * 1e6);
        }
    }

    // compiled execution plan vs the interpreted object-graph walk.
    // Same kernels, same math — the plan removes interpretation
    // overhead: fused row-major input boundary, transpose-free batch-1
    // path, deduplicated table arena, precomputed gather strides, no
    // per-layer buffer reshaping.  The contract (enforced below, skipped
    // under --quick): never slower at any batch size, strictly faster
    // at batch <= 64 where the per-call overhead dominates.
    let mut small_batch_compiled = 0.0;
    for batch in [1usize, 16, 64, 256, 1024] {
        let ti = h.sim_row("mnist-like interpreted", &mnist_like,
                           interpreted, batch);
        let tc = h.sim_row("mnist-like compiled plan", &mnist_like,
                           default_opts, batch);
        println!("compiled vs interpreted @ batch {batch}: {:.2}x",
                 ti / tc);
        if batch == 1 {
            small_batch_compiled = ti / tc;
        }
        if !quick {
            assert!(tc <= ti * 1.10,
                    "compiled eval {:.1}us regressed past interpreted \
                     {:.1}us at batch {batch}",
                    tc * 1e6, ti * 1e6);
            if batch <= 64 {
                assert!(tc < ti,
                        "compiled eval {:.1}us not faster than \
                         interpreted {:.1}us at batch {batch}",
                        tc * 1e6, ti * 1e6);
            }
        }
    }

    // plan compilation cost and the cache that amortizes it: the server
    // compiles once per content hash at registration; workers share the
    // immutable plan
    {
        let reps = h.reps;
        let t_compile = bench(reps, || {
            let p = compile(&mnist_like, PlanOptions::default());
            std::hint::black_box(&p);
        });
        let cache = PlanCache::new();
        let first = cache.get_or_compile(&mnist_like,
                                         PlanOptions::default());
        let t_hit = bench(reps, || {
            let p = cache.get_or_compile(&mnist_like,
                                         PlanOptions::default());
            std::hint::black_box(&p);
        });
        let again = cache.get_or_compile(&mnist_like,
                                         PlanOptions::default());
        assert!(Arc::ptr_eq(&first, &again),
                "cache must return the shared plan");
        println!("plan compile (mnist-like): {:.1} us; cache hit: {:.2} \
                  us ({} plans resident)",
                 t_compile * 1e6, t_hit * 1e6, cache.len());
    }

    // raw vs optimized: the netlist optimizer (const-fold, dead-logic,
    // CSE) runs once at load time; the simulator then compiles fewer
    // units and planes.  The mapper must agree that the optimized
    // netlist is a strictly smaller design on this reducible netlist
    // (dead units and constant-fed address bits are common in it, as in
    // trained tables), and the optimized hot path must never be slower.
    let (jsc_opt, opt_report) = optimize(&jsc_reduc, OptLevel::Full);
    println!("optimizer on jsc-like reducible: {}", opt_report.summary());
    let raw_pluts = map_netlist(&jsc_reduc, true).total_luts();
    let opt_pluts = map_netlist(&jsc_opt, true).total_luts();
    println!("mapped P-LUTs: raw {raw_pluts} -> optimized {opt_pluts}");
    assert!(opt_pluts < raw_pluts,
            "optimized netlist must map strictly smaller: \
             {opt_pluts} !< {raw_pluts}");
    let mut t_raw_1024 = 0.0;
    let mut t_opt_1024 = 0.0;
    for batch in [256usize, 1024] {
        let tr = h.sim_row("jsc-like reducible (raw netlist)", &jsc_reduc,
                           default_opts, batch);
        let to = h.sim_row("jsc-like reducible (optimized)", &jsc_opt,
                           default_opts, batch);
        if batch == 1024 {
            t_raw_1024 = tr;
            t_opt_1024 = to;
        }
    }
    println!("optimized vs raw simulator @ batch 1024: {:.2}x",
             t_raw_1024 / t_opt_1024);
    // enforced, not just printed: serving an optimized netlist must
    // never cost throughput (generous slack absorbs runner noise; the
    // expected direction is a clear win — fewer units and planes)
    if !quick {
        assert!(t_opt_1024 <= t_raw_1024 * 1.15,
                "optimized eval {:.1}us regressed past raw {:.1}us",
                t_opt_1024 * 1e6, t_raw_1024 * 1e6);
    }

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    for threads in [2usize, cores.max(2)] {
        h.sim_row(&format!("jsc-like reducible (bit-plane x{threads}t)"),
                  &jsc_reduc,
                  SimOptions { threads, ..Default::default() }, 4096);
        h.sim_row(&format!("mnist-like (bit-plane x{threads}t)"),
                  &mnist_like,
                  SimOptions { threads, ..Default::default() }, 4096);
    }

    // persistent pool vs per-call scoped spawning.  Small batches are
    // the regime the pool exists for: a scoped spawn never amortizes
    // there (the scoped path stays serial below its work floor), while
    // waking parked workers does.  At large batch both modes fan out
    // identically and the pool only saves the per-layer spawn/join.
    let pooled = |threads| SimOptions {
        threads, mode: ThreadMode::Pooled, ..Default::default()
    };
    let scoped = |threads| SimOptions {
        threads, mode: ThreadMode::Scoped, ..Default::default()
    };
    let mut small_batch_speedup = 0.0;
    for batch in [16usize, 64] {
        for threads in [2usize, 4] {
            let ts = h.sim_row(&format!("mnist-like scoped x{threads}t"),
                               &mnist_like, scoped(threads), batch);
            let tp = h.sim_row(&format!("mnist-like pooled x{threads}t"),
                               &mnist_like, pooled(threads), batch);
            if batch == 64 && threads == 2 {
                small_batch_speedup = ts / tp;
            }
        }
    }
    let big = cores.max(2);
    let ts_large = h.sim_row(&format!("mnist-like scoped x{big}t"),
                             &mnist_like, scoped(big), 4096);
    let tp_large = h.sim_row(&format!("mnist-like pooled x{big}t"),
                             &mnist_like, pooled(big), 4096);

    // per-sample eval_one (the naive baseline the batched path replaced)
    {
        let batch = 1024usize;
        let x = random_inputs(9, &mnist_like, batch);
        let reps = if quick { 2 } else { 5 };
        let t = bench(reps, || {
            for b in 0..batch {
                let out = mnist_like
                    .eval_one(&x[b * 784..(b + 1) * 784])
                    .unwrap();
                std::hint::black_box(&out);
            }
        });
        h.record("mnist-like eval_one loop (baseline)", batch, t);
    }

    // batching server end-to-end (threads + channels + sim)
    for sim_threads in [1usize, 2] {
        let server = InferenceServer::start_single(
            mnist_like.clone(),
            ServerConfig { sim_threads, ..Default::default() });
        let model = server.default_model().to_string();
        let n = 4096usize;
        let rows: Vec<Vec<i32>> = {
            let x = random_inputs(11, &mnist_like, n);
            (0..n).map(|b| x[b * 784..(b + 1) * 784].to_vec()).collect()
        };
        let t = Instant::now();
        server.infer_many(&model, rows).unwrap();
        let secs = t.elapsed().as_secs_f64();
        let st = server.model_stats(&model).unwrap();
        h.record(
            &format!("server e2e x{sim_threads}t ({} batches, occ {:.0}, \
                      mean {:.0}us p99 {:.0}us p999 {:.0}us)",
                     st.batches, st.mean_occupancy, st.latency.mean,
                     st.latency.p99, st.latency.p999),
            n, secs);
        server.shutdown();
    }

    h.table.print();
    h.write_json();
    println!("\nmixed-width bit-plane speedup vs gather @ batch 256: \
              {speedup_256:.2}x (acceptance floor: 2x)");
    println!("compiled plan vs interpreted walk @ batch 1: \
              {small_batch_compiled:.2}x (must be > 1x; no batch may \
              regress)");
    println!("wide lanes (w4) vs scalar @ batch 1024: \
              {wide_speedup_1024:.2}x (strict win required at batch >= \
              1024)");
    println!("pooled vs scoped workers @ batch 64 x2t: \
              {small_batch_speedup:.2}x (pool wakes where a spawn never \
              amortizes)");
    println!("pooled vs scoped workers @ batch 4096 x{big}t: {:.2}x",
             ts_large / tp_large);
    if quick {
        println!("(--quick: timing floors not enforced this run)");
        return;
    }
    // CI-facing floors (full mode): the margin of the bit-plane win is
    // algorithmic (~64 samples per table eval), so runner noise cannot
    // plausibly eat a 3x cushion.
    assert!(speedup_256 >= 2.0,
            "bit-plane speedup {speedup_256:.2}x fell below the 2x floor");
    // the pool must never lose at large batch (identical chunking, no
    // spawn/join); generous slack absorbs CI runner noise
    assert!(tp_large <= ts_large * 1.25,
            "pooled large-batch eval {:.1}us regressed past scoped {:.1}us",
            tp_large * 1e6, ts_large * 1e6);
}
