//! Table III: latency / Fmax / LUT / FF under the two pipelining
//! strategies (register every L-LUT layer vs every 3 layers).
//! (`cargo bench --bench table3_pipelining`)

#[path = "common/mod.rs"]
mod common;

use neuralut::config::Meta;
use neuralut::report::Table;
use neuralut::runtime::Runtime;
use neuralut::timing::{evaluate, DelayModel, Pipelining};

fn main() {
    let meta = Meta::load(Meta::default_dir()).expect("run `make artifacts`");
    let rt = Runtime::new().expect("pjrt");
    let dm = DelayModel::default();
    let mut table = Table::new(
        "Table III — pipelining strategies (model estimates; paper values in parens)",
        &["dataset", "strategy", "latency (ns)", "Fmax (MHz)", "LUTs", "FFs"],
    );

    // paper's Table III numbers for side-by-side shape comparison
    let paper: &[(&str, f64, f64, u64, u64, f64, f64, u64, u64)] = &[
        // (cfg, p1 lat, p1 fmax, p1 luts, p1 ffs, p3 lat, p3 fmax, p3 luts, p3 ffs)
        ("mnist", 6.6, 912.0, 5089, 5699, 2.1, 863.0, 5070, 725),
        ("jsc_cb", 7.0, 994.0, 8535, 2717, 5.7, 352.0, 8539, 1332),
        ("jsc_oml", 6.6, 1067.0, 1844, 1983, 2.1, 941.0, 1780, 540),
        ("nid", 3.4, 1479.0, 95, 187, 1.4, 1471.0, 91, 24),
    ];

    for &(config, l1, f1, lu1, ff1, l3, f3, lu3, ff3) in paper {
        let opts = common::options(config, 7);
        let r = common::run(&rt, &meta, &opts);
        let p1 = evaluate(&r.mapped, Pipelining::EveryLayer, &dm);
        let p3 = evaluate(&r.mapped, Pipelining::EveryK(3), &dm);
        table.row(&[
            config.into(),
            "every layer".into(),
            format!("{:.1} ({l1})", p1.latency_ns),
            format!("{:.0} ({f1})", p1.fmax_mhz),
            format!("{} ({lu1})", p1.luts),
            format!("{} ({ff1})", p1.ffs),
        ]);
        table.row(&[
            config.into(),
            "every 3 layers".into(),
            format!("{:.1} ({l3})", p3.latency_ns),
            format!("{:.0} ({f3})", p3.fmax_mhz),
            format!("{} ({lu3})", p3.luts),
            format!("{} ({ff3})", p3.ffs),
        ]);
        // shape assertions from the paper's discussion
        assert!(p3.ffs < p1.ffs, "{config}: pipeline-3 must register fewer bits");
        assert!(p3.latency_ns < p1.latency_ns,
                "{config}: pipeline-3 must cut latency");
        assert!(p3.fmax_mhz <= p1.fmax_mhz * 1.001,
                "{config}: fewer cuts cannot raise fmax");
    }
    table.print();
    println!(
        "\nshape checks passed: 3-layer pipelining always cuts FFs and \
         latency at some Fmax cost, largest where L-LUTs are deepest \
         (JSC CERNBox), as in the paper."
    );
}
