//! Shared harness code for the table/figure benches.
//!
//! Every bench prints the paper exhibit it regenerates (same rows/series),
//! using scaled-down training budgets by default; set `NLA_FULL=1` to
//! multiply budgets 4x for closer-to-paper operating points.

#![allow(dead_code)]

use std::collections::BTreeMap;

use neuralut::config::Meta;
use neuralut::coordinator::{run_flow, FlowOptions, FlowResult};
use neuralut::dataset::GenOpts;
use neuralut::netlist::OptLevel;
use neuralut::runtime::Runtime;
use neuralut::util::Json;

/// Shared machine-readable bench output: every bench that emits JSON
/// writes `BENCH_<name>.json` through this one function so the schema
/// stays uniform across exhibits — `{"bench": name, "quick": bool,
/// <extra keys>, "rows": [...]}` — and CI uploads are one glob away.
/// A write failure is reported, never fatal: the human-readable table
/// already went to stdout.
pub fn emit_bench_json(name: &str, quick: bool, extra: &[(&str, Json)],
                       rows: Vec<Json>) {
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str(name.into()));
    root.insert("quick".into(), Json::Bool(quick));
    for (k, v) in extra {
        root.insert((*k).to_string(), v.clone());
    }
    root.insert("rows".into(), Json::Arr(rows));
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// A JSON row from (key, value) pairs — the common emitter's unit.
pub fn json_row(fields: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    for (k, v) in fields {
        obj.insert((*k).to_string(), v.clone());
    }
    Json::Obj(obj)
}

pub fn scale() -> usize {
    if std::env::var("NLA_FULL").is_ok() {
        4
    } else {
        1
    }
}

/// Per-config quick training budgets (dense steps, sparse steps, n_train,
/// n_test), chosen so the whole bench suite completes in minutes on one
/// CPU core.
pub fn budget(config: &str) -> (usize, usize, usize, usize) {
    let s = scale();
    let (d, t, tr, te) = match config {
        "nid" => (300, 800, 8000, 1500),
        "mnist" => (40, 600, 8000, 1500),
        "jsc_cb" | "jsc_oml" => (150, 800, 10000, 1500),
        c if c.starts_with("fig5") => (60, 400, 6000, 1200),
        _ => (30, 150, 4000, 1000),
    };
    (d * s, t * s, tr * s, te * s)
}

pub fn options(config: &str, seed: u64) -> FlowOptions {
    let (dense, sparse, n_train, n_test) = budget(config);
    FlowOptions {
        config: config.to_string(),
        dense_steps: dense,
        sparse_steps: sparse,
        skip_scale: 1.0,
        seed,
        gen: GenOpts { n_train, n_test, seed: 0xDA7A, augment: false },
        emit_rtl: false,
        verify_bit_exact: false,
        opt_level: OptLevel::Full,
    }
}

pub fn run(rt: &Runtime, meta: &Meta, opts: &FlowOptions) -> FlowResult {
    let sw = std::time::Instant::now();
    let r = run_flow(rt, meta, opts).expect("flow failed");
    eprintln!(
        "  [{}{}] qat {:.3} netlist {:.3} ({:.0}s)",
        opts.config,
        if opts.skip_scale == 0.0 { " w/o-skips" }
        else if opts.dense_steps == 0 { " w/o-learned" } else { "" },
        r.qat_acc,
        r.netlist_acc,
        sw.elapsed().as_secs_f64()
    );
    r
}

/// A Table IV row reported from the paper itself (prior work we do not
/// re-implement; clearly labelled in the output).
pub struct PaperRow {
    pub dataset: &'static str,
    pub model: &'static str,
    pub acc: f64,
    pub luts: u64,
    pub ffs: u64,
    pub fmax: u64,
    pub latency_ns: f64,
}

pub const PAPER_ROWS: &[PaperRow] = &[
    // MNIST
    PaperRow { dataset: "mnist", model: "NeuraLUT-Assemble (paper)", acc: 0.979, luts: 5070, ffs: 725, fmax: 863, latency_ns: 2.1 },
    PaperRow { dataset: "mnist", model: "TreeLUT (paper)", acc: 0.966, luts: 4478, ffs: 597, fmax: 791, latency_ns: 2.5 },
    PaperRow { dataset: "mnist", model: "DWN (paper)", acc: 0.978, luts: 2092, ffs: 1757, fmax: 873, latency_ns: 9.2 },
    PaperRow { dataset: "mnist", model: "PolyLUT-Add (paper)", acc: 0.96, luts: 14810, ffs: 2609, fmax: 625, latency_ns: 10.0 },
    PaperRow { dataset: "mnist", model: "AmigoLUT-NeuraLUT (paper)", acc: 0.955, luts: 16081, ffs: 13292, fmax: 925, latency_ns: 7.6 },
    PaperRow { dataset: "mnist", model: "NeuraLUT (paper)", acc: 0.96, luts: 54798, ffs: 3757, fmax: 431, latency_ns: 12.0 },
    PaperRow { dataset: "mnist", model: "PolyLUT (paper)", acc: 0.975, luts: 75131, ffs: 4668, fmax: 353, latency_ns: 17.0 },
    PaperRow { dataset: "mnist", model: "FINN (paper)", acc: 0.96, luts: 91131, ffs: 0, fmax: 200, latency_ns: 310.0 },
    PaperRow { dataset: "mnist", model: "hls4ml-binary (paper)", acc: 0.95, luts: 260092, ffs: 165513, fmax: 200, latency_ns: 190.0 },
    // JSC CERNBox
    PaperRow { dataset: "jsc_cb", model: "NeuraLUT-Assemble (paper)", acc: 0.75, luts: 8539, ffs: 1332, fmax: 352, latency_ns: 5.7 },
    PaperRow { dataset: "jsc_cb", model: "AmigoLUT-NeuraLUT (paper)", acc: 0.744, luts: 42742, ffs: 4717, fmax: 520, latency_ns: 9.6 },
    PaperRow { dataset: "jsc_cb", model: "PolyLUT-Add (paper)", acc: 0.75, luts: 36484, ffs: 1209, fmax: 315, latency_ns: 16.0 },
    PaperRow { dataset: "jsc_cb", model: "NeuraLUT (paper)", acc: 0.75, luts: 92357, ffs: 4885, fmax: 368, latency_ns: 14.0 },
    PaperRow { dataset: "jsc_cb", model: "PolyLUT (paper)", acc: 0.751, luts: 246071, ffs: 12384, fmax: 203, latency_ns: 25.0 },
    PaperRow { dataset: "jsc_cb", model: "LogicNets (paper)", acc: 0.72, luts: 37931, ffs: 810, fmax: 427, latency_ns: 13.0 },
    // JSC OpenML
    PaperRow { dataset: "jsc_oml", model: "NeuraLUT-Assemble (paper)", acc: 0.76, luts: 1780, ffs: 540, fmax: 941, latency_ns: 2.1 },
    PaperRow { dataset: "jsc_oml", model: "TreeLUT (paper)", acc: 0.756, luts: 2234, ffs: 347, fmax: 735, latency_ns: 2.7 },
    PaperRow { dataset: "jsc_oml", model: "DWN (paper)", acc: 0.763, luts: 6302, ffs: 4128, fmax: 695, latency_ns: 14.4 },
    PaperRow { dataset: "jsc_oml", model: "hls4ml (paper)", acc: 0.762, luts: 63251, ffs: 4394, fmax: 200, latency_ns: 45.0 },
    // NID
    PaperRow { dataset: "nid", model: "NeuraLUT-Assemble (paper)", acc: 0.93, luts: 91, ffs: 24, fmax: 1471, latency_ns: 1.4 },
    PaperRow { dataset: "nid", model: "TreeLUT (paper)", acc: 0.927, luts: 345, ffs: 33, fmax: 681, latency_ns: 1.5 },
    PaperRow { dataset: "nid", model: "PolyLUT-Add (paper)", acc: 0.92, luts: 1649, ffs: 830, fmax: 620, latency_ns: 8.0 },
    PaperRow { dataset: "nid", model: "PolyLUT (paper)", acc: 0.922, luts: 3165, ffs: 774, fmax: 580, latency_ns: 9.0 },
    PaperRow { dataset: "nid", model: "LogicNets (paper)", acc: 0.91, luts: 15949, ffs: 1274, fmax: 471, latency_ns: 13.0 },
];
