//! Figure 5: JSC ablation over three tree architectures x three
//! configurations (complete / w/o learned mappings / w/o tree-level
//! skips), reporting mapped area (bar) and accuracy spread over seeds
//! (box).  Writes `BENCH_fig5_ablation.json` through the shared
//! `benches/common` emitter.
//!
//! Needs the compiled-config artifacts (`make artifacts`) and a PJRT
//! runtime.  When either is missing — notably in CI, which builds no
//! artifacts — the bench degrades gracefully: it reports why, emits a
//! JSON document with `"skipped": true` and no rows, and exits 0, so
//! the exhibit can run `--quick` in the gate without a hard dependency
//! on the training stack.  (`cargo bench --bench fig5_ablation`)

#[path = "common/mod.rs"]
mod common;

use neuralut::config::Meta;
use neuralut::report::{pct, Table};
use neuralut::runtime::Runtime;
use neuralut::util::Json;

fn emit_skipped(quick: bool, reason: &str) {
    println!("fig5_ablation skipped: {reason}");
    common::emit_bench_json(
        "fig5_ablation", quick,
        &[("skipped", Json::Bool(true)),
          ("reason", Json::Str(reason.into()))],
        Vec::new());
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let meta = match Meta::load(Meta::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            return emit_skipped(quick, &format!(
                "no compiled-config artifacts (run `make artifacts`): \
                 {e:#}"));
        }
    };
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            return emit_skipped(quick, &format!(
                "no PJRT runtime available: {e:#}"));
        }
    };
    let seeds: Vec<u64> = if quick {
        vec![7]
    } else if common::scale() > 1 {
        vec![7, 17, 27, 37]
    } else {
        vec![7, 17]
    };

    let mut table = Table::new(
        "Fig. 5 — JSC ablation: area (P-LUTs) and accuracy over seeds",
        &["architecture", "variant", "P-LUTs", "acc mean", "acc min..max"],
    );

    let archs = [
        ("fig5_opt1", "(1) 16-in tree of 4-LUTs, depth 2"),
        ("fig5_opt2", "(2) 16-in tree of 2-LUTs, depth 4"),
        ("fig5_opt3", "(3) 64-in tree of 2-LUTs, depth 6"),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut area_by_arch = Vec::new();
    let mut complete_mean = Vec::new();
    let mut wo_map_mean = Vec::new();
    let mut wo_skip_mean = Vec::new();
    for (config, label) in archs {
        for (variant, dense0, skip) in [
            ("complete", false, 1.0f32),
            ("w/o learned mappings", true, 1.0),
            ("w/o tree-level skips", false, 0.0),
        ] {
            let mut accs = Vec::new();
            let mut area = 0usize;
            for &seed in &seeds {
                let mut opts = common::options(config, seed);
                if dense0 {
                    opts.dense_steps = 0; // random connectivity
                }
                if quick {
                    // one seed, slashed budgets: exercises the whole
                    // ablation matrix without CI-scale training time
                    opts.dense_steps = opts.dense_steps.min(20);
                    opts.sparse_steps = opts.sparse_steps.min(60);
                    opts.gen.n_train = opts.gen.n_train.min(1500);
                    opts.gen.n_test = opts.gen.n_test.min(500);
                }
                opts.skip_scale = skip;
                let r = common::run(&rt, &meta, &opts);
                accs.push(r.netlist_acc);
                area = r.mapped.total_luts();
            }
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            let min = accs.iter().cloned().fold(1.0f64, f64::min);
            let max = accs.iter().cloned().fold(0.0f64, f64::max);
            table.row(&[
                label.into(),
                variant.into(),
                area.to_string(),
                pct(mean),
                format!("{}..{}", pct(min), pct(max)),
            ]);
            rows.push(common::json_row(&[
                ("architecture", Json::Str(config.into())),
                ("variant", Json::Str(variant.into())),
                ("p_luts", Json::Num(area as f64)),
                ("acc_mean", Json::Num(mean)),
                ("acc_min", Json::Num(min)),
                ("acc_max", Json::Num(max)),
                ("seeds", Json::Num(accs.len() as f64)),
            ]));
            match variant {
                "complete" => {
                    complete_mean.push(mean);
                    area_by_arch.push(area);
                }
                "w/o learned mappings" => wo_map_mean.push(mean),
                _ => wo_skip_mean.push(mean),
            }
        }
    }
    table.print();
    common::emit_bench_json(
        "fig5_ablation", quick,
        &[("skipped", Json::Bool(false)),
          ("seeds", Json::Num(seeds.len() as f64))],
        rows);

    // the paper's Fig. 5 takeaways, as shape checks
    println!("\nshape checks:");
    let a1 = area_by_arch[0] as f64;
    let a2 = area_by_arch[1] as f64;
    let a3 = area_by_arch[2] as f64;
    println!(
        "  area(1)/area(2) = {:.1}x (paper: 26x worst-case bound; support-\n   reduced tables land lower), area(1)/area(3) = {:.1}x (paper: 3.4x)",
        a1 / a2, a1 / a3
    );
    let d_map: f64 = complete_mean
        .iter()
        .zip(&wo_map_mean)
        .map(|(c, w)| c - w)
        .sum::<f64>() / 3.0;
    let d_skip: f64 = complete_mean
        .iter()
        .zip(&wo_skip_mean)
        .map(|(c, w)| c - w)
        .sum::<f64>() / 3.0;
    println!("  mean accuracy drop w/o learned mappings: {:.1}pp", d_map * 100.0);
    println!("  mean accuracy drop w/o tree-level skips: {:.1}pp", d_skip * 100.0);
    println!(
        "  skip-ablation drop by depth (paper: grows with tree depth): \
         d2 {:.1}pp, d4 {:.1}pp, d6 {:.1}pp",
        (complete_mean[0] - wo_skip_mean[0]) * 100.0,
        (complete_mean[1] - wo_skip_mean[1]) * 100.0,
        (complete_mean[2] - wo_skip_mean[2]) * 100.0
    );
}
