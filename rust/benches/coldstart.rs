//! Cold-start bench: what does it cost to get N models *runnable* in a
//! fresh process?  Four paths, same netlists (EXPERIMENTS.md §Cold
//! start):
//!
//! * **recompile** — the pre-artifact world: plans compiled from the
//!   in-memory netlists (bit-plane decomposition, support extraction,
//!   table interning — all redone every process start);
//! * **copy-load** — `load_nlb` on exported `.nlb` artifacts carrying
//!   compiled-plan images (read + checksum + full validation, arenas
//!   copied into owned buffers);
//! * **mmap-load** — `load_nlb_mapped` on the same artifacts: identical
//!   validation, but the word/conn arenas are borrowed zero-copy from
//!   the mapping (v2 files pad so the offsets are 8-byte aligned);
//! * **plan cache** — a fresh `PlanCache::persistent` instance over a
//!   warm cache directory (the restarted-server path; must serve every
//!   plan from disk, asserted via `disk_hits` — disk hits are mapped
//!   by default, `set_mmap(false)` timed as the copying contrast).
//!
//! Every mapped plan is also run through the engine `check_conformance`
//! suite against its own netlist — scalar, `WidePlanExecutor` at
//! W ∈ {4, 8}, and a sample over TCP — so the bench doubles as the CI
//! cold-start smoke (`-- --quick` skips the timing floors, never the
//! conformance).  Writes `BENCH_coldstart.json` through the shared
//! `benches/common` emitter.  (`cargo bench --bench coldstart`)

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use neuralut::coordinator::{check_conformance, InferenceServer,
                            ModelRegistry, ServerConfig};
use neuralut::net::{NetConfig, NetServer, RemoteEngine};
use neuralut::netlist::testutil::random_reducible_netlist;
use neuralut::netlist::{compile, load_nlb, load_nlb_mapped, save_nlb,
                        Netlist, PlanCache, PlanExecutor, PlanOptions,
                        WidePlanExecutor};
use neuralut::report::Table;
use neuralut::util::Json;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    median(times)
}

/// Whether this host takes the zero-copy path at all (elsewhere the
/// mapped loader transparently copies, so the mmap row degenerates to
/// the copy-load row and its floor is skipped).
fn host_maps() -> bool {
    cfg!(all(unix, target_pointer_width = "64", target_endian = "little"))
}

/// N structurally distinct jsc-shaped reducible netlists (per-bit
/// support <= 6, the structure trained tables have) with unique
/// content hashes.
fn model_fleet(n: usize) -> Vec<Netlist> {
    (0..n)
        .map(|i| {
            let mut nl = random_reducible_netlist(
                1000 + i as u64, 16, 4,
                &[(80, 2, 4), (40, 2, 4), (20, 2, 4), (10, 2, 4),
                  (5, 2, 8)],
                6);
            nl.name = format!("fleet{i}");
            nl
        })
        .collect()
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nla_coldstart_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 2 } else { 7 };
    if quick {
        println!("--quick: minimal reps, timing floors skipped \
                  (conformance still enforced)");
    }
    let n_total = 64usize;
    let fleet = model_fleet(n_total);
    let opts = PlanOptions::default();

    // export the whole fleet once: .nlb with plan images
    let art_dir = temp_dir("artifacts");
    let paths: Vec<PathBuf> = fleet
        .iter()
        .map(|nl| {
            let p = art_dir.join(format!("{}.nlb", nl.name));
            let plan = compile(nl, opts);
            save_nlb(&p, nl, Some(&plan)).unwrap();
            p
        })
        .collect();

    // warm plan-cache directory (what a prior server run leaves behind)
    let cache_dir = temp_dir("plancache");
    {
        let warm = PlanCache::persistent(&cache_dir);
        for nl in &fleet {
            warm.get_or_compile(nl, opts);
        }
        assert_eq!(warm.misses(), n_total as u64,
                   "warming must compile every model once");
    }

    let mut table = Table::new(
        "cold start: N models runnable in a fresh process",
        &["path", "N", "median total", "per model"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut record = |table: &mut Table, rows: &mut Vec<Json>, case: &str,
                      n: usize, secs: f64| {
        table.row(&[
            case.into(),
            n.to_string(),
            format!("{:.2} ms", secs * 1e3),
            format!("{:.1} us", secs * 1e6 / n as f64),
        ]);
        rows.push(common::json_row(&[
            ("case", Json::Str(case.into())),
            ("n_models", Json::Num(n as f64)),
            ("ms", Json::Num(secs * 1e3)),
            ("us_per_model", Json::Num(secs * 1e6 / n as f64)),
        ]));
    };

    let mut compile_at = BTreeMap::new();
    let mut load_at = BTreeMap::new();
    let mut mmap_at = BTreeMap::new();
    let mut cache_at = BTreeMap::new();
    for n in [1usize, 8, 16, n_total] {
        let t_compile = bench(reps, || {
            for nl in &fleet[..n] {
                std::hint::black_box(compile(nl, opts));
            }
        });
        record(&mut table, &mut rows, "recompile from netlist", n,
               t_compile);
        let t_load = bench(reps, || {
            for p in &paths[..n] {
                let m = load_nlb(p).unwrap();
                assert!(m.plan.is_some());
                std::hint::black_box(&m);
            }
        });
        record(&mut table, &mut rows, "copy-load .nlb plan image", n,
               t_load);
        let t_mmap = bench(reps, || {
            for p in &paths[..n] {
                let m = load_nlb_mapped(p).unwrap();
                let plan = m.plan.as_ref().expect("plan image");
                assert_eq!(plan.is_mapped(), host_maps(),
                           "zero-copy load expected iff the host \
                            supports it");
                std::hint::black_box(&m);
            }
        });
        record(&mut table, &mut rows, "mmap-load .nlb plan image", n,
               t_mmap);
        let t_cache = bench(reps, || {
            let cache = PlanCache::persistent(&cache_dir);
            for nl in &fleet[..n] {
                std::hint::black_box(cache.get_or_compile(nl, opts));
            }
            assert_eq!(cache.disk_hits(), n as u64,
                       "every plan must come from the warm disk cache");
        });
        record(&mut table, &mut rows, "persistent plan cache (warm)", n,
               t_cache);
        compile_at.insert(n, t_compile);
        load_at.insert(n, t_load);
        mmap_at.insert(n, t_mmap);
        cache_at.insert(n, t_cache);
    }

    // conformance: every *mapped* plan must satisfy the engine contract
    // against its own netlist, at every lane width — this is the CI
    // smoke payload, and the proof that borrowing arenas from a mapping
    // changes nothing observable
    for (i, p) in paths.iter().enumerate() {
        let m = load_nlb_mapped(p).unwrap();
        let plan = Arc::new(
            m.plan.expect("artifact carries a plan image"));
        assert_eq!(plan.is_mapped(), host_maps());
        let mut w1 = PlanExecutor::new(plan.clone());
        check_conformance(&mut w1, &m.netlist, 0xC0 + i as u64)
            .unwrap_or_else(|e| panic!("model {i} w1: {e:#}"));
        let mut w4: WidePlanExecutor<4> =
            WidePlanExecutor::new(plan.clone());
        check_conformance(&mut w4, &m.netlist, 0xC0 + i as u64)
            .unwrap_or_else(|e| panic!("model {i} w4: {e:#}"));
        let mut w8: WidePlanExecutor<8> = WidePlanExecutor::new(plan);
        check_conformance(&mut w8, &m.netlist, 0xC0 + i as u64)
            .unwrap_or_else(|e| panic!("model {i} w8: {e:#}"));
    }
    println!("conformance: {} mapped plans pass the engine contract at \
              W in {{1, 4, 8}}", paths.len());

    // ...and over TCP: a served mapped artifact answers bit-exactly
    // through the whole wire stack
    {
        let mut registry = ModelRegistry::new();
        let m = load_nlb_mapped(&paths[0]).unwrap();
        assert_eq!(m.plan.as_ref().map(|p| p.is_mapped()),
                   Some(host_maps()));
        registry.register_artifact("fleet0", m);
        let server = InferenceServer::start(
            registry, ServerConfig::default());
        let net = NetServer::bind(server, "127.0.0.1:0",
                                  NetConfig::default())
            .expect("bind loopback");
        let mut remote = RemoteEngine::open(net.local_addr(), "fleet0")
            .expect("connect");
        check_conformance(&mut remote, &fleet[0], 0x7C9)
            .unwrap_or_else(|e| panic!("tcp conformance: {e:#}"));
        net.shutdown();
        println!("conformance: mapped plan serves bit-exactly over TCP");
    }

    table.print();
    common::emit_bench_json(
        "coldstart", quick,
        &[("reps", Json::Num(reps as f64)),
          ("n_models", Json::Num(n_total as f64)),
          ("host_maps", Json::Bool(host_maps()))],
        rows);

    for n in [8usize, 16, n_total] {
        println!("@ {n} models: copy-load {:.2}x vs recompile, \
                  mmap-load {:.2}x vs copy-load, warm cache {:.2}x vs \
                  recompile",
                 compile_at[&n] / load_at[&n],
                 load_at[&n] / mmap_at[&n],
                 compile_at[&n] / cache_at[&n]);
    }

    let _ = std::fs::remove_dir_all(&art_dir);
    let _ = std::fs::remove_dir_all(&cache_dir);
    if quick {
        println!("(--quick: timing floors not enforced this run)");
        return;
    }
    // the acceptance floors: at >= 8 registered models both artifact
    // paths must beat recompilation outright — skipping bit-plane
    // decomposition and table interning is an algorithmic win, not a
    // constant-factor one, so no noise slack is granted — and the
    // mapped load must beat the copying load (O(validation) vs
    // O(bytes); only meaningful where the host actually maps)
    for n in [8usize, 16, n_total] {
        assert!(load_at[&n] < compile_at[&n],
                "@ {n} models: copy-load {:.2}ms not faster than \
                 recompile {:.2}ms",
                load_at[&n] * 1e3, compile_at[&n] * 1e3);
        assert!(cache_at[&n] < compile_at[&n],
                "@ {n} models: warm plan cache {:.2}ms not faster than \
                 recompile {:.2}ms",
                cache_at[&n] * 1e3, compile_at[&n] * 1e3);
        if host_maps() {
            assert!(mmap_at[&n] < load_at[&n],
                    "@ {n} models: mmap-load {:.2}ms not faster than \
                     copy-load {:.2}ms",
                    mmap_at[&n] * 1e3, load_at[&n] * 1e3);
        }
    }
}
